"""Property tests: page-pool allocator + radix prefix tree invariants.

The whole module needs ``hypothesis`` (like the other property modules —
CI installs it; the bare container skips).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; CI installs it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.pages import PagePool  # noqa: E402
from repro.serve.prefix import RadixPrefixCache  # noqa: E402


# ---------------------------------------------------------------------------
# PagePool: alloc/free/refcount never leaks or double-frees
# ---------------------------------------------------------------------------
def _pool_invariants(pool: PagePool, live):
    held = [p for pages in live.values() for p in pages]
    # no page is in two live allocations
    assert len(held) == len(set(held))
    # conservation: every page is exactly one of free / cold / hot
    assert pool.n_free + pool.n_cold + pool.n_hot == pool.n_pages
    # every held page is referenced
    for p in held:
        assert pool.refcount(p) >= 1


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 24),
       st.lists(st.tuples(st.integers(0, 2), st.integers(1, 6),
                          st.booleans()),
                min_size=1, max_size=40))
def test_pool_alloc_free_never_leaks(n_pages, ops):
    """Random alloc/decref/cache interleavings: pages are never shared
    between live allocations, never lost, and never double-freed."""
    pool = PagePool(n_pages, page_size=2)
    live = {}
    uid = 0
    for kind, n, mark in ops:
        if kind == 0:                      # alloc
            got = pool.alloc(n)
            if n > n_pages:
                assert got is None
                continue
            if got is not None:
                assert len(got) == n
                if mark:                   # register with the "tree"
                    for p in got:
                        pool.mark_cached(p)
                live[uid] = got
                uid += 1
        elif kind == 1 and live:           # release the oldest allocation
            k = min(live)
            pool.decref(live.pop(k))
        elif kind == 2 and live:           # share then release (refcount)
            k = max(live)
            pool.incref(live[k])
            pool.decref(live[k])
        _pool_invariants(pool, live)
    for pages in live.values():
        pool.decref(pages)
    # everything released: nothing hot beyond zero
    assert pool.n_hot == 0
    assert pool.n_free + pool.n_cold == pool.n_pages


def test_pool_double_free_raises():
    pool = PagePool(4, page_size=2)
    pages = pool.alloc(2)
    pool.decref(pages)
    with pytest.raises(RuntimeError, match="double free"):
        pool.decref(pages)


def test_pool_odd_page_size_rejected():
    with pytest.raises(ValueError, match="even"):
        PagePool(4, page_size=3)


def test_pool_eviction_is_lru_and_notifies():
    """Cold pages evict oldest-first and the hook fires per eviction."""
    pool = PagePool(4, page_size=2)
    evicted = []
    pool.evict_hook = evicted.append
    a = pool.alloc(2)
    b = pool.alloc(2)
    for p in a + b:
        pool.mark_cached(p)
    pool.decref(a)          # a goes cold first → LRU victim
    pool.decref(b)
    got = pool.alloc(3)
    assert got is not None and len(got) == 3
    assert evicted[:2] == a  # oldest cold allocation evicted first
    assert pool.evictions == 3


# ---------------------------------------------------------------------------
# Radix prefix tree: insert/match/evict invariants
# ---------------------------------------------------------------------------
PS = 4  # block/page size for tree tests


def _blocks(rng, n, alphabet=3):
    return rng.integers(0, alphabet, size=n * PS).astype(np.int32)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5), st.integers(0, 6))
def test_radix_match_returns_inserted_prefix(seed, n_blocks, max_blocks):
    """Immediately after insert (owner still holds its refs), matching
    the same prompt returns exactly the inserted pages, capped at
    max_blocks, and each returned page carries the match's reference."""
    rng = np.random.default_rng(seed)
    pool = PagePool(16, PS)
    tree = RadixPrefixCache(pool)
    tokens = _blocks(rng, n_blocks)
    pages = pool.alloc(n_blocks)
    tree.insert(tokens, pages)
    got = tree.match(tokens, max_blocks=max_blocks)
    assert got == pages[:min(max_blocks, n_blocks)]
    for p in got:
        assert pool.refcount(p) >= 2       # owner + match
    pool.decref(got)
    pool.decref(pages)
    assert pool.n_hot == 0


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 4))
def test_radix_divergent_tails_share_common_prefix(seed, n_shared):
    """Two prompts sharing n_shared leading blocks: the second match
    walks the shared path only; pages past the divergence are not
    returned."""
    rng = np.random.default_rng(seed)
    pool = PagePool(32, PS)
    tree = RadixPrefixCache(pool)
    shared = _blocks(rng, n_shared)
    a = np.concatenate([shared, _blocks(rng, 2) + 10])
    b = np.concatenate([shared, _blocks(rng, 2) + 20])
    pa = pool.alloc(n_shared + 2)
    tree.insert(a, pa)
    got = tree.match(b, max_blocks=n_shared + 2)
    assert got == pa[:n_shared]
    pool.decref(got)
    pool.decref(pa)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.lists(st.integers(1, 3), min_size=2, max_size=8))
def test_radix_eviction_never_strands_live_pages(seed, sizes):
    """Insert prompts until the pool must evict: every page a match
    returns is hot (refcounted), evicted pages vanish from the tree, and
    free+cold+hot conservation holds throughout."""
    rng = np.random.default_rng(seed)
    pool = PagePool(10, PS)
    tree = RadixPrefixCache(pool)
    for n in sizes:
        tokens = _blocks(rng, n, alphabet=5)
        got = tree.match(tokens, max_blocks=max(n - 1, 0))
        fresh = pool.alloc(n - len(got))
        if fresh is None:                  # pool genuinely full of hot pages
            pool.decref(got)
            continue
        tree.insert(tokens, got + fresh)
        for p in got + fresh:
            assert pool.refcount(p) >= 1
        pool.decref(got + fresh)           # retire immediately
        assert pool.n_free + pool.n_cold + pool.n_hot == pool.n_pages
        assert pool.n_hot == 0
        # the tree never references a freed page
        for page, node in tree._by_page.items():
            assert node.page == page
            assert pool._cached[page]


def test_radix_reset_releases_everything():
    pool = PagePool(8, PS)
    tree = RadixPrefixCache(pool)
    tokens = np.arange(3 * PS, dtype=np.int32)
    pages = pool.alloc(3)
    tree.insert(tokens, pages)
    pool.decref(pages)
    assert pool.n_cold == 3
    tree.reset()
    assert pool.n_cold == 0 and pool.n_free == 8
    assert tree.match(tokens, max_blocks=3) == []
