"""OpenAI-compatible HTTP frontend: SSE protocol, parity, abort."""
import http.client
import json
import socket
import struct
import threading
import time

import jax
import pytest

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import (Engine, Request, SamplingParams, ServeConfig,
                         encode_text, serve_http)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _engine(cfg, params, **kw):
    # prefill_len 48: byte-level chat rendering (<|role|>...<|end|>)
    # runs ~30-40 tokens, which must fit the unpaged compiled prefill
    defaults = dict(max_len=64, decode_batch=3, max_new_tokens=6,
                    prefill_len=48, scheduler="continuous")
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


@pytest.fixture()
def server(tiny):
    """Engine + HTTP server on an ephemeral port; yields (host, port,
    engine), tears the server down after the test."""
    cfg, params = tiny
    eng = _engine(cfg, params)
    httpd, srv = serve_http(eng, port=0, model_id="repro-test")
    t = threading.Thread(target=httpd.serve_forever, daemon=True)
    t.start()
    host, port = httpd.server_address[:2]
    yield host, port, eng
    httpd.shutdown()
    srv.close()


def _post(host, port, path, body, timeout=120):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    conn.request("POST", path, json.dumps(body),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    data = resp.read()
    conn.close()
    return resp.status, json.loads(data)


def _get(host, port, path):
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", path)
    resp = conn.getresponse()
    data = resp.read()
    ctype = resp.getheader("Content-Type", "")
    conn.close()
    return resp.status, data, ctype


def _stream(host, port, path, body, timeout=120):
    """POST with stream=true; returns the decoded SSE data payloads
    (http.client undoes the chunked transfer encoding)."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    conn.request("POST", path, json.dumps({**body, "stream": True}),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    assert resp.status == 200, resp.read()
    raw = resp.read().decode()
    conn.close()
    return [f[len("data: "):] for f in
            (s.strip() for s in raw.split("\n\n")) if f.startswith("data: ")]


# ---------------------------------------------------------------------------
# Protocol conformance
# ---------------------------------------------------------------------------
def test_completion_non_stream(server):
    host, port, _ = server
    status, out = _post(host, port, "/v1/completions",
                        {"prompt": "hello world", "max_tokens": 4})
    assert status == 200
    assert out["object"] == "text_completion"
    assert out["id"].startswith("cmpl-")
    choice = out["choices"][0]
    assert choice["finish_reason"] == "length"
    assert len(choice["token_ids"]) == 4
    assert choice["text"] == "".join(f"<{t}>" for t in choice["token_ids"])
    assert out["usage"] == {"prompt_tokens": len(b"hello world"),
                            "completion_tokens": 4, "total_tokens":
                            len(b"hello world") + 4}


def test_completion_token_id_prompt(server):
    host, port, _ = server
    status, out = _post(host, port, "/v1/completions",
                        {"prompt": [5, 6, 7], "max_tokens": 2})
    assert status == 200
    assert out["usage"]["prompt_tokens"] == 3


def test_chat_stream_protocol(server):
    """SSE stream: role delta first, content deltas, exactly one
    finish_reason on the final data chunk, then [DONE]."""
    host, port, _ = server
    frames = _stream(host, port, "/v1/chat/completions",
                     {"messages": [{"role": "user", "content": "hi"}],
                      "max_tokens": 5})
    assert frames[-1] == "[DONE]"
    events = [json.loads(f) for f in frames[:-1]]
    assert all(e["object"] == "chat.completion.chunk" for e in events)
    assert all(e["id"].startswith("chatcmpl-") for e in events)
    assert len({e["id"] for e in events}) == 1
    assert events[0]["choices"][0]["delta"] == {"role": "assistant"}
    finishes = [e["choices"][0]["finish_reason"] for e in events]
    assert finishes[-1] == "length"
    assert all(f is None for f in finishes[:-1])
    tokens = [e["choices"][0]["token_ids"][0] for e in events
              if e["choices"][0].get("delta", {}).get("content")]
    assert len(tokens) == 5
    assert "usage" in events[-1]


def test_http_stream_matches_generate(tiny, server):
    """The streamed tokens are exactly what Engine.generate() produces
    for the same (prompt, SamplingParams) — greedy and seeded-sampled."""
    cfg, params = tiny
    host, port, _ = server
    prompt = "parity check prompt"
    ids = encode_text(prompt, cfg.vocab)

    ref = _engine(cfg, params).generate([
        Request(uid=1, prompt=ids, params=SamplingParams(max_new_tokens=6)),
        Request(uid=2, prompt=ids,
                params=SamplingParams(temperature=0.9, top_p=0.8, top_k=7,
                                      seed=123, max_new_tokens=6))])

    for req_body, want in [
            ({"prompt": prompt, "max_tokens": 6}, ref[0]),
            ({"prompt": prompt, "max_tokens": 6, "temperature": 0.9,
              "top_p": 0.8, "top_k": 7, "seed": 123}, ref[1])]:
        status, out = _post(host, port, "/v1/completions", req_body)
        assert status == 200
        assert out["choices"][0]["token_ids"] == want.tokens.tolist()


def test_concurrent_streams(server):
    """Two clients streaming at once both complete with full outputs."""
    host, port, _ = server
    results = {}

    def worker(i):
        frames = _stream(host, port, "/v1/completions",
                         {"prompt": f"client {i}", "max_tokens": 6})
        results[i] = frames

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    for i in range(2):
        frames = results[i]
        assert frames[-1] == "[DONE]"
        events = [json.loads(f) for f in frames[:-1]]
        tokens = [e["choices"][0]["token_ids"][0] for e in events
                  if e["choices"][0].get("text")]
        assert len(tokens) == 6


def test_disconnect_aborts_request(tiny):
    """Closing the socket mid-stream must abort the request: the slot
    frees, pages decref, and the aborted counter ticks."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, page_size=8, max_len=512,
                  max_new_tokens=400, prefill_len=16)
    httpd, srv = serve_http(eng, port=0, model_id="repro-test")
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    host, port = httpd.server_address[:2]
    try:
        conn = http.client.HTTPConnection(host, port, timeout=120)
        conn.request("POST", "/v1/completions",
                     json.dumps({"prompt": "runaway generation",
                                 "stream": True, "max_tokens": 400}),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        # read one SSE frame worth, then vanish. SO_LINGER(0) turns the
        # close into an RST so the server's very next chunk write fails
        # (a plain FIN close can let frames pile into the socket buffer
        # until the whole 400-token generation completes "successfully").
        # Note resp holds a makefile() reference to the same socket, so
        # closing conn.sock alone never closes the fd — close both.
        resp.read(64)
        conn.sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                             struct.pack("ii", 1, 0))
        resp.close()
        conn.close()
        deadline = time.time() + 30
        while time.time() < deadline:
            st = srv.stats()
            if st["aborted"] >= 1 and eng.sched.table.n_active == 0:
                break
            time.sleep(0.2)
        st = srv.stats()
        assert st["aborted"] == 1
        assert eng.sched.table.n_active == 0
        # only the parked pages stay hot — nothing leaked
        assert st["pages_hot"] == eng.sc.decode_batch
    finally:
        httpd.shutdown()
        srv.close()


# ---------------------------------------------------------------------------
# Logprobs surfaces
# ---------------------------------------------------------------------------
def test_completions_logprobs_non_stream(server):
    """Completions-style block: parallel arrays over positions, greedy
    sampled token tops its own top_logprobs map."""
    host, port, _ = server
    status, out = _post(host, port, "/v1/completions",
                        {"prompt": "logprob check", "max_tokens": 4,
                         "logprobs": 2})
    assert status == 200
    choice = out["choices"][0]
    lp = choice["logprobs"]
    assert len(lp["tokens"]) == 4
    assert len(lp["token_logprobs"]) == 4
    assert lp["tokens"] == [f"<{t}>" for t in choice["token_ids"]]
    for piece, chosen, top in zip(lp["tokens"], lp["token_logprobs"],
                                  lp["top_logprobs"]):
        assert len(top) == 2
        assert piece in top                 # greedy: argmax emitted
        assert abs(top[piece] - chosen) < 1e-6
        assert all(v <= 0.0 for v in top.values())


def test_completions_no_logprobs_field_when_not_requested(server):
    host, port, _ = server
    status, out = _post(host, port, "/v1/completions",
                        {"prompt": "plain", "max_tokens": 2})
    assert status == 200
    assert "logprobs" not in out["choices"][0]


def test_chat_stream_logprobs_chunks(server):
    """Chat stream: every content delta carries one logprobs content
    entry with the requested top_logprobs width."""
    host, port, _ = server
    frames = _stream(host, port, "/v1/chat/completions",
                     {"messages": [{"role": "user", "content": "hi"}],
                      "max_tokens": 3, "logprobs": True,
                      "top_logprobs": 2})
    events = [json.loads(f) for f in frames if f != "[DONE]"]
    content_evs = [ev for ev in events
                   if ev["choices"][0].get("delta", {}).get("content")]
    assert len(content_evs) == 3
    for ev in content_evs:
        choice = ev["choices"][0]
        entries = choice["logprobs"]["content"]
        assert len(entries) == 1
        e = entries[0]
        assert e["token"] == choice["delta"]["content"]
        assert len(e["top_logprobs"]) == 2
        assert e["top_logprobs"][0]["token"] == e["token"]
        assert abs(e["top_logprobs"][0]["logprob"] - e["logprob"]) < 1e-6


def test_logprobs_validation_envelope(server):
    """Out-of-range logprobs (> compiled TOP_LOGPROBS) is a 400, not an
    engine crash."""
    host, port, _ = server
    status, out = _post(host, port, "/v1/completions",
                        {"prompt": "x", "max_tokens": 1, "logprobs": 9})
    assert status == 400 and "logprobs" in out["error"]["message"]


# ---------------------------------------------------------------------------
# Error envelopes + introspection routes
# ---------------------------------------------------------------------------
def test_error_envelopes(server):
    host, port, eng = server
    status, out = _post(host, port, "/v1/completions", {"prompt": 42})
    assert status == 400 and out["error"]["type"] == "invalid_request_error"

    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("POST", "/v1/completions", "{broken",
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    out = json.loads(resp.read())
    conn.close()
    assert resp.status == 400 and "error" in out

    status, out = _post(host, port, "/v1/chat/completions",
                        {"messages": []})
    assert status == 400

    status, out = _post(host, port, "/v1/completions",
                        {"prompt": "x", "stop": ["\n"]})
    assert status == 400 and "stop_token_ids" in out["error"]["message"]

    status, out = _post(host, port, "/v1/completions",
                        {"prompt": "x", "model": "gpt-4"})
    assert status == 404 and out["error"]["type"] == "not_found_error"

    long_prompt = "y" * (eng.sc.max_len + 10)
    status, out = _post(host, port, "/v1/completions",
                        {"prompt": long_prompt})
    assert status == 400 and "error" in out

    status, out = _post(host, port, "/v1/nope", {})
    assert status == 404


def test_introspection_routes(server):
    host, port, _ = server
    status, body, _ = _get(host, port, "/health")
    assert status == 200 and json.loads(body)["status"] == "ok"

    status, body, _ = _get(host, port, "/v1/models")
    models = json.loads(body)
    assert status == 200 and models["data"][0]["id"] == "repro-test"

    status, body, ctype = _get(host, port, "/metrics")
    assert status == 200 and b"# TYPE" in body
    assert ctype.startswith("text/plain")

    status, body, _ = _get(host, port, "/metrics.json")
    snap = json.loads(body)
    assert status == 200 and "admitted" in snap
