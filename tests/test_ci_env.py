"""CI environment guards.

The property-test modules (``test_kernels``, ``test_quantizers``,
``test_core_srr``, ``test_paged_pool``) open with
``pytest.importorskip("hypothesis")`` so local environments without it
still run the rest of tier-1. That skip is silent — if hypothesis ever
dropped out of the CI install line, four modules of coverage would
vanish without a red X. This guard turns that into a hard failure: it
only runs where ``CI`` is set (GitHub Actions always sets it) and
asserts the property-test dependency is importable there.
"""
import importlib.util
import os

import pytest

PROPERTY_TEST_MODULES = (
    "test_kernels", "test_quantizers", "test_core_srr", "test_paged_pool")


def test_hypothesis_installed_in_ci():
    if not os.environ.get("CI"):
        pytest.skip("dependency guard only enforced in CI")
    assert importlib.util.find_spec("hypothesis") is not None, (
        "hypothesis is not installed in the CI environment — the "
        f"property-test modules {PROPERTY_TEST_MODULES} would silently "
        "skip out of tier-1. Restore it in the workflow's install step.")
