"""The while-aware HLO cost analyzer against known-FLOPs programs."""
import subprocess
import sys

import pytest


def _run(code):
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_scan_flops_multiplied_by_trip_count():
    out = _run(r"""
import jax, jax.numpy as jnp
from repro.launch.hlo_cost import analyze_text
M, T = 128, 8
def f(x, ws):
    y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
    return y
c = jax.jit(f).lower(jax.ShapeDtypeStruct((M, M), jnp.float32),
                     jax.ShapeDtypeStruct((T, M, M), jnp.float32)).compile()
r = analyze_text(c.as_text())
expected = 2 * M * M * M * T
assert 0.95 * expected < r["flops"] < 1.1 * expected, (r["flops"], expected)
print("SCAN-OK", r["flops"])
""")
    assert "SCAN-OK" in out


def test_sharded_matmul_collectives_counted():
    out = _run(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze_text
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("model",))
M = 512
with mesh:
    jj = jax.jit(lambda a, b: a @ b,
                 in_shardings=(NamedSharding(mesh, P(None, "model")),
                               NamedSharding(mesh, P("model", None))),
                 out_shardings=NamedSharding(mesh, P()))
    c = jj.lower(jax.ShapeDtypeStruct((M, M), jnp.float32),
                 jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
r = analyze_text(c.as_text())
per_dev = 2 * M * M * (M // 4)
assert 0.95 * per_dev < r["flops"] < 1.1 * per_dev
assert r["coll_by_kind"]["all-reduce"] >= M * M * 4  # f32 result reduced
print("COLL-OK")
""")
    assert "COLL-OK" in out


def test_dus_accumulator_bytes_not_trip_inflated():
    """A scan that accumulates into a big carried buffer must charge
    per-iteration bytes ~slice-sized, not buffer-sized."""
    out = _run(r"""
import jax, jax.numpy as jnp
from repro.launch.hlo_cost import analyze_text
T, M = 64, 256
def f(ws):
    def body(c, i):
        c = jax.lax.dynamic_update_slice_in_dim(
            c, jnp.tanh(ws[i])[None], i, axis=0)
        return c, None
    out, _ = jax.lax.scan(body, jnp.zeros((T, M, M)), jnp.arange(T))
    return out
c = jax.jit(f).lower(jax.ShapeDtypeStruct((T, M, M), jnp.float32)).compile()
r = analyze_text(c.as_text())
buffer_bytes = T * M * M * 4
# naive accounting would charge ≥ T × buffer ≈ T²·M²·4; slice-aware stays
# within a few buffer passes
assert r["bytes"] < 8 * buffer_bytes, (r["bytes"], buffer_bytes)
print("DUS-OK", r["bytes"] / buffer_bytes)
""")
    assert "DUS-OK" in out
