"""Self-speculative decoding: token parity, rollback bookkeeping,
budget interplay, and logprob plumbing.

The acceptance bar for speculation is behavioral invisibility: with
``speculative=True`` the engine must emit token-for-token what plain
per-token decode emits, for every KV container × fused mode ×
paged/unpaged, greedy and sampled lanes alike. For the unquantized
model that parity is structural (the Q-only draft IS the target model;
the verify chunk is read-only over KV storage); for a quantized Q+LR
model the verify chunk upgrades the drafts' Q-only K/V entries to
full-model values and the parity check covers the heavy-rejection
regime too.
"""
import argparse

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, SamplingParams, ServeConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def qtiny():
    """SRR-quantized reduced model: params carry real low-rank slivers,
    so the Q-only draft diverges from the Q+LR target and speculative
    rounds exercise the rejection/rollback path heavily."""
    from repro.launch.serve import build_quantized_model
    args = argparse.Namespace(arch="phi3-mini-3.8b", seed=0,
                              method="srr", rank=8, bits=4)
    params, cfg = build_quantized_model(args, tag="test")
    return cfg, params


def _engine(cfg, params, **kw):
    defaults = dict(max_len=128, decode_batch=3, max_new_tokens=12,
                    prefill_len=16, scheduler="continuous")
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


def _reqs(cfg, n, base_len=5, params=None, budget=None):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=base_len + (i % 3))
                    .astype(np.int32),
                    max_new_tokens=budget[i] if budget else None,
                    params=params[i] if params else None)
            for i in range(n)]


def _same(a, b, msg=""):
    for ra, rb in zip(a, b):
        assert ra.uid == rb.uid
        np.testing.assert_array_equal(ra.tokens, rb.tokens, err_msg=msg)
        assert ra.finish_reason == rb.finish_reason, msg


def _spec_vs_plain(cfg, params, spec_k=4, nreq=4, reqs_kw=None, **kw):
    """Run the same workload spec-off and spec-on; return both result
    lists plus the speculative engine (for stats/pool inspection)."""
    reqs_kw = reqs_kw or {}
    plain = _engine(cfg, params, **kw).generate(_reqs(cfg, nreq, **reqs_kw))
    eng = _engine(cfg, params, speculative=True, spec_k=spec_k, **kw)
    spec = eng.generate(_reqs(cfg, nreq, **reqs_kw))
    return plain, spec, eng


# ---------------------------------------------------------------------------
# Token parity (the acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype", ["f32", "int4"])
@pytest.mark.parametrize("paged", [False, True])
def test_spec_parity_fp(tiny, kv_dtype, paged):
    """No low-rank slivers → the draft is the target model, the verify
    chunk stays read-only, and greedy parity is structural: identical
    tokens on any workload, not a lucky seed."""
    cfg, params = tiny
    kw = dict(kv_dtype=kv_dtype)
    if paged:
        kw.update(paged=True, page_size=8)
    plain, spec, eng = _spec_vs_plain(cfg, params, **kw)
    _same(plain, spec, f"spec diverged at kv={kv_dtype} paged={paged}")
    st = eng.stats()
    assert st["spec_rounds"] >= 1
    assert st["spec_accepted_tokens"] <= st["spec_draft_tokens"]


@pytest.mark.parametrize("kv_dtype", ["f32", "bf16", "int8", "int4"])
def test_spec_parity_quantized(qtiny, kv_dtype):
    """Q+LR model: the draft skips the LR sliver, so rejections (and the
    post-rejection plain-decode correction) dominate — parity must hold
    through the accept/reject/rewind machinery on every KV container."""
    cfg, params = qtiny
    plain, spec, eng = _spec_vs_plain(cfg, params, kv_dtype=kv_dtype,
                                      paged=True, page_size=8, nreq=3)
    _same(plain, spec, f"quantized spec diverged at kv={kv_dtype}")
    assert eng.stats()["spec_rounds"] >= 1


@pytest.mark.parametrize("fused", ["on", "off"])
def test_spec_parity_quantized_fused_modes(qtiny, fused):
    cfg, params = qtiny
    plain, spec, _ = _spec_vs_plain(cfg, params, kv_dtype="int4",
                                    paged=True, page_size=8, fused=fused,
                                    nreq=3)
    _same(plain, spec, f"quantized spec diverged at fused={fused}")


def test_spec_parity_unpaged_quantized(qtiny):
    cfg, params = qtiny
    plain, spec, _ = _spec_vs_plain(cfg, params, kv_dtype="int8", nreq=3)
    _same(plain, spec, "quantized spec diverged unpaged")


# ---------------------------------------------------------------------------
# Sampled lanes: per-token fallback
# ---------------------------------------------------------------------------
def test_spec_sampled_lanes_fall_back(tiny):
    """Temperature lanes decode per-token (counter-based draws are
    per-position), so an all-sampled batch runs zero speculative rounds
    and still matches the non-speculative engine exactly."""
    cfg, params = tiny
    sp = [SamplingParams(temperature=0.8, seed=7 + i) for i in range(3)]
    plain, spec, eng = _spec_vs_plain(cfg, params, nreq=3,
                                      reqs_kw=dict(params=sp))
    _same(plain, spec, "sampled lanes diverged under speculation")
    assert eng.stats()["spec_rounds"] == 0


def test_spec_mixed_batch_one_sampled_lane_blocks_round(tiny):
    """One sampled lane in the batch forces plain decode for everyone
    (a speculative round needs every decoding lane greedy) — outputs
    still match the non-speculative engine per request."""
    cfg, params = tiny
    sp = [None, SamplingParams(temperature=1.1, seed=3), None]
    plain, spec, eng = _spec_vs_plain(cfg, params, nreq=3,
                                      reqs_kw=dict(params=sp))
    _same(plain, spec, "mixed batch diverged under speculation")
    assert eng.stats()["spec_rounds"] == 0


# ---------------------------------------------------------------------------
# Stop tokens inside an accepted window
# ---------------------------------------------------------------------------
def test_spec_stop_token_in_accepted_chunk(tiny):
    """A stop token that lands inside the accepted draft window must
    truncate right there with finish_reason='stop' — tokens past it are
    never recorded, matching non-speculative retirement."""
    cfg, params = tiny
    probe = _engine(cfg, params).generate(_reqs(cfg, 1))
    stop = int(probe[0].tokens[3])
    cut = probe[0].tokens.tolist().index(stop)
    sp = [SamplingParams(stop=(stop,), max_new_tokens=12)]
    eng = _engine(cfg, params, speculative=True, spec_k=6)
    res = eng.generate(_reqs(cfg, 1, params=sp))
    assert res[0].finish_reason == "stop"
    assert res[0].tokens[-1] == stop
    assert len(res[0].tokens) == cut + 1
    np.testing.assert_array_equal(res[0].tokens,
                                  probe[0].tokens[:cut + 1])
    # spec_k=6 over a 12-token budget: the stop at index <= 3 sits in
    # the first accepted window, so the truncation really exercised
    # the speculative path
    assert eng.stats()["spec_rounds"] >= 1


# ---------------------------------------------------------------------------
# Paged rollback: refcount conservation
# ---------------------------------------------------------------------------
def _assert_pool_conserved(eng):
    pool = eng.pool
    assert pool.n_free + pool.n_cold + pool.n_hot == pool.n_pages
    refs = sum(pool.refcount(p) for p in range(pool.n_pages))
    # once every request retired, the only references left are the
    # parked per-lane placeholder pages
    assert refs == eng.sc.decode_batch, \
        f"leaked {refs - eng.sc.decode_batch} page refs"


def test_spec_refcounts_conserved_accept_path(tiny):
    """All-accept regime (fp model): rounds rewind positions without
    touching page ownership; two back-to-back runs leak nothing."""
    cfg, params = tiny
    eng = _engine(cfg, params, speculative=True, spec_k=4, paged=True,
                  page_size=8)
    eng.generate(_reqs(cfg, 5))
    _assert_pool_conserved(eng)
    eng.generate(_reqs(cfg, 5))
    _assert_pool_conserved(eng)


def test_spec_refcounts_conserved_reject_path(qtiny):
    """Heavy-rejection regime (quantized model): rejected tails rewind
    into pages the request already owns — no alloc/decref inside a
    round, so the pool balances exactly after retirement."""
    cfg, params = qtiny
    eng = _engine(cfg, params, speculative=True, spec_k=4, paged=True,
                  page_size=8)
    eng.generate(_reqs(cfg, 4))
    st = eng.stats()
    assert st["spec_rounds"] >= 1
    _assert_pool_conserved(eng)


def test_spec_refcounts_conserved_abort_mid_flight(tiny):
    """Aborting a lane between speculative rounds releases its pages;
    the remaining lanes finish and the pool balances."""
    cfg, params = tiny
    eng = _engine(cfg, params, speculative=True, spec_k=4, paged=True,
                  page_size=8, max_new_tokens=16)
    for r in _reqs(cfg, 4, budget={i: 16 for i in range(4)}):
        eng.submit(r)
    done = []
    for _ in range(2):
        done.extend(eng.step())
    assert eng.stats()["spec_rounds"] >= 1
    res = eng.abort(1)
    assert res is not None and res.finish_reason == "abort"
    done.append(res)
    done.extend(eng.drain())
    assert sorted(r.uid for r in done) == [0, 1, 2, 3]
    _assert_pool_conserved(eng)


# ---------------------------------------------------------------------------
# Token-budget interplay
# ---------------------------------------------------------------------------
def test_spec_respects_step_budget(tiny):
    """Draft and verify dispatches are charged against max_step_tokens.
    At the smallest legal budget (prefill width + 1 = 17) a full
    3-lane k=4 round costs 3 base + 9 draft + 12 verify = 24 > 17, so
    rounds can only ever run while ≤ 2 lanes are decoding (admission
    ramp-up / retirement tail) — and the output still matches the
    unbudgeted non-speculative engine exactly."""
    cfg, params = tiny
    base = dict(decode_batch=3, prefill_len=16)
    ref = _engine(cfg, params, **base).generate(_reqs(cfg, 3))

    tight = _engine(cfg, params, speculative=True, spec_k=4,
                    max_step_tokens=17, **base)
    res_t = tight.generate(_reqs(cfg, 3))
    _same(ref, res_t, "tight-budget spec diverged")
    st = tight.stats()
    # a round over n lanes drafts (k-1)·n tokens: with every round
    # capped at 2 lanes, draft tokens can't exceed 2(k-1) per round
    assert st["spec_draft_tokens"] <= 2 * 3 * st["spec_rounds"], \
        "a speculative round ran over the full batch despite the budget"

    roomy = _engine(cfg, params, speculative=True, spec_k=4,
                    max_step_tokens=64, **base)
    res_r = roomy.generate(_reqs(cfg, 3))
    _same(ref, res_r, "roomy-budget spec diverged")
    assert roomy.stats()["spec_rounds"] >= 1


# ---------------------------------------------------------------------------
# Logprobs through the speculative path
# ---------------------------------------------------------------------------
def test_spec_logprobs_cover_every_token(tiny):
    """logprobs-requesting lanes still get one record per emitted token
    when those tokens come out of accepted draft windows, and greedy
    records are self-consistent (chosen token tops its own top-list)."""
    cfg, params = tiny
    sp = [SamplingParams(logprobs=2) for _ in range(2)]
    eng = _engine(cfg, params, speculative=True, spec_k=4, decode_batch=2)
    infos = {}
    eng.on_token = lambda uid, tok, info: \
        infos.setdefault(uid, []).append((tok, info))
    res = eng.generate(_reqs(cfg, 2, params=sp))
    assert eng.stats()["spec_rounds"] >= 1
    for r in res:
        recs = infos[r.uid]
        assert len(recs) == len(r.tokens)
        for tok, info in recs:
            assert info is not None
            assert isinstance(info["logprob"], float)
            assert len(info["top_logprobs"]) == 2
            top_tok, top_lp = info["top_logprobs"][0]
            assert top_tok == tok          # greedy: argmax emitted
            assert abs(top_lp - info["logprob"]) < 1e-6
