"""End-to-end: serving engine behaviour + trainer resume + launch CLIs."""
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import batches, data_config_for
from repro.models import init_lm
from repro.optim import AdamW, cosine_schedule
from repro.serve import Engine, Request, ServeConfig
from repro.train import (
    CheckpointManager,
    StepConfig,
    Trainer,
    init_train_state,
    make_train_step,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def test_engine_generates_and_orders_results(tiny):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, decode_batch=4,
                                          max_new_tokens=6))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, size=5 + 3 * (i % 2)).astype(np.int32))
        for i in range(7)]
    res = eng.generate(reqs)
    assert [r.uid for r in res] == list(range(7))
    assert all(len(r.tokens) == 6 for r in res)


def test_engine_greedy_deterministic(tiny):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, decode_batch=2,
                                          max_new_tokens=5))
    req = [Request(uid=0, prompt=np.arange(6, dtype=np.int32))]
    a = eng.generate(req)[0].tokens
    b = eng.generate(req)[0].tokens
    np.testing.assert_array_equal(a, b)


def test_engine_respects_eos(tiny):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, decode_batch=2,
                                          max_new_tokens=32, eos_id=-1))
    req = [Request(uid=0, prompt=np.arange(6, dtype=np.int32))]
    out = eng.generate(req)[0]
    # find what the 3rd token is, then rerun with it as EOS
    eos = int(out.tokens[2])
    eng2 = Engine(params, cfg, ServeConfig(max_len=64, decode_batch=2,
                                           max_new_tokens=32, eos_id=eos))
    out2 = eng2.generate(req)[0]
    assert len(out2.tokens) <= 3 or int(out2.tokens[-1]) == eos


def test_trainer_kill_and_resume_bitexact(tiny):
    """Fault-tolerance contract: 10 straight steps ≡ 5 steps + restart + 5
    (deterministic data + checkpoint restore)."""
    cfg, params = tiny
    opt = AdamW(learning_rate=cosine_schedule(1e-3, 2, 10))
    dcfg = data_config_for(cfg, seq_len=16, global_batch=4)
    step = jax.jit(make_train_step(cfg, opt,
                                   StepConfig(compute_dtype=jnp.float32)))

    def fresh():
        return init_train_state(init_lm(jax.random.PRNGKey(0), cfg), opt)

    straight, _ = Trainer(step, lambda s: batches(dcfg, s),
                          log_fn=lambda *_: None).run(fresh(), 10)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        Trainer(step, lambda s: batches(dcfg, s), ckpt=mgr, ckpt_every=5,
                log_fn=lambda *_: None).run(fresh(), 5)
        resumed, _ = Trainer(step, lambda s: batches(dcfg, s), ckpt=mgr,
                             ckpt_every=5, log_fn=lambda *_: None
                             ).run(fresh(), 10)
    a = np.asarray(jax.tree_util.tree_leaves(straight.params)[0])
    b = np.asarray(jax.tree_util.tree_leaves(resumed.params)[0])
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_train_cli_full_and_qpeft():
    for mode in ("full", "qpeft"):
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--mode", mode,
             "--arch", "xlstm-125m", "--steps", "12", "--batch", "4",
             "--seq", "32", "--rank", "8"],
            capture_output=True, text=True, timeout=560,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu",
                 "HOME": "/root"}, cwd="/root/repo")
        assert r.returncode == 0, r.stderr[-2000:]
        assert "final loss" in r.stdout


def test_serve_cli_srr():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "minitron-4b", "--method", "srr", "--rank", "8",
         "--requests", "4", "--new-tokens", "4", "--kv", "int8"],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/root"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "quantized" in r.stdout and "requests" in r.stdout


def test_compressed_psum_subprocess():
    """int8 EF all-reduce over a 'pod' axis (needs >1 device ⇒ subprocess
    with forced host device count)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim import ef_compressed_psum, init_error_feedback
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("pod",))
g = jnp.stack([jnp.full((8,), float(i + 1)) for i in range(4)])  # per-pod
ef = jnp.zeros((4, 8))
def inner(gi, ei):
    s, e2 = ef_compressed_psum(gi[0], ei[0], axis="pod")
    return s[None], e2[None]
sync = shard_map(inner, mesh=mesh, in_specs=(P("pod"), P("pod")),
                 out_specs=(P("pod"), P("pod")), check_rep=False)
s, e2 = sync(g, ef)
# every pod sees the mean (= 2.5); EF residual bounded by one int8 step
np.testing.assert_allclose(np.asarray(s), 2.5, rtol=0.05)
# error feedback: second round with same grads drives residual down
s2, e3 = sync(g, e2)
assert float(jnp.mean(jnp.abs(np.asarray(s) + np.asarray(s2) - 5.0))) < 0.02
print("EF-PSUM-OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EF-PSUM-OK" in r.stdout


def test_dryrun_cli_smallest_cell():
    """The dry-run driver end-to-end on the cheapest (arch × shape)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--mesh", "single", "--out",
         tempfile.mkdtemp()],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/root"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "1 ok, 0 skip, 0 FAIL" in r.stdout
