"""Numerics tier for the flash-decode attention serving path.

Pins ``kernels.ops.decode_attention_op`` — both entries (Pallas kernel
in interpret mode on CPU CI, fused-XLA lowering) — to the dense-softmax
oracle in ``kernels/ref.py`` across KV dtypes (f32 / bf16 / int8 codes +
scales), ragged per-row slot maps, sliding window on/off, and GQA group
counts. On top: mode-parity for ``attention_step`` / ``mla_step`` (the
model-layer call sites) and engine-level token parity across
``fused=auto|on|off`` for every KV cache dtype.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import decode_attention_op
from repro.kernels.ref import decode_attention_ref
from repro.models.attention import (absorb_mla_weights, attention_step,
                                    attention_seq, decode_attention,
                                    init_attention, init_attn_cache,
                                    init_mla, init_mla_cache,
                                    mla_seq, mla_step)
from repro.models.linear import Ctx


def _case(key, b, kv, g, hd, s, ragged=True):
    q = jax.random.normal(key, (b, kv, g, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, hd))
    # per-row positions: co-batched rows at unrelated decode depths
    q_pos = jnp.asarray([s - 1 - (3 * i) % max(s // 2, 1) for i in range(b)],
                        jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    if ragged:  # empty slots mid-cache (continuous batching / ring wrap)
        k_pos = k_pos.at[0, s // 3: s // 3 + 2].set(-1)
        if b > 1:
            k_pos = k_pos.at[1, : s // 4].set(-1)
    return q, k, v, q_pos, k_pos


def _int8(k, v):
    amax = jnp.max(jnp.abs(k), axis=-1)
    ks = jnp.maximum(amax, 1e-8) / 127.0
    kc = jnp.clip(jnp.round(k / ks[..., None]), -127, 127).astype(jnp.int8)
    amax = jnp.max(jnp.abs(v), axis=-1)
    vs = jnp.maximum(amax, 1e-8) / 127.0
    vc = jnp.clip(jnp.round(v / vs[..., None]), -127, 127).astype(jnp.int8)
    return kc, ks, vc, vs


def _int4(k, v):
    """Packed4 containers: int4 codes two-per-byte along the slot axis
    of the head-major pages, per-(B, KV, S) scales."""
    from repro.quant.mxint import pack_codes_4bit

    def q4(x):
        amax = jnp.max(jnp.abs(x), axis=-1)
        sc = jnp.maximum(amax, 1e-8) / 7.0
        c = jnp.clip(jnp.round(x / sc[..., None]), -7, 7).astype(jnp.int8)
        return pack_codes_4bit(c), sc

    (kp, ks), (vp, vs) = q4(k), q4(v)
    return kp, ks, vp, vs


# ---------------------------------------------------------------------------
# decode_attention_op (Pallas interpret + fused-XLA) vs the jnp oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv,g", [(1, 1), (2, 4), (4, 2)])
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("kernel", [True, False])
def test_decode_op_matches_ref_float(kv, g, window, kernel):
    key = jax.random.PRNGKey(kv * 10 + g)
    q, k, v, q_pos, k_pos = _case(key, 3, kv, g, 32, 100)
    y = decode_attention_op(q, k, v, q_pos, k_pos, window=window,
                            kernel=kernel)
    ref = decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("kernel", [True, False])
def test_decode_op_kv_dtypes(dtype, kernel):
    key = jax.random.PRNGKey(7)
    q, k, v, q_pos, k_pos = _case(key, 2, 2, 2, 64, 96)
    y = decode_attention_op(q, k.astype(dtype), v.astype(dtype),
                            q_pos, k_pos, kernel=kernel)
    ref = decode_attention_ref(q, k.astype(dtype), v.astype(dtype),
                               q_pos, k_pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("kernel", [True, False])
def test_decode_op_int8_kv(window, kernel):
    key = jax.random.PRNGKey(11)
    q, k, v, q_pos, k_pos = _case(key, 3, 2, 4, 64, 130)  # S pads to block
    kc, ks, vc, vs = _int8(k, v)
    y = decode_attention_op(q, kc, vc, q_pos, k_pos, k_scale=ks, v_scale=vs,
                            window=window, kernel=kernel)
    ref = decode_attention_ref(q, kc, vc, q_pos, k_pos, k_scale=ks,
                               v_scale=vs, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("kernel", [True, False])
def test_decode_op_int4_packed_kv(window, kernel):
    """Packed4 pages (two slots per uint8 byte on the slot axis) must
    match the oracle through both op entries — the kernel unpacks
    nibbles in VMEM, the XLA path expands to int8 codes up front."""
    key = jax.random.PRNGKey(19)
    q, k, v, q_pos, k_pos = _case(key, 3, 2, 4, 64, 130)  # S pads to block
    kp, ks, vp, vs = _int4(k, v)
    assert kp.dtype == jnp.uint8 and kp.shape == (3, 2, 65, 64)
    y = decode_attention_op(q, kp, vp, q_pos, k_pos, k_scale=ks, v_scale=vs,
                            window=window, kernel=kernel)
    ref = decode_attention_ref(q, kp, vp, q_pos, k_pos, k_scale=ks,
                               v_scale=vs, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_op_int4_within_quant_tolerance_of_float():
    """The packed path is the real cache quantized to 4 bits: its output
    must sit within the int4 quantization error envelope of the float
    attention, not just match its own oracle."""
    key = jax.random.PRNGKey(23)
    q, k, v, q_pos, k_pos = _case(key, 2, 2, 2, 32, 64)
    kp, ks, vp, vs = _int4(k, v)
    exact = decode_attention_ref(q, k, v, q_pos, k_pos)
    y = decode_attention_op(q, kp, vp, q_pos, k_pos, k_scale=ks, v_scale=vs,
                            kernel=True)
    err = np.abs(np.asarray(y) - np.asarray(exact)).max()
    assert err < 0.25 * np.abs(np.asarray(exact)).max()


def test_decode_op_int4_unpack_matches_int8_codes():
    """Pack → op ≡ unpack → op: the packed container is purely a layout,
    never a second quantizer."""
    from repro.quant.mxint import unpack_codes_4bit
    key = jax.random.PRNGKey(29)
    q, k, v, q_pos, k_pos = _case(key, 2, 1, 2, 32, 96)
    kp, ks, vp, vs = _int4(k, v)
    for kernel in (True, False):
        y_packed = decode_attention_op(q, kp, vp, q_pos, k_pos, k_scale=ks,
                                       v_scale=vs, kernel=kernel)
        y_codes = decode_attention_op(q, unpack_codes_4bit(kp),
                                      unpack_codes_4bit(vp), q_pos, k_pos,
                                      k_scale=ks, v_scale=vs, kernel=kernel)
        np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_codes),
                                   rtol=1e-5, atol=1e-5)


def test_decode_op_custom_scale():
    """The MLA latent path scores in the latent dim but scales by the
    head dim — the op must honor an explicit scale."""
    key = jax.random.PRNGKey(13)
    q, k, v, q_pos, k_pos = _case(key, 2, 1, 4, 24, 40, ragged=False)
    for kernel in (True, False):
        y = decode_attention_op(q, k, v, q_pos, k_pos, scale=0.125,
                                kernel=kernel)
        ref = decode_attention_ref(q, k, v, q_pos, k_pos, scale=0.125)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_legacy_decode_attention_matches_ref():
    """The ``fused="off"`` head-major einsum lowering stays pinned too."""
    key = jax.random.PRNGKey(17)
    q, k, v, q_pos, k_pos = _case(key, 2, 2, 3, 32, 64)
    # q for the legacy entry is (B, 1, KV, G, hd)
    y = decode_attention(q[:, None], k, v, q_pos, k_pos)[:, 0]
    ref = decode_attention_ref(q, k, v, q_pos, k_pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Masking numerics: empty lanes and window-masked prefixes
# ---------------------------------------------------------------------------
def test_decode_op_empty_lane_emits_zeros():
    """Regression: a row with no valid slot used to leave the kernel's
    running max at NEG_INF, making p = exp(NEG_INF − NEG_INF) = 1 per
    masked column — an unweighted V-mean — while the XLA path emitted a
    uniform softmax. All three lowerings (kernel, fused-XLA, oracle) now
    agree on zeros."""
    key = jax.random.PRNGKey(31)
    q, k, v, q_pos, k_pos = _case(key, 3, 2, 2, 32, 96, ragged=False)
    k_pos = k_pos.at[1].set(-1)                 # row 1: fully-empty lane
    ref = decode_attention_ref(q, k, v, q_pos, k_pos)
    assert np.abs(np.asarray(ref)[1]).max() == 0.0
    for kernel in (True, False):
        y = np.asarray(decode_attention_op(q, k, v, q_pos, k_pos,
                                           kernel=kernel))
        assert np.abs(y[1]).max() == 0.0, f"kernel={kernel}"
        # the non-empty rows stay pinned to the oracle
        np.testing.assert_allclose(y, np.asarray(ref), rtol=2e-4, atol=2e-4)
    # multi-block grid: the empty lane must stay zero across S steps
    from repro.kernels.decode_attention import flash_decode_bkgd
    y = np.asarray(flash_decode_bkgd(q, k, v, q_pos, k_pos, bs=32,
                                     interpret=True))
    assert np.abs(y[1]).max() == 0.0
    np.testing.assert_allclose(y, np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_legacy_decode_attention_empty_lane_emits_zeros():
    """The fused="off" einsum lowering agrees on the empty-lane
    semantics (retired continuous-batching slots ride along masked)."""
    key = jax.random.PRNGKey(37)
    q, k, v, q_pos, k_pos = _case(key, 2, 2, 2, 32, 64, ragged=False)
    k_pos = k_pos.at[0].set(-1)
    y = np.asarray(decode_attention(q[:, None], k, v, q_pos, k_pos)[:, 0])
    assert np.abs(y[0]).max() == 0.0
    ref = decode_attention_ref(q, k, v, q_pos, k_pos)
    np.testing.assert_allclose(y, np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_decode_kernel_window_masked_prefix_blocks():
    """A sliding window that masks *entire leading sequence blocks* (the
    shape where the old p = 1 pollution entered l/acc before the running
    max turned finite) stays pinned to the oracle. bs=32 over S=128
    forces 4 grid steps with the first 3 fully window-masked."""
    from repro.kernels.decode_attention import flash_decode_bkgd
    key = jax.random.PRNGKey(41)
    s, window = 128, 16
    q, k, v, q_pos, k_pos = _case(key, 2, 2, 2, 32, s, ragged=False)
    q_pos = jnp.full((2,), s - 1, jnp.int32)     # slots 0..111 all outside
    y = flash_decode_bkgd(q, k, v, q_pos, k_pos, window=window, bs=32,
                          interpret=True)
    ref = decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    # the dispatcher entries (single-block here) agree too
    for kernel in (True, False):
        y2 = decode_attention_op(q, k, v, q_pos, k_pos, window=window,
                                 kernel=kernel)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_flash_decode_rejects_unaligned_block():
    """Regression: flash_decode_bkgd used to compute n_s = S // bs and
    silently drop the tail slots when S % bs != 0 — now a ValueError."""
    from repro.kernels.decode_attention import flash_decode_bkgd
    key = jax.random.PRNGKey(43)
    q, k, v, q_pos, k_pos = _case(key, 2, 1, 2, 32, 48, ragged=False)
    with pytest.raises(ValueError, match="not a multiple"):
        flash_decode_bkgd(q, k, v, q_pos, k_pos, bs=32, interpret=True)
    # aligned call still works (the dispatcher pads before calling)
    y = flash_decode_bkgd(q, k[:, :, :32], v[:, :, :32], q_pos,
                          k_pos[:, :32], bs=32, interpret=True)
    ref = decode_attention_ref(q, k[:, :, :32], v[:, :, :32], q_pos,
                               k_pos[:, :32])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# attention_step mode parity (GQA + sliding-window, every KV dtype)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype",
                         [jnp.float32, jnp.bfloat16, jnp.int8, "int4"])
@pytest.mark.parametrize("local", [False, True])
def test_attention_step_mode_parity(kv_dtype, local):
    from repro.configs import get_config
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.3
    xt = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model)) * 0.3

    outs = {}
    for mode in ("off", "auto", "on"):
        ctx = Ctx(fused=mode)
        cache = init_attn_cache(cfg, 2, 24, local, kv_dtype)
        _, cache = attention_seq(ctx, params, x, cfg, local=local,
                                 cache=cache,
                                 lengths=jnp.asarray([12, 7], jnp.int32))
        y, cache = attention_step(ctx, params, xt, cache, cfg, local=local)
        y2, _ = attention_step(ctx, params, xt, cache, cfg, local=local)
        outs[mode] = (np.asarray(y), np.asarray(y2))
    for mode in ("auto", "on"):
        for a, b in zip(outs["off"], outs[mode]):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4,
                                       err_msg=f"mode={mode}")


def test_attention_step_int4_within_quant_tolerance():
    """The int4 cache's step output tracks the f32 cache within the 4-bit
    quantization envelope (≲ amax/7 per element ⇒ low-% relative error),
    and the packed pages really halve the int8 cache's K/V bytes."""
    from repro.configs import get_config
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.3
    xt = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model)) * 0.3
    outs, caches = {}, {}
    for dt in (jnp.float32, jnp.int8, "int4"):
        ctx = Ctx(fused="auto")
        cache = init_attn_cache(cfg, 2, 24, False, dt)
        _, cache = attention_seq(ctx, params, x, cfg, cache=cache,
                                 lengths=jnp.asarray([12, 7], jnp.int32))
        y, cache = attention_step(ctx, params, xt, cache, cfg)
        outs[dt], caches[dt] = np.asarray(y), cache
    ref = np.abs(outs[jnp.float32]).max()
    assert np.abs(outs["int4"] - outs[jnp.float32]).max() < 0.2 * ref
    # int8 stays the tighter approximation
    assert (np.abs(outs[jnp.int8] - outs[jnp.float32]).max()
            < np.abs(outs["int4"] - outs[jnp.float32]).max())
    kv_bytes = lambda c: (c["k"].size * c["k"].dtype.itemsize  # noqa: E731
                          + c["v"].size * c["v"].dtype.itemsize)
    assert kv_bytes(caches["int4"]) * 2 == kv_bytes(caches[jnp.int8])


# ---------------------------------------------------------------------------
# MLA: latent-path parity + absorbed-weight cache
# ---------------------------------------------------------------------------
def _mla_case():
    from repro.configs import get_config
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    params = init_mla(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.3
    xt = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.d_model)) * 0.3
    return cfg, params, x, xt


def test_mla_step_mode_parity():
    cfg, params, x, xt = _mla_case()
    outs = {}
    for mode in ("off", "auto", "on"):
        ctx = Ctx(fused=mode)
        cache = init_mla_cache(cfg, 2, 16)
        _, cache = mla_seq(ctx, params, x, cfg, cache=cache)
        y, _ = mla_step(ctx, params, xt, cache, cfg)
        outs[mode] = np.asarray(y)
    np.testing.assert_allclose(outs["off"], outs["auto"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["off"], outs["on"], rtol=2e-4, atol=2e-4)


def test_mla_absorbed_weights_parity():
    """Pre-absorbed dense up-projections ≡ per-step weight_of
    materialization, for fp and quantized (Q+LR) mixers."""
    from repro.core.api import PTQConfig
    from repro.models.quantize import quantize_model_params
    from repro.quant.base import QuantizerConfig

    cfg, params, x, xt = _mla_case()
    ptq = PTQConfig(method="srr", scaling="identity", rank=4,
                    quantizer=QuantizerConfig(kind="mxint", bits=3,
                                              block_size=32))
    qparams, _ = quantize_model_params(params, None, ptq)
    for p in (params, qparams):
        absorbed = absorb_mla_weights(p)
        assert "w_uk_dense" in absorbed and "w_uv_dense" in absorbed
        ctx = Ctx(fused="auto")
        cache = init_mla_cache(cfg, 2, 16)
        _, cache = mla_seq(ctx, p, x, cfg, cache=cache)
        y_plain, _ = mla_step(ctx, p, xt, dict(cache), cfg)
        y_abs, _ = mla_step(ctx, absorbed, xt, dict(cache), cfg)
        np.testing.assert_allclose(np.asarray(y_plain), np.asarray(y_abs),
                                   rtol=1e-5, atol=1e-5)


def test_engine_absorb_cache_identity():
    """absorbed_params is identity-cached per params tree: two engines
    over the same quantized model share one absorption."""
    from repro.serve.engine import absorbed_params
    cfg, params, _, _ = _mla_case()
    a = absorbed_params(params)
    b = absorbed_params(params)
    assert a is b
    assert a["w_uk_dense"] is b["w_uk_dense"]


# ---------------------------------------------------------------------------
# Engine-level token parity across fused modes × KV dtypes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype", ["f32", "bf16", "int8", "int4"])
def test_engine_fused_token_parity_kv_dtypes(kv_dtype):
    from repro.configs import get_config
    from repro.core.api import PTQConfig
    from repro.models import init_lm
    from repro.models.quantize import quantize_model_params
    from repro.quant.base import QuantizerConfig
    from repro.serve import Engine, Request, ServeConfig

    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ptq = PTQConfig(method="srr", scaling="identity", rank=8,
                    quantizer=QuantizerConfig(kind="mxint", bits=3,
                                              block_size=32))
    qparams, _ = quantize_model_params(params, None, ptq)

    def reqs():
        rng = np.random.default_rng(0)
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, size=5 + 3 * i)
                        .astype(np.int32), max_new_tokens=4)
                for i in range(3)]

    outs = {}
    for mode in ("off", "auto", "on"):
        sc = ServeConfig(max_len=48, decode_batch=2, max_new_tokens=4,
                         prefill_len=16, kv_dtype=kv_dtype, fused=mode)
        eng = Engine(qparams, cfg, sc)
        outs[mode] = eng.generate(reqs())
    for mode in ("auto", "on"):
        for a, b in zip(outs["off"], outs[mode]):
            assert a.uid == b.uid
            np.testing.assert_array_equal(
                a.tokens, b.tokens,
                err_msg=f"kv={kv_dtype} fused={mode} diverged from off")
