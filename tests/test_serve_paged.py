"""Paged KV cache serving: block tables, prefix reuse, chunked prefill.

Acceptance-criteria coverage for PR 5: token-identical outputs paged vs
unpaged across KV dtypes × fused modes, chunked prefill beyond the
compiled chunk shape, prefix-cache reuse that provably skips prefill
work, and eviction under pool pressure — plus op-level paged
kernel/XLA-vs-oracle checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n, base_len=5, budget=None, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=base_len + (i % 3))
                    .astype(np.int32),
                    max_new_tokens=budget[i] if budget else None)
            for i in range(n)]


def _engine(cfg, params, **kw):
    defaults = dict(max_len=64, decode_batch=3, max_new_tokens=6,
                    prefill_len=16, scheduler="continuous")
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


def _same_tokens(a, b, msg=""):
    for ra, rb in zip(a, b):
        assert ra.uid == rb.uid
        np.testing.assert_array_equal(ra.tokens, rb.tokens, err_msg=msg)


# ---------------------------------------------------------------------------
# Token parity: paged vs unpaged (acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype", ["f32", "bf16", "int8", "int4"])
def test_paged_matches_unpaged_greedy(tiny, kv_dtype):
    """Paging the cache must be behaviorally invisible: identical greedy
    tokens for every KV container, with slot reuse (more requests than
    slots) in the mix."""
    cfg, params = tiny
    budget = {i: 3 + (i % 4) for i in range(6)}
    res_u = _engine(cfg, params, kv_dtype=kv_dtype).generate(
        _reqs(cfg, 6, budget=budget))
    res_p = _engine(cfg, params, kv_dtype=kv_dtype, paged=True,
                    page_size=8).generate(_reqs(cfg, 6, budget=budget))
    _same_tokens(res_u, res_p, f"paged diverged at kv={kv_dtype}")


@pytest.mark.parametrize("fused", ["off", "auto", "on"])
def test_paged_fused_mode_parity_int4(tiny, fused):
    """The paged block-table read must agree across the legacy
    dequantize path, the fused-XLA gather lowering, and the
    scalar-prefetch Pallas kernel — on the packed4 container, whose
    paged writes exercise both the byte-pair chunk scatter and the
    single-nibble decode RMW."""
    cfg, params = tiny
    res_u = _engine(cfg, params, kv_dtype="int4", fused=fused).generate(
        _reqs(cfg, 4))
    res_p = _engine(cfg, params, kv_dtype="int4", fused=fused, paged=True,
                    page_size=8).generate(_reqs(cfg, 4))
    _same_tokens(res_u, res_p, f"paged int4 diverged at fused={fused}")


def test_paged_streaming_submit_step_drain(tiny):
    """Late submissions join mid-flight; streaming matches generate()."""
    cfg, params = tiny
    eng = _engine(cfg, params, decode_batch=2, paged=True, page_size=8)
    reqs = _reqs(cfg, 4)
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    done = []
    for _ in range(3):
        done.extend(eng.step())
    eng.submit(reqs[2])
    eng.submit(reqs[3])
    done.extend(eng.drain())
    done.sort(key=lambda r: r.uid)
    assert [r.uid for r in done] == [0, 1, 2, 3]
    assert all(len(r.tokens) == 6 for r in done)
    res_u = _engine(cfg, params, decode_batch=2).generate(_reqs(cfg, 4))
    _same_tokens(res_u, done)


# ---------------------------------------------------------------------------
# Chunked prefill (prompt > prefill_len)
# ---------------------------------------------------------------------------
def test_chunked_prefill_long_prompt_exact(tiny):
    """A 40-token prompt through a 16-wide chunk shape: three chunks,
    tokens identical to the bucketed scheduler's native-length prefill
    (the unpaged continuous engine rejects this prompt outright). f32
    KV: chunked attention over stored context is then mathematically
    exact, so cross-scheduler greedy identity is a hard guarantee."""
    cfg, params = tiny
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=40).astype(np.int32)
    mk = lambda: [Request(uid=0, prompt=prompt.copy(),  # noqa: E731
                          max_new_tokens=6)]
    kw = dict(max_len=96, decode_batch=2, prefill_len=16, kv_dtype="f32")
    with pytest.raises(ValueError, match="prefill"):
        _engine(cfg, params, **kw).submit(mk()[0])
    res_b = _engine(cfg, params, scheduler="bucketed", **kw).generate(mk())
    eng = _engine(cfg, params, paged=True, page_size=8, **kw)
    res_p = eng.generate(mk())
    _same_tokens(res_b, res_p, "chunked prefill diverged from bucketed")
    st = eng.stats()
    assert st["prefill_chunks"] == 3          # ceil(40 / 16)
    assert st["prefill_tokens_computed"] == 40


@pytest.mark.parametrize("kv_dtype", ["f32", "int4"])
def test_chunk_overhang_pad_writes_dropped(tiny, kv_dtype):
    """Regression: when the final chunk overhangs the block table
    (start + chunk_len > n_blocks·page_size), its pad-lane writes used
    to clamp into the row's last block and collide with valid prompt
    slots — an unordered duplicate-index scatter that let pad garbage
    replace real KV. Pad lanes must be dropped: max_len=24, page=8,
    chunk=16, 20-token prompt (final chunk spans [16, 32) over a
    24-slot table) has to reproduce the bucketed tokens exactly (f32)
    and the int4 byte-pair path likewise must not corrupt."""
    cfg, params = tiny
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab, size=20).astype(np.int32)
    mk = lambda: [Request(uid=0, prompt=prompt.copy(),  # noqa: E731
                          max_new_tokens=3)]
    kw = dict(decode_batch=2, prefill_len=16, kv_dtype=kv_dtype)
    res_over = _engine(cfg, params, paged=True, page_size=8, max_len=24,
                       **kw).generate(mk())
    if kv_dtype == "f32":
        # exact math: the bucketed native-length prefill is the oracle
        ref = _engine(cfg, params, scheduler="bucketed", max_len=24,
                      **kw).generate(mk())
    else:
        # quantized chunked context reads legitimately differ from the
        # bucketed exact prefill; the corruption-isolating oracle is the
        # same paged pipeline on a table the final chunk does NOT
        # overhang (max_len 32 ⇒ 4 blocks ⊇ chunk [16, 32))
        ref = _engine(cfg, params, paged=True, page_size=8, max_len=32,
                      **kw).generate(mk())
    _same_tokens(ref, res_over, f"overhang pad writes corrupted kv={kv_dtype}")


@pytest.mark.parametrize("kv_dtype", ["int8", "int4"])
def test_chunked_prefill_long_prompt_fused_parity(tiny, kv_dtype):
    """Quantized KV + chunked prefill: later chunks legitimately read
    *stored* (quantized) context where a one-shot prefill reads exact
    activations, so cross-scheduler greedy identity is not guaranteed at
    4 bits. The hard criterion is self-parity: the three attention
    lowerings (legacy dequant, fused-XLA gather, Pallas paged kernel)
    run the same quantization pipeline and must emit identical tokens —
    and the chunk accounting must show the prompt streamed in chunks."""
    cfg, params = tiny
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=40).astype(np.int32)
    mk = lambda: [Request(uid=0, prompt=prompt.copy(),  # noqa: E731
                          max_new_tokens=6)]
    kw = dict(max_len=96, decode_batch=2, prefill_len=16, kv_dtype=kv_dtype,
              paged=True, page_size=8)
    outs = {}
    for fused in ("off", "auto", "on"):
        eng = _engine(cfg, params, fused=fused, **kw)
        outs[fused] = eng.generate(mk())
        assert eng.stats()["prefill_chunks"] == 3
    _same_tokens(outs["off"], outs["auto"],
                 f"kv={kv_dtype} fused=auto diverged from off")
    _same_tokens(outs["off"], outs["on"],
                 f"kv={kv_dtype} fused=on diverged from off")


def test_chunked_prefill_interleaves_with_decode(tiny):
    """A long prompt admitted mid-flight advances one chunk per engine
    step while the resident request keeps decoding — and neither
    request's tokens change vs. serial execution."""
    cfg, params = tiny
    rng = np.random.default_rng(8)
    long_prompt = rng.integers(0, cfg.vocab, size=40).astype(np.int32)
    short = _reqs(cfg, 1, base_len=6)[0]
    short.max_new_tokens = 10

    kw = dict(max_len=96, decode_batch=2, prefill_len=16, kv_dtype="f32")
    eng = _engine(cfg, params, paged=True, page_size=8, **kw)
    eng.submit(short)
    for _ in range(2):
        eng.step()                     # short is decoding
    eng.submit(Request(uid=1, prompt=long_prompt.copy(), max_new_tokens=4))
    done = eng.drain()
    done.sort(key=lambda r: r.uid)
    assert [len(r.tokens) for r in done] == [10, 4]

    # serial references: each request alone produces the same tokens
    ref_s = _engine(cfg, params, paged=True, page_size=8, **kw).generate(
        [Request(uid=0, prompt=short.prompt, max_new_tokens=10)])
    ref_l = _engine(cfg, params, paged=True, page_size=8, **kw).generate(
        [Request(uid=1, prompt=long_prompt.copy(), max_new_tokens=4)])
    np.testing.assert_array_equal(done[0].tokens, ref_s[0].tokens)
    np.testing.assert_array_equal(done[1].tokens, ref_l[0].tokens)


# ---------------------------------------------------------------------------
# Prefix cache
# ---------------------------------------------------------------------------
def test_prefix_reuse_skips_work_and_preserves_tokens(tiny):
    """Shared system prompt: later requests map the donor's pages in
    (hit rate > 0, computed prefill tokens drop) and greedy outputs are
    identical to the same engine with reuse disabled."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    sys_p = rng.integers(0, cfg.vocab, size=24).astype(np.int32)

    def mk():
        r = np.random.default_rng(4)
        return [Request(uid=i, prompt=np.concatenate(
            [sys_p, r.integers(0, cfg.vocab, size=6).astype(np.int32)]),
            max_new_tokens=4) for i in range(5)]

    kw = dict(max_len=96, decode_batch=2, prefill_len=16, kv_dtype="f32",
              paged=True, page_size=8)
    eng = _engine(cfg, params, **kw)
    res = eng.generate(mk())
    eng_no = _engine(cfg, params, prefix_cache=False, **kw)
    res_no = eng_no.generate(mk())
    _same_tokens(res, res_no, "prefix reuse changed outputs")

    st, st_no = eng.stats(), eng_no.stats()
    assert st["prefix_hit_blocks"] > 0
    assert st["prefix_hit_rate"] > 0
    assert (st["prefill_tokens_computed"]
            < st_no["prefill_tokens_computed"])
    assert st_no["prefill_tokens_computed"] == st_no["prompt_tokens_total"]


def test_prefix_cache_warm_across_generate_calls(tiny):
    """The radix tree persists across generate() runs: a repeat of the
    same workload prefills almost nothing and still emits the same
    tokens (greedy determinism criterion, paged flavor)."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, page_size=8, kv_dtype="f32",
                  max_len=96, prefill_len=16)
    reqs = lambda: _reqs(cfg, 3, base_len=17)  # noqa: E731  (2 full blocks)
    a = eng.generate(reqs())
    cold = eng.stats()["prefill_tokens_computed"]
    b = eng.generate(reqs())
    warm = eng.stats()["prefill_tokens_computed"]
    _same_tokens(a, b, "warm prefix cache changed outputs")
    assert warm < cold


def test_eviction_under_pool_pressure(tiny):
    """A pool too small to retain every retired prompt must evict
    (stats count it) and still produce exactly the big-pool tokens."""
    cfg, params = tiny
    budget = {i: 6 for i in range(6)}
    reqs = lambda: _reqs(cfg, 6, base_len=24, budget=budget, seed=5)  # noqa: E731
    kw = dict(max_len=64, decode_batch=2, prefill_len=16, kv_dtype="f32",
              paged=True, page_size=8)
    big = _engine(cfg, params, **kw).generate(reqs())
    eng = _engine(cfg, params, n_pages=12, **kw)   # nb=8 + 2 parked + 2
    res = eng.generate(reqs())
    _same_tokens(big, res, "eviction changed outputs")
    assert eng.stats()["evictions"] > 0


def test_pool_exhaustion_defers_admission(tiny):
    """With pages for only one resident request, the second request
    waits for the first to retire instead of deadlocking or corrupting;
    everything completes with the bucketed scheduler's tokens."""
    cfg, params = tiny
    budget = {0: 4, 1: 4}
    # 30/31-token prompts need 5 blocks each; 10 pages − 2 parked = 8
    # free, so only one request fits at a time
    reqs = lambda: _reqs(cfg, 2, base_len=30, budget=budget, seed=6)  # noqa: E731
    kw = dict(max_len=64, decode_batch=2, prefill_len=16, kv_dtype="f32")
    eng = _engine(cfg, params, paged=True, page_size=8, n_pages=10,
                  prefix_cache=False, **kw)
    res = eng.generate(reqs())
    assert [len(r.tokens) for r in res] == [4, 4]
    assert eng.stats()["occupancy"] <= 0.75  # the lanes never ran together
    res_b = _engine(cfg, params, scheduler="bucketed", **kw).generate(reqs())
    _same_tokens(res_b, res)


# ---------------------------------------------------------------------------
# Guards + op-level paged parity
# ---------------------------------------------------------------------------
def test_paged_rejects_unsupported_arch():
    """Recurrent / local-window stacks have no block-sharing story."""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = init_lm(jax.random.PRNGKey(1), cfg)
    with pytest.raises(ValueError, match="paged"):
        _engine(cfg, params, paged=True)


def test_paged_needs_continuous_scheduler(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="continuous"):
        _engine(cfg, params, paged=True, scheduler="bucketed")


@pytest.mark.parametrize("container", ["f32", "int8", "int4"])
@pytest.mark.parametrize("kernel", [False, True])
def test_paged_op_matches_oracle(container, kernel):
    """decode_attention_op(block_table=...) — both the XLA gather
    lowering and the scalar-prefetch Pallas kernel — against the paged
    oracle, on a shuffled block table with ragged row positions."""
    from repro.kernels.ops import decode_attention_op
    from repro.kernels.ref import decode_attention_ref
    from repro.quant.mxint import pack_codes_4bit

    rng = np.random.default_rng(11)
    b, kv, g, hd, ps, nb, pages = 3, 2, 2, 16, 8, 4, 14
    q = jnp.asarray(rng.normal(size=(b, kv, g, hd)), jnp.float32)
    q_pos = jnp.asarray([3, 17, 31], jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(nb * ps)[None],
                             (b, nb * ps)).astype(jnp.int32)
    bt = jnp.asarray(rng.permutation(pages)[:b * nb].reshape(b, nb),
                     jnp.int32)
    ks = vs = None
    if container == "f32":
        k = jnp.asarray(rng.normal(size=(pages, kv, ps, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(pages, kv, ps, hd)), jnp.float32)
    else:
        hi = 128 if container == "int8" else 8
        kc = rng.integers(-hi + 1, hi, size=(pages, kv, ps, hd))
        vc = rng.integers(-hi + 1, hi, size=(pages, kv, ps, hd))
        ks = jnp.asarray(rng.uniform(0.01, 0.1, size=(pages, kv, ps)),
                         jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.1, size=(pages, kv, ps)),
                         jnp.float32)
        k = jnp.asarray(kc, jnp.int8)
        v = jnp.asarray(vc, jnp.int8)
        if container == "int4":
            k, v = pack_codes_4bit(k), pack_codes_4bit(v)
    ref = decode_attention_ref(q, k, v, q_pos, k_pos, ks, vs,
                               block_table=bt)
    out = decode_attention_op(q, k, v, q_pos, k_pos, k_scale=ks, v_scale=vs,
                              kernel=kernel, block_table=bt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_hbm_smaller_than_slot_rows(tiny):
    """The sized-down pool undercuts the contiguous slot cache: the
    structural memory win paging exists for."""
    from repro.serve import PagedKVCache, SlotKVCache
    cfg, _ = tiny
    dense = SlotKVCache(cfg, 8, 512, "int8")
    # typical mix: half the lanes short-lived — pool sized well under
    # full residency (8 lanes × 64 blocks) still serves the workload
    paged = PagedKVCache(cfg, 8, 512, "int8", page_size=8, n_pages=300)
    assert paged.hbm_bytes() < dense.hbm_bytes()
