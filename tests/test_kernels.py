"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; CI installs it
from hypothesis import given, settings, strategies as st

from repro.kernels import mxint_lowrank_matmul, mxint_quantize
from repro.kernels.ref import (
    mxint_dequant_ref,
    mxint_lowrank_matmul_ref,
    mxint_quantize_ref,
)
from repro.quant import MXIntQuantizer


def _quant(w, bits=3):
    packed = MXIntQuantizer(bits=bits, block_size=32).quantize(w)
    return packed.codes, jnp.exp2(packed.exponents.astype(jnp.float32))


# ---------------------------------------------------------------------------
# mxint_lowrank_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,r", [
    (8, 256, 128, 16),      # tall-skinny activations
    (130, 512, 384, 64),    # ragged M (pads to block)
    (1, 1024, 256, 0),      # decode row, rank-0 adapter
    (64, 96, 64, 8),        # K smaller than default bk
    (256, 128, 640, 32),    # wide N
])
def test_matmul_kernel_matches_ref(m, k, n, r):
    key = jax.random.PRNGKey(m * 31 + k * 7 + n + r)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    codes, scale = _quant(w)
    l = (jax.random.normal(jax.random.fold_in(key, 2), (k, r))
         if r else jnp.zeros((k, 0)))
    rr = (jax.random.normal(jax.random.fold_in(key, 3), (r, n))
          if r else jnp.zeros((0, n)))
    y = mxint_lowrank_matmul(x, codes, scale, l, rr)
    yref = mxint_lowrank_matmul_ref(x, codes, scale, l, rr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 256)).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (256, 128))
    codes, scale = _quant(w)
    l = jax.random.normal(jax.random.fold_in(key, 2), (256, 8))
    rr = jax.random.normal(jax.random.fold_in(key, 3), (8, 128))
    y = mxint_lowrank_matmul(x, codes, scale, l, rr)
    assert y.dtype == dtype
    yref = mxint_lowrank_matmul_ref(x.astype(jnp.float32), codes, scale, l, rr)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yref),
                               rtol=2e-2, atol=2e-1)


def test_matmul_kernel_3d_input():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 5, 512))
    w = jax.random.normal(jax.random.fold_in(key, 1), (512, 256))
    codes, scale = _quant(w)
    l = jax.random.normal(key, (512, 16))
    rr = jax.random.normal(key, (16, 256))
    y = mxint_lowrank_matmul(x, codes, scale, l, rr)
    assert y.shape == (2, 5, 256)
    yref = mxint_lowrank_matmul_ref(x, codes, scale, l, rr)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-3)


def test_matmul_block_shape_sweep():
    """Tiling must not change results."""
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (64, 512))
    w = jax.random.normal(jax.random.fold_in(key, 1), (512, 256))
    codes, scale = _quant(w)
    l = jnp.zeros((512, 0))
    rr = jnp.zeros((0, 256))
    ys = [mxint_lowrank_matmul(x, codes, scale, l, rr, bm=bm, bn=bn, bk=bk)
          for bm, bn, bk in [(32, 64, 128), (64, 128, 256), (128, 256, 512)]]
    for y in ys[1:]:
        np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(y),
                                   rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# mxint_quantize
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("m,n", [(256, 256), (512, 384), (96, 130), (32, 8)])
def test_quantize_kernel_matches_ref(bits, m, n):
    w = jax.random.normal(jax.random.PRNGKey(m + n + bits), (m, n)) * 2.0
    ck, ek = mxint_quantize(w, bits=bits)
    cr, er = mxint_quantize_ref(w, bits=bits)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(ek), np.asarray(er))


def test_quantize_kernel_matches_quantizer_class():
    w = jax.random.normal(jax.random.PRNGKey(9), (128, 96))
    ck, ek = mxint_quantize(w, bits=3)
    packed = MXIntQuantizer(bits=3, block_size=32).quantize(w)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(packed.codes))
    np.testing.assert_array_equal(np.asarray(ek),
                                  np.asarray(packed.exponents))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4]),
       st.sampled_from([(32, 32), (64, 48), (96, 64)]))
def test_quantize_roundtrip_property(seed, bits, shape):
    """Property: kernel quantize → dequant error ≤ half step everywhere."""
    m, n = shape
    w = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) * 3.0
    codes, exps = mxint_quantize(w, bits=bits)
    deq = mxint_dequant_ref(codes, jnp.exp2(exps.astype(jnp.float32)))
    step = jnp.repeat(jnp.exp2(exps.astype(jnp.float32)), 32, axis=0)
    assert bool(jnp.all(jnp.abs(w - deq) <= step * 0.5 + 1e-7))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,sq,sk,kv,g,hd,causal,window", [
    (2, 128, 128, 2, 2, 64, True, 0),
    (1, 300, 300, 4, 1, 128, True, 0),     # ragged S (pads)
    (2, 64, 256, 2, 4, 64, False, 0),      # cross-attention shape
    (1, 256, 256, 1, 8, 64, True, 64),     # sliding window
])
def test_flash_attention_matches_ref(b, sq, sk, kv, g, hd, causal, window):
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref
    key = jax.random.PRNGKey(sq + sk + kv)
    q = jax.random.normal(key, (b, sq, kv, g, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, sk, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, sk, kv, hd))
    qp, kp = jnp.arange(sq), jnp.arange(sk)
    out = flash_attention(q, k, v, qp, kp, causal=causal, window=window)
    kb = jnp.broadcast_to(k[:, :, :, None, :], (b, sk, kv, g, hd))
    vb = jnp.broadcast_to(v[:, :, :, None, :], (b, sk, kv, g, hd))
    ref = flash_attention_ref(
        q.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, sq, hd),
        kb.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, sk, hd),
        vb.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, sk, hd),
        qp, kp, causal=causal, window=window)
    ref = ref.reshape(b, kv, g, sq, hd).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_flash_attention_matches_blockwise():
    """Kernel semantics == the model zoo's XLA attention."""
    from repro.kernels.ops import flash_attention
    from repro.models.attention import blockwise_attention
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 96, 2, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 96, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 96, 2, 32))
    qp = jnp.arange(96)
    bw = blockwise_attention(q, k, v, qp, qp, causal=True)
    fl = flash_attention(q, k, v, qp, qp, causal=True)
    np.testing.assert_allclose(np.asarray(bw), np.asarray(fl),
                               rtol=1e-4, atol=1e-5)


def test_flash_attention_serving_path():
    """ctx.use_pallas routes prefill through the kernel; logits match."""
    from repro.configs import get_config
    from repro.models import Ctx, init_lm
    from repro.models.transformer import init_cache, prefill
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab}
    l_x, _ = prefill(Ctx(), params, batch, cfg, init_cache(cfg, 2, 32))
    l_p, _ = prefill(Ctx(use_pallas=True), params, batch, cfg,
                     init_cache(cfg, 2, 32))
    np.testing.assert_allclose(np.asarray(l_x), np.asarray(l_p),
                               rtol=1e-3, atol=1e-4)
