"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.models import Ctx, decode_step, init_lm, lm_loss
from repro.models.transformer import init_cache, forward, prefill

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.arange(b * s).reshape(b, s) % cfg.vocab,
             "labels": jnp.arange(b * s).reshape(b, s) % cfg.vocab}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((b, cfg.enc_seq, cfg.d_frontend)) * 0.1
    if cfg.n_vision_tokens:
        batch["vision"] = jnp.ones((b, cfg.n_vision_tokens,
                                    cfg.d_frontend or cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one grad step on the reduced config: shapes + finite."""
    cfg = get_config(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    ctx = Ctx()
    hidden, aux, _ = forward(ctx, params, batch, cfg)
    exp_s = 16 + (cfg.n_vision_tokens or 0)
    assert hidden.shape == (2, exp_s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))

    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(ctx, p, batch, cfg))(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    cache = init_cache(cfg, 2, 32 + (cfg.n_vision_tokens or 0))
    logits, cache = prefill(Ctx(), params, batch, cfg, cache)
    assert logits.shape == (2, 1, cfg.vocab)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, cache = decode_step(Ctx(), params, tok, cache, cfg)
    assert logits2.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "chatglm3-6b",
                                  "recurrentgemma-9b", "xlstm-125m",
                                  "deepseek-v2-lite-16b"])
def test_decode_matches_teacher_forcing(arch):
    """Stepwise decode must reproduce the full-sequence forward logits —
    the cache paths (ring buffers, latents, recurrent states) are only
    correct if these agree position by position."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.moe:
        # capacity-based dispatch is only decode/prefill-consistent when
        # nothing is dropped; give prefill headroom for this equality test
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_lm(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    toks = (jnp.arange(b * s).reshape(b, s) * 7 + 3) % cfg.vocab
    batch = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((b, cfg.enc_seq, cfg.d_frontend)) * 0.1
    ctx = Ctx()
    hidden, _, _ = forward(ctx, params, batch, cfg)

    # teacher-forced logits at the final position via prefill
    cache = init_cache(cfg, b, 32)
    logits_pref, cache = prefill(ctx, params,
                                 {**batch, "tokens": toks[:, :-1]},
                                 cfg, cache)
    # decode one step with the true next token's predecessor
    logits_dec, _ = decode_step(ctx, params, toks[:, -1:], cache, cfg)

    # compare against prefill over the full sequence
    cache_full = init_cache(cfg, b, 32)
    logits_full, _ = prefill(ctx, params, batch, cfg, cache_full)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, 0]),
                               rtol=2e-2, atol=2e-3)


def test_int8_kv_close_to_f32():
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    ctx = Ctx()
    c32 = init_cache(cfg, 2, 32, dtype=jnp.float32)
    c8 = init_cache(cfg, 2, 32, dtype=jnp.int8)
    l32, c32 = prefill(ctx, params, batch, cfg, c32)
    l8, c8 = prefill(ctx, params, batch, cfg, c8)
    tok = jnp.argmax(l32[:, -1], -1).astype(jnp.int32)[:, None]
    d32, _ = decode_step(ctx, params, tok, c32, cfg)
    d8, _ = decode_step(ctx, params, tok, c8, cfg)
    # int8 KV must preserve the argmax and stay close in logit space
    assert jnp.array_equal(jnp.argmax(d32[:, 0], -1), jnp.argmax(d8[:, 0], -1))
    rel = float(jnp.linalg.norm(d32 - d8) / jnp.linalg.norm(d32))
    assert rel < 0.05


def test_local_attention_ring_buffer_evicts():
    """Sliding-window cache must forget positions beyond the window."""
    cfg = get_config("recurrentgemma-9b").reduced()  # window=16
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b = 1
    toks = jnp.ones((b, 4), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    cache = init_cache(cfg, b, 64)
    _, cache = prefill(Ctx(), params, batch, cfg, cache)
    tok = jnp.zeros((b, 1), jnp.int32)
    for _ in range(cfg.window + 4):  # run past the window
        logits, cache = decode_step(Ctx(), params, tok, cache, cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_long_context_flags():
    assert get_config("recurrentgemma-9b").supports_long_context
    assert get_config("xlstm-125m").supports_long_context
    assert not get_config("qwen1.5-32b").supports_long_context
    ok, why = shape_applicable(get_config("qwen1.5-32b"), SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why


def test_n_params_ballpark():
    """Param-count model must land within 25% of the nominal sizes it is
    used for (MODEL_FLOPS accounting)."""
    nominal = {"phi3-mini-3.8b": 3.8e9, "qwen1.5-32b": 32.5e9,
               "deepseek-moe-16b": 16.4e9, "xlstm-125m": 0.125e9}
    for arch, n in nominal.items():
        est = get_config(arch).n_params()
        assert 0.7 * n < est < 1.35 * n, (arch, est, n)
    moe = get_config("deepseek-moe-16b")
    assert moe.n_active_params() < 0.35 * moe.n_params()
