"""Quantizer substrate: MXINT / uniform / GPTQ invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; CI installs it
from hypothesis import given, settings, strategies as st

from repro.quant import (
    MXIntQuantizer,
    UniformQuantizer,
    QuantizerConfig,
    effective_bits,
    make_quantizer,
    pack_codes_4bit,
    unpack_codes_4bit,
)
from repro.quant.gptq import GPTQQuantizer, hessian_from_activations


def _w(seed, m=96, n=64, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, n)) * scale


# ---------------------------------------------------------------------------
# MXINT
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("block", [16, 32])
def test_mxint_roundtrip_bound(bits, block):
    """|w − Q(w)| ≤ scale/2 per element, scale = 2^exp of the block."""
    q = MXIntQuantizer(bits=bits, block_size=block)
    w = _w(bits * 7 + block, 128, 48, scale=3.0)
    packed = q.quantize(w)
    deq = q.dequantize(packed)
    scales = jnp.exp2(packed.exponents.astype(jnp.float32))
    per_elem_scale = jnp.repeat(scales, block, axis=0)[: w.shape[0]]
    assert jnp.all(jnp.abs(w - deq) <= per_elem_scale * 0.5 + 1e-7)


def test_mxint_idempotent():
    q = MXIntQuantizer(bits=3, block_size=32)
    w = _w(1)
    w1 = q.fake_quant(w)
    w2 = q.fake_quant(w1)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)


def test_mxint_zero_block():
    q = MXIntQuantizer(bits=3, block_size=32)
    w = jnp.zeros((64, 8))
    assert float(jnp.max(jnp.abs(q.fake_quant(w)))) == 0.0


def test_mxint_code_range():
    q = MXIntQuantizer(bits=3, block_size=32)
    packed = q.quantize(_w(2, 64, 32, scale=10.0))
    assert int(packed.codes.max()) <= 3 and int(packed.codes.min()) >= -4


def test_mxint_pads_ragged_rows():
    q = MXIntQuantizer(bits=3, block_size=32)
    w = _w(3, 40, 16)  # 40 % 32 != 0
    assert q.fake_quant(w).shape == w.shape


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack4_roundtrip(seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-8, 8, size=(16, 6)).astype(np.int8)
    packed = pack_codes_4bit(jnp.asarray(codes))
    out = unpack_codes_4bit(packed)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_effective_bits_accounting():
    assert effective_bits(QuantizerConfig("mxint", 3, 32)) == 3.25
    assert effective_bits(QuantizerConfig("mxint", 4, 32)) == 4.25
    assert effective_bits(QuantizerConfig("mxint", 2, 32)) == 2.25


# ---------------------------------------------------------------------------
# Uniform
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("symmetric", [True, False])
def test_uniform_roundtrip(symmetric):
    q = UniformQuantizer(bits=4, group_size=32, symmetric=symmetric)
    w = _w(4, 96, 32)
    deq = q.fake_quant(w)
    # error bounded by half step of each group
    err = float(jnp.max(jnp.abs(w - deq)))
    amax = float(jnp.max(jnp.abs(w)))
    assert err <= amax / (2 ** 3) + 1e-5


# ---------------------------------------------------------------------------
# GPTQ
# ---------------------------------------------------------------------------
def test_gptq_beats_rtn_on_correlated_inputs():
    """Hessian-aware rounding should reduce output-space error vs plain
    rounding when inputs are correlated."""
    key = jax.random.PRNGKey(5)
    m, n = 64, 48
    w = jax.random.normal(key, (m, n))
    mix = jax.random.normal(jax.random.fold_in(key, 1), (m, m)) * 0.3 \
        + jnp.eye(m)
    x = jax.random.normal(jax.random.fold_in(key, 2), (512, m)) @ mix
    h = hessian_from_activations(x)
    gptq = GPTQQuantizer(bits=3, group_size=32).make_bound(h)
    rtn = UniformQuantizer(bits=3, group_size=32)
    err_gptq = float(jnp.linalg.norm(x @ (w - gptq.fake_quant(w))))
    err_rtn = float(jnp.linalg.norm(x @ (w - rtn.fake_quant(w))))
    assert err_gptq < err_rtn


def test_make_quantizer_factory():
    assert make_quantizer(QuantizerConfig("mxint", 3, 32)).effective_bits == 3.25
    with pytest.raises(ValueError):
        make_quantizer(QuantizerConfig("gptq", 3, 32))  # needs hessian
