"""Continuous-batching serving: slots, scheduler, engine semantics."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n, rng=None, base_len=5, budget=None):
    rng = rng or np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=base_len + (i % 3))
                    .astype(np.int32),
                    max_new_tokens=budget[i] if budget else None)
            for i in range(n)]


def _engine(cfg, params, **kw):
    defaults = dict(max_len=64, decode_batch=3, max_new_tokens=6,
                    prefill_len=16, scheduler="continuous")
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


# ---------------------------------------------------------------------------
# Scheduler semantics
# ---------------------------------------------------------------------------
def test_mixed_max_new_tokens_in_one_batch(tiny):
    """Co-batched requests honour their own budgets: a 2-token request
    retires while its neighbours keep decoding to 4 and 8."""
    cfg, params = tiny
    budget = {0: 2, 1: 4, 2: 8}
    eng = _engine(cfg, params)
    res = eng.generate(_reqs(cfg, 3, budget=budget))
    assert [len(r.tokens) for r in res] == [2, 4, 8]


def test_eos_retirement_frees_slot_for_queued_request(tiny):
    """With 2 slots and 5 requests, EOS retirement must hand lanes to the
    queue: everything completes, and early-EOS requests stop early."""
    cfg, params = tiny
    # discover a token each prompt actually generates, use it as EOS
    eng0 = _engine(cfg, params, decode_batch=2)
    probe = eng0.generate(_reqs(cfg, 5))
    eos = int(probe[0].tokens[1])  # 2nd token of request 0

    eng = _engine(cfg, params, decode_batch=2, eos_id=eos,
                  max_new_tokens=12)
    res = eng.generate(_reqs(cfg, 5))
    assert [r.uid for r in res] == list(range(5))
    st = eng.stats()
    assert st["admitted"] == 5 and st["retired"] == 5
    assert st["eos_retired"] >= 1
    for r in res:
        if eos in r.tokens.tolist():
            assert r.tokens[-1] == eos  # truncated at EOS, slot freed


def test_zero_budget_stays_zero_not_default():
    """Regression: next_admission used `req.max_new_tokens or default`,
    so an explicit max_new_tokens=0 silently became the default budget;
    the check must be `is not None`."""
    from repro.serve.scheduler import ContinuousScheduler
    sched = ContinuousScheduler(n_slots=1, eos_id=-1, default_budget=64)
    sched.submit(Request(uid=0, prompt=np.zeros((3,), np.int32),
                         max_new_tokens=0))
    sched.submit(Request(uid=1, prompt=np.zeros((3,), np.int32),
                         max_new_tokens=None))
    req, state = sched.next_admission()
    assert req.uid == 0 and state.budget == 0
    sched.admit(state)
    sched.retire(0)
    _, state = sched.next_admission()
    assert state.budget == 64          # None still means the default


def test_engine_zero_budget_request(tiny):
    """A max_new_tokens=0 request yields 0 tokens and frees its slot on
    the admission step — not the engine-default budget — and both
    schedulers agree on the zero-token semantics."""
    cfg, params = tiny
    budget = {0: 0, 1: 3, 2: 3}
    res = _engine(cfg, params, max_new_tokens=6).generate(
        _reqs(cfg, 3, budget=budget))
    assert [len(r.tokens) for r in res] == [0, 3, 3]
    res_b = _engine(cfg, params, max_new_tokens=6,
                    scheduler="bucketed").generate(
        _reqs(cfg, 3, budget=budget))
    for rc, rb in zip(res, res_b):
        np.testing.assert_array_equal(rc.tokens, rb.tokens)


def test_bucketed_occupancy_uses_real_slot_count(tiny):
    """Regression: the bucketed path must feed SchedulerStats its real
    lane count (decode_batch), not the dataclass's n_slots=1 default —
    otherwise an under-full bucket reports occupancy > 1 instead of the
    honest fraction. One request in a 4-lane bucket: exactly 1 of 4
    lanes does useful work per decode step."""
    from repro.serve import SchedulerStats
    cfg, params = tiny
    eng = _engine(cfg, params, decode_batch=4, scheduler="bucketed",
                  max_new_tokens=6)
    eng.generate(_reqs(cfg, 1))
    assert isinstance(eng._bucket_stats, SchedulerStats)
    assert eng._bucket_stats.n_slots == 4
    st = eng.stats()
    assert st["decode_steps"] > 0
    assert abs(st["occupancy"] - 0.25) < 1e-6
    # a fresh generate() resets the counters with the same n_slots
    eng.generate(_reqs(cfg, 1))
    assert eng._bucket_stats.n_slots == 4
    assert abs(eng.stats()["occupancy"] - 0.25) < 1e-6


def test_more_requests_than_slots_all_complete(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, decode_batch=2)
    res = eng.generate(_reqs(cfg, 7))
    assert [r.uid for r in res] == list(range(7))
    assert all(len(r.tokens) == 6 for r in res)
    assert eng.stats()["occupancy"] > 0.5


# ---------------------------------------------------------------------------
# Parity: schedulers and KV dtypes
# ---------------------------------------------------------------------------
def test_continuous_matches_bucketed_greedy(tiny):
    """Greedy outputs must be identical between the two schedulers even
    with mixed prompt lengths and budgets (acceptance criterion)."""
    cfg, params = tiny
    budget = {i: 3 + (i % 4) for i in range(6)}
    reqs = lambda: _reqs(cfg, 6, budget=budget)  # noqa: E731
    res_c = _engine(cfg, params).generate(reqs())
    res_b = _engine(cfg, params, scheduler="bucketed").generate(reqs())
    for rc, rb in zip(res_c, res_b):
        assert rc.uid == rb.uid
        np.testing.assert_array_equal(rc.tokens, rb.tokens)


def test_no_state_leak_across_admissions_recurrent(tiny):
    """Regression: consecutive admissions must not leak recurrent state
    (RG-LRU conv history, xLSTM C/n/m) through the shared prefill
    template — parity on a recurrent arch catches it."""
    del tiny
    cfg = get_config("xlstm-125m").reduced()
    params = init_lm(jax.random.PRNGKey(2), cfg)
    budget = {i: 3 + (i % 3) for i in range(5)}
    res_c = _engine(cfg, params, decode_batch=2).generate(
        _reqs(cfg, 5, budget=budget))
    res_b = _engine(cfg, params, decode_batch=2,
                    scheduler="bucketed").generate(_reqs(cfg, 5, budget=budget))
    for rc, rb in zip(res_c, res_b):
        np.testing.assert_array_equal(rc.tokens, rb.tokens)


def test_int8_kv_matches_bf16_greedy(tiny):
    """int8 KV quantization must preserve greedy token choices on the
    reduced config (continuous scheduler)."""
    cfg, params = tiny
    res_bf = _engine(cfg, params, kv_dtype="bf16").generate(_reqs(cfg, 4))
    res_i8 = _engine(cfg, params, kv_dtype="int8").generate(_reqs(cfg, 4))
    for rb, ri in zip(res_bf, res_i8):
        np.testing.assert_array_equal(rb.tokens, ri.tokens)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8", "int4"])
def test_window_local_slot_reuse_fused_parity(tiny, kv_dtype):
    """Sliding-window × continuous-batching interplay: more requests
    than slots on a window-local arch (recurrentgemma's rglru/local
    pattern), so retired slots are reused mid-flight and the local
    layer's ring buffer wraps (max_len ≫ window). Greedy tokens must be
    identical across fused=auto|on|off for every KV dtype — including
    int4, whose ring writes go through the packed nibble pages."""
    del tiny
    cfg = get_config("recurrentgemma-9b").reduced()
    assert "local" in cfg.block_pattern and cfg.window == 16
    params = init_lm(jax.random.PRNGKey(3), cfg)
    budget = {0: 26, 1: 3, 2: 7, 3: 4, 4: 5}   # uid 0 wraps the ring

    outs = {}
    for mode in ("off", "auto", "on"):
        eng = _engine(cfg, params, decode_batch=2, max_len=48,
                      kv_dtype=kv_dtype, fused=mode, max_new_tokens=26)
        outs[mode] = eng.generate(_reqs(cfg, 5, budget=budget))
        assert [len(r.tokens) for r in outs[mode]] == [26, 3, 7, 4, 5]
    for mode in ("auto", "on"):
        for a, b in zip(outs["off"], outs[mode]):
            assert a.uid == b.uid
            np.testing.assert_array_equal(
                a.tokens, b.tokens,
                err_msg=f"kv={kv_dtype} fused={mode} diverged from off")


# ---------------------------------------------------------------------------
# Streaming API
# ---------------------------------------------------------------------------
def test_streaming_submit_step_drain(tiny):
    """Late submissions join mid-flight and still complete."""
    cfg, params = tiny
    eng = _engine(cfg, params, decode_batch=2)
    reqs = _reqs(cfg, 4)
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    done = []
    for _ in range(3):
        done.extend(eng.step())
    eng.submit(reqs[2])      # arrives while 0/1 are decoding
    eng.submit(reqs[3])
    done.extend(eng.drain())
    assert sorted(r.uid for r in done) == [0, 1, 2, 3]
    assert all(len(r.tokens) == 6 for r in done)
    assert all(r.latency_s >= r.ttft_s > 0 for r in done)


def test_streaming_matches_batch_generate(tiny):
    cfg, params = tiny
    eng1 = _engine(cfg, params, decode_batch=2)
    for r in _reqs(cfg, 4):
        eng1.submit(r)
    res1 = eng1.drain()
    res2 = _engine(cfg, params, decode_batch=2).generate(_reqs(cfg, 4))
    for a, b in zip(res1, res2):
        np.testing.assert_array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# Validation + sampling fixes
# ---------------------------------------------------------------------------
def test_prompt_longer_than_max_len_raises(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, max_len=16, prefill_len=16)
    long_prompt = Request(uid=0, prompt=np.zeros((16,), np.int32))
    with pytest.raises(ValueError, match="decode budget"):
        eng.submit(long_prompt)
    engb = _engine(cfg, params, max_len=16, scheduler="bucketed")
    with pytest.raises(ValueError, match="decode budget"):
        engb.generate([long_prompt])


def test_prompt_exceeding_prefill_len_raises(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, prefill_len=8)
    with pytest.raises(ValueError, match="prefill"):
        eng.submit(Request(uid=0, prompt=np.zeros((12,), np.int32)))


def test_first_token_respects_temperature(tiny):
    """The first token (from prefill logits) goes through the same
    temperature path as decode steps: different seeds must produce
    different outputs — including the very first token somewhere."""
    cfg, params = tiny
    for sched in ("bucketed", "continuous"):
        eng = _engine(cfg, params, scheduler=sched, temperature=4.0,
                      max_new_tokens=8)
        reqs = lambda: _reqs(cfg, 3)  # noqa: E731
        a = eng.generate(reqs(), seed=0)
        b = eng.generate(reqs(), seed=1)
        firsts_a = [r.tokens[0] for r in a]
        firsts_b = [r.tokens[0] for r in b]
        assert firsts_a != firsts_b, (
            f"{sched}: first token ignored the sampling seed")


def test_greedy_deterministic_across_runs(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params)
    a = eng.generate(_reqs(cfg, 3))
    b = eng.generate(_reqs(cfg, 3))
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
