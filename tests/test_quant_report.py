"""Quantize-time introspection (repro.obs.quant) + report tooling.

Runs the real SRR pipeline over a reduced model with a
:class:`QuantRecorder` threaded through, then checks the paper-facing
invariants of every record (energy split, rank budget, byte
accounting), validates the written report against
``tools/quant_report_schema.json`` with the repo's own validator, and
smoke-renders it through ``python -m tools.quant_report``.
"""
import json
import os
import sys

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from repro.configs import get_config
from repro.core.api import PTQConfig
from repro.models import init_lm
from repro.models.quantize import quantize_model_params
from repro.obs import NULL_QUANT_RECORDER, QuantRecorder
from repro.quant.base import QuantizerConfig

from tools.quant_report import main as render_main          # noqa: E402
from tools.validate_metrics import validate                 # noqa: E402

SCHEMA_PATH = os.path.join(REPO, "tools", "quant_report_schema.json")


@pytest.fixture(scope="module")
def quantized():
    """One SRR pass over the reduced model with a live recorder."""
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rec = QuantRecorder()
    ptq = PTQConfig(method="srr", scaling="identity", rank=8,
                    quantizer=QuantizerConfig(kind="mxint", bits=3,
                                              block_size=32))
    qparams, reports = quantize_model_params(params, None, ptq,
                                             container="int8", recorder=rec)
    return cfg, qparams, rec, reports


def _schema():
    with open(SCHEMA_PATH) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# per-record invariants
# ---------------------------------------------------------------------------
def test_every_pass_recorded(quantized):
    _, _, rec, reports = quantized
    assert len(rec.records) == len(reports) > 0
    assert {r.name for r in reports} == set(rec.records)


def test_record_energy_and_rank_invariants(quantized):
    _, _, rec, _ = quantized
    for r in rec.records.values():
        assert 0.0 <= r.preserved_energy_fraction <= 1.0
        assert abs(r.preserved_energy_fraction
                   + r.quant_exposed_energy_fraction - 1.0) < 1e-9
        assert 0 <= r.k <= r.rank
        # MXINT 3-bit block-32: 3 + 8/32 effective bits
        assert r.bits == pytest.approx(3.25)
        # the spectrum head is descending (singular values of SW)
        head = r.singular_head
        assert head == sorted(head, reverse=True)
        assert r.scaled_err >= 0 and r.weight_err >= 0
        assert 0 < r.scaled_rel_err < 1.0


def test_record_matches_layer_report(quantized):
    _, _, rec, reports = quantized
    for rep in reports:
        r = rec.records[rep.name]
        assert r.scaled_err == pytest.approx(rep.scaled_err)
        assert r.weight_err == pytest.approx(rep.weight_err)
        assert r.k == rep.k_star and r.rank == rep.rank


def test_container_byte_accounting(quantized):
    _, _, rec, _ = quantized
    for r in rec.records.values():
        assert r.container == "int8"
        assert r.quant_bytes > 0 and r.lowrank_bytes > 0
        assert r.total_bytes == r.quant_bytes + r.lowrank_bytes


# ---------------------------------------------------------------------------
# report: schema pin + CLI render + Chrome trace
# ---------------------------------------------------------------------------
def test_report_validates_against_schema(quantized):
    _, _, rec, _ = quantized
    report = rec.build_report()
    schema = _schema()
    assert validate(report, schema, schema) == []
    s = report["summary"]
    assert s["layers"] == len(rec.records)
    assert s["total_bytes"] == s["quant_bytes"] + s["lowrank_bytes"]
    assert 0.0 <= s["mean_preserved_energy_fraction"] <= 1.0


def test_write_produces_report_and_trace(quantized, tmp_path):
    _, _, rec, _ = quantized
    path = str(tmp_path / "report.json")
    rec.write(path)
    with open(path) as f:
        report = json.load(f)
    schema = _schema()
    assert validate(report, schema, schema) == []
    trace = str(tmp_path / "report.trace.json")
    with open(trace) as f:
        events = json.load(f)["traceEvents"]
    spans = [e for e in events if e.get("ph") == "X" and e.get("pid") == 3]
    assert len(spans) == len(rec.records)
    assert any(e.get("name") == "process_name" for e in events)


def test_cli_renders_tables_and_worst(quantized, tmp_path, capsys):
    _, _, rec, _ = quantized
    path = str(tmp_path / "report.json")
    rec.write(path)
    assert render_main([path, "--worst", "2"]) == 0
    out = capsys.readouterr().out
    assert "worst 2 layers" in out
    assert "pres%" in out and "s-rel-err" in out
    # every layer shows up in the table
    for name in rec.records:
        assert name in out


def test_cli_rejects_schema_violation(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"version": 1, "config": {},
                               "summary": {}, "layers": {}}))
    assert render_main([str(bad)]) == 1
    assert "violates" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# null object
# ---------------------------------------------------------------------------
def test_null_recorder_is_inert_and_schema_clean():
    NULL_QUANT_RECORDER.record_layer("x", None, None, None, None, None, None)
    NULL_QUANT_RECORDER.attach_container("x", {}, "int8")
    report = NULL_QUANT_RECORDER.build_report()
    schema = _schema()
    assert validate(report, schema, schema) == []
    assert report["layers"] == {} and report["summary"]["layers"] == 0


def test_pipeline_without_recorder_unchanged(quantized):
    """recorder=None is the default and must not perturb the pass."""
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ptq = PTQConfig(method="srr", scaling="identity", rank=8,
                    quantizer=QuantizerConfig(kind="mxint", bits=3,
                                              block_size=32))
    _, reports = quantize_model_params(params, None, ptq)
    _, _, _, recorded_reports = quantized
    assert [(r.name, r.k_star, r.scaled_err) for r in reports] == \
        [(r.name, r.k_star, r.scaled_err) for r in recorded_reports]
