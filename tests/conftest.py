import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def planted_lowrank(key, m, n, rank_sig=8, sig=6.0, noise=0.02):
    """Weight with dominant low-rank structure + dense noise — the regime
    the paper targets (Fig. 1: quantization corrupts dominant dirs)."""
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (m, rank_sig))
    v = jax.random.normal(k2, (rank_sig, n))
    base = jax.random.normal(k3, (m, n)) * noise
    return base + (u @ v) * (sig / (m * n) ** 0.5)


@pytest.fixture(scope="session")
def calib_x():
    return jax.random.normal(jax.random.PRNGKey(7), (1024, 256))
