"""QPEFT: adapter init, gradient scaling (Eq. 7–9), split/merge, training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import planted_lowrank
from repro.core import (
    AdapterParams,
    adapter_matmul,
    fixed_gamma_scale,
    init_adapter,
    make_scaling,
    scale_adapter_grads,
    sgp_scale,
    srr_decompose,
)
from repro.optim import scale_lr_grads_by_key
from repro.quant import MXIntQuantizer

QZ = MXIntQuantizer(bits=3, block_size=32)


def _dec(seed=0, m=128, n=96, r=16):
    w = planted_lowrank(jax.random.PRNGKey(seed), m, n)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (512, m))
    s = make_scaling("qera-exact", x)
    return w, srr_decompose(w, s, QZ, r, jax.random.PRNGKey(2),
                            exact=True).decomposition


def test_adapter_init_reconstructs_weight():
    w, dec = _dec()
    params, static = init_adapter(dec)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, w.shape[0]))
    y = adapter_matmul(x, params, static)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ dec.reconstruct()), atol=1e-3)


def test_fixed_gamma_scale_vector():
    g = fixed_gamma_scale(8, 3, 0.1)
    np.testing.assert_allclose(np.asarray(g[:3]), 0.1)
    np.testing.assert_allclose(np.asarray(g[3:]), 1.0)


def test_gamma_grad_scaling_attenuates_preserved_only():
    w, dec = _dec()
    params, static = init_adapter(dec, mode="gamma", gamma=0.1)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, w.shape[0]))

    def loss(p):
        return jnp.sum(adapter_matmul(x, p, static) ** 2)

    grads = jax.grad(loss)(params)
    scaled = scale_adapter_grads(grads, static)
    k = dec.k
    np.testing.assert_allclose(np.asarray(scaled.l[:, :k]),
                               np.asarray(grads.l[:, :k]) * 0.1, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(scaled.l[:, k:]),
                               np.asarray(grads.l[:, k:]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(scaled.r[:k]),
                               np.asarray(grads.r[:k]) * 0.1, rtol=1e-6)


def test_sgp_scale_rankwise():
    """Eq. 9: λ_i = (α+1)σ_i/(ασ_i+σ_1); top singular direction gets the
    strongest attenuation (λ_1 = 1 ⇒ scale 0)."""
    _, dec = _dec()
    g = sgp_scale(dec, alpha=5.0)
    k = dec.k
    assert float(g[0]) == pytest.approx(0.0, abs=1e-5)
    assert np.all(np.diff(np.asarray(g[:k])) >= -1e-6)  # monotone up
    np.testing.assert_allclose(np.asarray(g[k:]), 1.0)


def test_gamma_extremes_match_paper_semantics():
    """γ=1 ⇒ no attenuation; γ=0 ⇒ preserved block frozen."""
    _, dec = _dec()
    p1, s1 = init_adapter(dec, mode="gamma", gamma=1.0)
    np.testing.assert_allclose(np.asarray(s1.grad_scale), 1.0)
    p0, s0 = init_adapter(dec, mode="gamma", gamma=0.0)
    g = AdapterParams(l=jnp.ones_like(p0.l), r=jnp.ones_like(p0.r))
    sg = scale_adapter_grads(g, s0)
    assert float(jnp.sum(jnp.abs(sg.l[:, :dec.k]))) == 0.0


def test_dict_schema_grad_scaling_stacked():
    """Model-tree variant handles stacked (scan) adapters per matrix."""
    G, m, r, n = 3, 8, 4, 6
    grads = {"l": jnp.ones((G, m, r)), "r": jnp.ones((G, r, n))}
    gscale = jnp.stack([jnp.array([0.1, 0.1, 1.0, 1.0]),
                        jnp.array([0.1, 1.0, 1.0, 1.0]),
                        jnp.ones(4)])
    scales = {"gscale": gscale}
    out = scale_lr_grads_by_key(grads, scales)
    np.testing.assert_allclose(np.asarray(out["l"][0, :, 0]), 0.1)
    np.testing.assert_allclose(np.asarray(out["l"][2]), 1.0)
    np.testing.assert_allclose(np.asarray(out["r"][1, 0]), 0.1)
    np.testing.assert_allclose(np.asarray(out["r"][1, 1]), 1.0)


def test_model_qpeft_split_merge_roundtrip():
    from repro.configs import get_config
    from repro.core.api import PTQConfig
    from repro.models import init_lm, lm_loss, Ctx
    from repro.models.quantize import (merge_qpeft, quantize_model_params,
                                       split_qpeft)
    from repro.quant.base import QuantizerConfig

    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ptq = PTQConfig(method="srr", scaling="identity", rank=8,
                    quantizer=QuantizerConfig("mxint", 3, 32))
    qparams, _ = quantize_model_params(params, None, ptq)
    trainable, frozen = split_qpeft(qparams)
    merged = merge_qpeft(trainable, frozen)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.zeros((2, 8), jnp.int32)}
    l0 = float(lm_loss(Ctx(), qparams, batch, cfg))
    l1 = float(lm_loss(Ctx(), merged, batch, cfg))
    assert l0 == pytest.approx(l1, rel=1e-6)
    # backbone must not appear in the trainable tree
    for path, leaf in jax.tree_util.tree_flatten_with_path(trainable)[0]:
        key = jax.tree_util.keystr(path)
        assert "codes" not in key and "scale" not in key


def test_qpeft_training_descends():
    from repro.configs import get_config
    from repro.core.api import PTQConfig
    from repro.data import data_config_for, host_batch
    from repro.models import init_lm, lm_loss, Ctx
    from repro.models.quantize import merge_qpeft, quantize_model_params, split_qpeft
    from repro.optim import AdamW, cosine_schedule
    from repro.quant.base import QuantizerConfig
    from repro.train import StepConfig, init_qpeft_state, make_qpeft_step

    cfg = get_config("minitron-4b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ptq = PTQConfig(method="srr", scaling="identity", rank=8,
                    quantizer=QuantizerConfig("mxint", 3, 32))
    qparams, _ = quantize_model_params(params, None, ptq)
    trainable, frozen = split_qpeft(qparams)
    opt = AdamW(learning_rate=cosine_schedule(3e-3, 5, 40))
    state = init_qpeft_state(trainable, frozen, opt)
    step = jax.jit(make_qpeft_step(
        cfg, opt, StepConfig(compute_dtype=jnp.float32)))
    dcfg = data_config_for(cfg, seq_len=32, global_batch=8)
    eval_batch = host_batch(dcfg, 999)

    def eval_loss(st):
        return float(lm_loss(Ctx(), merge_qpeft(st.trainable, st.frozen),
                             eval_batch, cfg))

    before = eval_loss(state)
    frozen_before = jax.tree_util.tree_leaves(state.frozen)[0].copy()
    for s in range(40):
        state, _ = step(state, host_batch(dcfg, s))
    after = eval_loss(state)
    assert after < before - 0.01
    # frozen backbone untouched
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(state.frozen)[0]),
        np.asarray(frozen_before))
