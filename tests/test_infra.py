"""Infrastructure: sharding rules, data determinism, optim, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

try:  # jax ≥ 0.5
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x
    AxisType = None

from repro.configs import get_config
from repro.data import DataConfig, data_config_for, host_batch
from repro.launch.mesh import make_host_mesh
from repro.models import init_lm
from repro.optim import (
    AdamW,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    dequantize_int8,
    quantize_int8,
)
from repro.sharding import (
    batch_axes,
    batch_spec,
    spec_for_cache,
    tree_param_specs,
)
from repro.train import CheckpointManager


def _mesh16():
    return jax.sharding.Mesh(
        np.array(jax.devices() * 256).reshape(16, 16)[:16, :16]
        if jax.device_count() == 1 else None, ("data", "model")) \
        if False else None


@pytest.fixture(scope="module")
def mesh():
    # an abstract 16×16 mesh built from repeated CPU devices is invalid;
    # use AbstractMesh for pure spec logic
    from jax.sharding import AbstractMesh
    if AxisType is None:  # jax 0.4.x signature: tuple of (name, size)
        return AbstractMesh((("data", 16), ("model", 16)))
    return AbstractMesh((16, 16), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------
def test_param_specs_cover_and_divide(mesh):
    for arch in ("qwen1.5-32b", "deepseek-moe-16b", "whisper-large-v3"):
        cfg = get_config(arch)
        absp = jax.eval_shape(
            lambda k: init_lm(k, cfg, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        specs = tree_param_specs(absp, mesh)
        flat_s = jax.tree_util.tree_flatten_with_path(specs)[0]
        flat_p = jax.tree_util.tree_flatten_with_path(absp)[0]
        assert len(flat_s) == len(flat_p)
        for (path, spec), (_, arr) in zip(flat_s, flat_p):
            assert len(spec) <= len(arr.shape)
            for dim, ax in zip(arr.shape, spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([dict(mesh.shape)[a] for a in axes]))
                assert dim % n == 0, (jax.tree_util.keystr(path), arr.shape,
                                      spec)


def test_expert_parallelism_claims_model_axis(mesh):
    cfg = get_config("deepseek-moe-16b")
    absp = jax.eval_shape(lambda k: init_lm(k, cfg, dtype=jnp.bfloat16),
                          jax.random.PRNGKey(0))
    specs = tree_param_specs(absp, mesh)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    seen = False
    for path, spec in flat:
        key = jax.tree_util.keystr(path)
        if "experts" in key and key.endswith("['w']"):
            assert "model" in str(spec), (key, spec)
            # within-expert dims must not reuse the model axis
            assert str(spec).count("model") == 1
            seen = True
    assert seen


def test_batch_spec_adapts_to_small_batches(mesh):
    assert batch_axes(mesh, 256) == ("data",)
    assert batch_axes(mesh, 1) == ()
    assert batch_spec(mesh, 1, 1) == P(None, None)


def test_cache_spec_heads_else_sequence(mesh):
    """Divisible KV heads take the model axis; otherwise the SEQUENCE dim
    does (flash-decode: softmax-stat psums only — sharding head_dim would
    all-reduce full score rows; see EXPERIMENTS.md §Perf It-3). Slot K/V
    pages are head-major (B, KV, S, hd); cross-attention memories stay
    sequence-major (B, S, KV, hd)."""
    spec2 = spec_for_cache(
        (jax.tree_util.DictKey("k"),), (128, 32, 32768, 128), mesh, 128)
    assert spec2[1] == "model" and spec2[2] is None   # heads preferred
    spec = spec_for_cache(
        (jax.tree_util.DictKey("k"),), (128, 40, 32768, 128), mesh, 128)
    assert spec[2] == "model"                         # S fallback (40 ∤ 16)
    assert spec[1] is None and spec[3] is None
    xspec = spec_for_cache(
        (jax.tree_util.DictKey("cross_k"),), (128, 1500, 32, 128), mesh, 128)
    assert xspec[2] == "model" and xspec[1] is None   # seq-major memories


# ---------------------------------------------------------------------------
# Data determinism
# ---------------------------------------------------------------------------
def test_data_deterministic_per_step():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    a = host_batch(cfg, step=3)
    b = host_batch(cfg, step=3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = host_batch(cfg, step=4)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_data_host_sharding_partitions_global_batch():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    full = host_batch(cfg, 0, host_index=0, host_count=1)
    h0 = host_batch(cfg, 0, host_index=0, host_count=2)
    h1 = host_batch(cfg, 0, host_index=1, host_count=2)
    stacked = np.concatenate([np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"])])
    np.testing.assert_array_equal(stacked, np.asarray(full["tokens"]))


def test_labels_shift_tokens():
    cfg = DataConfig(vocab=50, seq_len=12, global_batch=2)
    b = host_batch(cfg, 0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_converges_quadratic():
    opt = AdamW(learning_rate=0.1)
    params = {"w": jnp.ones((8,)) * 5.0}
    st = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        u, st = opt.update(g, st, params)
        params = apply_updates(params, u)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_weight_decay_excludes_norms():
    opt = AdamW(learning_rate=0.0, weight_decay=1.0)
    params = {"w": jnp.ones((4, 4)), "norm": {"g": jnp.ones((4,))}}
    st = opt.init(params)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    u, _ = opt.update(zeros, st, params)
    assert float(jnp.max(jnp.abs(u["norm"]["g"]))) == 0.0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100, floor=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((100,)) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)


def test_int8_grad_quantization_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    codes, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(codes, scale) - x)
    assert float(jnp.max(err)) <= float(scale) * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# Checkpointing (atomicity, retention, resume)
# ---------------------------------------------------------------------------
def _state(v):
    return {"params": {"w": jnp.full((4, 4), float(v))},
            "step": jnp.asarray(v, jnp.int32)}


def test_checkpoint_roundtrip_and_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for v in (1, 2, 3):
            mgr.save(v * 10, _state(v))
        assert mgr.latest_step() == 30
        restored, manifest = mgr.restore(_state(0))
        assert manifest["step"] == 30
        assert float(restored["params"]["w"][0, 0]) == 3.0
        # retention pruned the oldest
        steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
        assert steps == ["step_00000020", "step_00000030"]


def test_checkpoint_ignores_torn_tmp():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(10, _state(1))
        # simulate a crash mid-write: orphan tmp dir + torn step dir
        os.makedirs(os.path.join(d, ".tmp.99.1234"))
        os.makedirs(os.path.join(d, "step_00000099"))  # no manifest inside
        assert mgr.latest_step() == 10
        restored, _ = mgr.restore(_state(0))
        assert float(restored["params"]["w"][0, 0]) == 1.0


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, _state(1))
        bad = {"params": {"w": jnp.zeros((2, 2))}, "step": jnp.asarray(0)}
        with pytest.raises(ValueError):
            mgr.restore(bad)
