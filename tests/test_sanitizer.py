"""Runtime invariant sanitizer (serve/sanitizer.py).

Detection tests corrupt one piece of engine/device state on purpose and
assert the matching invariant fires; the parity test asserts the
sanitizer is behaviorally invisible (identical tokens with it on/off)
on the speculative paged path, whose rollback bookkeeping is exactly
what the pos checks audit.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.constraints import PACKED4_SLOT_ALIGN
from repro.models import init_lm
from repro.serve import Engine, Request, SanitizerError, ServeConfig
from repro.serve.sanitizer import _attn_layers


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=5 + (i % 3))
                    .astype(np.int32))
            for i in range(n)]


def _engine(cfg, params, **kw):
    defaults = dict(max_len=64, decode_batch=2, max_new_tokens=8,
                    prefill_len=16, scheduler="continuous", sanitize=True)
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


@pytest.fixture(scope="module")
def decoding_engine(tiny):
    """A paged int4 engine mid-decode: active lanes holding generated
    tokens, pages mapped, sanitizer armed and passing."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, kv_dtype="int4", page_size=8)
    for r in _reqs(cfg, 3):
        eng.submit(r)
    for _ in range(12):
        eng.step()
        if any(st.tokens for st in eng.sched.table.active.values()):
            break
    assert any(st.tokens for st in eng.sched.table.active.values())
    return eng


def _decoding_slot(eng):
    return next(s for s, st in eng.sched.table.active.items() if st.tokens)


# ---------------------------------------------------------------------------
# each invariant detects its own corruption
# ---------------------------------------------------------------------------
def test_clean_engine_passes(decoding_engine):
    decoding_engine._san.check(decoding_engine)


def test_detects_refcount_leak(decoding_engine):
    eng = decoding_engine
    page = eng._row_pages[_decoding_slot(eng)][0]
    eng.pool._ref[page] += 1
    try:
        with pytest.raises(SanitizerError, match="refcount"):
            eng._san.check(eng)
    finally:
        eng.pool._ref[page] -= 1
    eng._san.check(eng)


def test_detects_block_table_corruption(decoding_engine):
    eng = decoding_engine
    slot = _decoding_slot(eng)
    path, layer = next(p for p in _attn_layers(eng.slots.cache)
                       if "block_table" in p[1])
    saved = layer["block_table"]
    # point the slot's first block at a different (valid) page id: the
    # device row no longer mirrors the host mapping
    wrong = (int(eng._row_pages[slot][0]) + 1) % eng.pool.n_pages
    layer["block_table"] = saved.at[..., slot, 0].set(wrong)
    try:
        with pytest.raises(SanitizerError, match="block-table"):
            eng._san.check(eng)
    finally:
        layer["block_table"] = saved
    eng._san.check(eng)


def test_detects_pos_drift(decoding_engine):
    eng = decoding_engine
    slot = _decoding_slot(eng)
    path, layer = next(iter(_attn_layers(eng.slots.cache)))
    saved = layer["pos"]
    layer["pos"] = saved.at[..., slot].add(1)
    try:
        with pytest.raises(SanitizerError, match=r"\[sanitize:pos\]"):
            eng._san.check(eng)
    finally:
        layer["pos"] = saved
    eng._san.check(eng)


def test_detects_uncommitted_rollback(decoding_engine):
    eng = decoding_engine
    state = eng.sched.table.active[_decoding_slot(eng)]
    eng._san.check(eng)                      # records the watermark
    tok = state.tokens.pop()                 # "rollback" an emitted token
    try:
        with pytest.raises(SanitizerError, match="pos-monotonic"):
            eng._san.check(eng)
    finally:
        state.tokens.append(tok)
    eng._san.check(eng)


def test_detects_packed4_misalignment(decoding_engine):
    eng = decoding_engine
    path, layer = next(p for p in _attn_layers(eng.slots.cache)
                       if getattr(p[1].get("k"), "dtype", None) == np.uint8)
    saved = layer["k"]
    layer["k"] = saved[..., :-1, :]          # drop one packed byte row
    try:
        with pytest.raises(SanitizerError, match="int4-align"):
            eng._san.check(eng)
    finally:
        layer["k"] = saved
    eng._san.check(eng)
    assert eng.page_size % PACKED4_SLOT_ALIGN == 0


def test_detects_prefix_cache_disagreement(tiny):
    """Both directions of the radix-tree ↔ pool._cached audit: an
    orphaned cached flag (no tree owner) and a ghost tree node (pool
    un-flagged the page)."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, kv_dtype="int4", page_size=8,
                  max_new_tokens=4)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, size=16).astype(np.int32)
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [shared, rng.integers(0, cfg.vocab, size=4)
                         .astype(np.int32)]))
            for i in range(2)]
    eng.generate(reqs)
    assert eng.prefix is not None and eng.prefix._by_page, \
        "the shared 16-token prefix must have inserted full pages"
    eng._san.check(eng)
    page = next(iter(eng.prefix._by_page))
    # orphan: the pool says cached, the tree has no owning node
    node = eng.prefix._by_page.pop(page)
    try:
        with pytest.raises(SanitizerError, match="prefix-cache"):
            eng._san.check(eng)
    finally:
        eng.prefix._by_page[page] = node
    eng._san.check(eng)
    # ghost: the tree still maps a page the pool no longer marks cached.
    # On a cold page the pool partition audit fires first in the full
    # check (defense in depth), so pin the new invariant directly too.
    eng.pool._cached[page] = False
    try:
        with pytest.raises(SanitizerError, match="prefix-cache"):
            eng._san._check_prefix_cache(eng)
        with pytest.raises(SanitizerError):
            eng._san.check(eng)
    finally:
        eng.pool._cached[page] = True
    eng._san.check(eng)


# ---------------------------------------------------------------------------
# configuration and parity
# ---------------------------------------------------------------------------
def test_sanitize_requires_continuous_scheduler(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="sanitize"):
        Engine(params, cfg, ServeConfig(scheduler="bucketed",
                                        sanitize=True))


def test_sanitizer_is_token_invisible_speculative_paged(tiny):
    """The flagship parity check: speculative + paged + int8, where
    rollback/repark bookkeeping is busiest. The audit must not change a
    single token."""
    cfg, params = tiny

    def run(sanitize):
        eng = _engine(cfg, params, paged=True, kv_dtype="int8",
                      speculative=True, spec_k=3, max_new_tokens=6,
                      sanitize=sanitize)
        out = eng.generate(_reqs(cfg, 4))
        return [list(r.tokens) for r in sorted(out, key=lambda r: r.uid)]

    assert run(False) == run(True)
