"""The paper's contribution: SRR rank allocation, QER baselines, scalings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; CI installs it
from hypothesis import given, settings, strategies as st

from conftest import planted_lowrank
from repro.core import (
    Decomposition,
    make_scaling,
    qer_decompose,
    scaled_error,
    select_rank,
    srr_decompose,
    w_only,
    weight_error,
)
from repro.core.rank_alloc import rho_prefix, true_reconstruction_error
from repro.core.scaling import qera_exact_scaling
from repro.core.svd import exact_svd, randomized_svd
from repro.quant import MXIntQuantizer

QZ = MXIntQuantizer(bits=3, block_size=32)


def _setup(m=256, n=192, seed=0):
    w = planted_lowrank(jax.random.PRNGKey(seed), m, n)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (1024, m))
    s = make_scaling("qera-exact", x)
    return w, s


# ---------------------------------------------------------------------------
# SVD substrate
# ---------------------------------------------------------------------------
def test_randomized_svd_matches_exact_on_lowrank():
    w, _ = _setup()
    r = 16
    ex = exact_svd(w, r)
    rd = randomized_svd(w, r, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(rd.s[:8]), np.asarray(ex.s[:8]),
                               rtol=1e-3)
    # reconstructions agree (up to sign/rotation ⇒ compare products)
    np.testing.assert_allclose(np.asarray(rd.lowrank()),
                               np.asarray(ex.lowrank()), atol=1e-2)


def test_svd_factors_orthonormal_left():
    w, _ = _setup()
    l, r = exact_svd(w, 12).factors()
    np.testing.assert_allclose(np.asarray(l.T @ l), np.eye(12), atol=1e-4)


def test_rho_prefix_monotone_decreasing():
    w, _ = _setup()
    sv = jnp.linalg.svd(w, compute_uv=False)
    rho = rho_prefix(sv, jnp.sum(w ** 2), 32)
    assert float(rho[0]) == 1.0
    assert np.all(np.diff(np.asarray(rho)) <= 1e-7)


# ---------------------------------------------------------------------------
# QER baseline (Eq. 1): Eckart–Young optimality
# ---------------------------------------------------------------------------
def test_qer_is_best_rank_r_correction():
    w, s = _setup()
    r = 16
    dec = qer_decompose(w, s, QZ, r, exact=True)
    base = scaled_error(w, dec, s)
    # any perturbed rank-r correction is no better
    key = jax.random.PRNGKey(3)
    for i in range(3):
        dl = dec.l + 0.01 * jax.random.normal(jax.random.fold_in(key, i),
                                              dec.l.shape)
        worse = scaled_error(w, Decomposition(dec.q, dl, dec.r, 0), s)
        assert float(worse) >= float(base) - 1e-5


def test_qer_identity_scaling_matches_weight_error():
    w, _ = _setup()
    s_id = make_scaling("identity")
    dec = qer_decompose(w, s_id, QZ, 8, exact=True)
    np.testing.assert_allclose(float(scaled_error(w, dec, s_id)),
                               float(weight_error(w, dec)), rtol=1e-5)


def test_w_only_has_zero_adapter():
    w, _ = _setup()
    dec = w_only(w, QZ, 8)
    assert float(jnp.linalg.norm(dec.l)) == 0.0
    assert dec.rank == 8


# ---------------------------------------------------------------------------
# SRR (Algorithm 1)
# ---------------------------------------------------------------------------
def test_srr_rank_budget_respected():
    w, s = _setup()
    for r in (8, 16, 32):
        res = srr_decompose(w, s, QZ, r, jax.random.PRNGKey(0), exact=True)
        dec = res.decomposition
        assert dec.l.shape == (w.shape[0], r)
        assert dec.r.shape == (r, w.shape[1])
        assert 0 <= dec.k <= r
        assert np.linalg.matrix_rank(np.asarray(dec.l @ dec.r)) <= r


def test_srr_beats_qer_on_planted_lowrank():
    """The paper's headline claim at its operating regime (Fig. 1/7)."""
    w, s = _setup(512, 512, seed=2)
    r = 64
    e_qer = scaled_error(w, qer_decompose(w, s, QZ, r, exact=True), s)
    res = srr_decompose(w, s, QZ, r, jax.random.PRNGKey(1), exact=True)
    e_srr = scaled_error(w, res.decomposition, s)
    assert float(e_srr) < float(e_qer)
    assert res.decomposition.k > 0  # actually preserved something


def test_srr_k0_equals_qer():
    w, s = _setup()
    r = 16
    dq = qer_decompose(w, s, QZ, r, exact=True)
    rs = srr_decompose(w, s, QZ, r, jax.random.PRNGKey(0), k=0, exact=True)
    np.testing.assert_allclose(float(scaled_error(w, rs.decomposition, s)),
                               float(scaled_error(w, dq, s)), rtol=1e-4)


def test_srr_joint_variant_eq6():
    """Eq. 6: single rank-r SVD of S(W−Q) is optimal for fixed Q ⇒ joint
    error ≤ split error at the same k."""
    w, s = _setup(seed=4)
    r = 16
    split = srr_decompose(w, s, QZ, r, jax.random.PRNGKey(0), k=6,
                          exact=True, variant="split")
    joint = srr_decompose(w, s, QZ, r, jax.random.PRNGKey(0), k=6,
                          exact=True, variant="joint")
    # identical quantized backbone by construction
    np.testing.assert_allclose(np.asarray(split.decomposition.q),
                               np.asarray(joint.decomposition.q), atol=1e-6)
    assert float(scaled_error(w, joint.decomposition, s)) \
        <= float(scaled_error(w, split.decomposition, s)) + 1e-5


def test_surrogate_tracks_true_error():
    """Fig. 2: argmin of the surrogate lands near the true-argmin (same
    shape of the curve)."""
    w, s = _setup(384, 256, seed=6)
    r = 24
    sel = select_rank(w, s, r, jax.random.PRNGKey(0), exact=True)
    ks = list(range(0, r + 1, 4))
    true = [float(true_reconstruction_error(w, s, QZ, r, k)) for k in ks]
    k_true = ks[int(np.argmin(true))]
    k_sur = int(sel.k_star)
    # the surrogate's k should score close to the optimum on the true curve
    t_at_sur = float(true_reconstruction_error(w, s, QZ, r, k_sur))
    assert t_at_sur <= min(true) * 1.10


def test_kstar_stable_across_probe_seeds():
    """App B.1: probe randomness moves k* only slightly. The paper sees
    ±1–3 at transformer dims (4096); at this test's 512×384 the probe
    spectrum concentrates less, so the tolerance scales accordingly."""
    w, s = _setup(512, 384, seed=8)
    r = 32
    ks = [int(select_rank(w, s, r, jax.random.PRNGKey(seed),
                          exact=True).k_star)
          for seed in range(4)]
    assert max(ks) - min(ks) <= 6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_reconstruction_error_never_worse_than_wonly(seed):
    """Property: any rank-r correction (QER or SRR) ≥-improves on w-only."""
    key = jax.random.PRNGKey(seed)
    w = planted_lowrank(key, 96, 64, rank_sig=4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (256, 96))
    s = make_scaling("qera-approx", x)
    r = 8
    e_w = scaled_error(w, w_only(w, QZ, r), s)
    e_q = scaled_error(w, qer_decompose(w, s, QZ, r, exact=True), s)
    res = srr_decompose(w, s, QZ, r, jax.random.fold_in(key, 2), exact=True)
    e_s = scaled_error(w, res.decomposition, s)
    assert float(e_q) <= float(e_w) + 1e-5
    assert float(e_s) <= float(e_w) + 1e-5


# ---------------------------------------------------------------------------
# Scalings
# ---------------------------------------------------------------------------
def test_scaling_inverse_roundtrip(calib_x):
    s = qera_exact_scaling(calib_x)
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    np.testing.assert_allclose(np.asarray(s.apply_inv(s.apply(w))),
                               np.asarray(w), atol=1e-3)


def test_diag_scalings_positive(calib_x):
    for kind in ("lqer", "qera-approx"):
        s = make_scaling(kind, calib_x)
        assert bool(jnp.all(s.diag > 0))


def test_qera_exact_minimizes_output_error(calib_x):
    """S = (E xxᵀ)^½ ⇒ ‖SΔ‖_F² = E‖xΔ‖² — scaled error equals true
    expected output error, which diagonal scalings only approximate."""
    x = calib_x
    w = planted_lowrank(jax.random.PRNGKey(9), 256, 128)
    r = 16
    errs = {}
    for kind in ("identity", "lqer", "qera-approx", "qera-exact"):
        s = make_scaling(kind, x)
        dec = qer_decompose(w, s, QZ, r, exact=True)
        # true output-space error on the calibration sample
        errs[kind] = float(jnp.linalg.norm(x @ (w - dec.reconstruct())))
    assert errs["qera-exact"] <= min(errs.values()) * 1.02
