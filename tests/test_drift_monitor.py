"""Runtime accuracy-drift monitor (ServeConfig(drift_monitor=True)).

The flagship guarantee is behavioral invisibility: a monitored engine
emits exactly the tokens a bare one does, on the busiest path we serve
(paged + int4 KV + fused kernels). The remaining tests pin the metric
surface — sampled shadow checks populate the KL / agreement / delta
series, a NaN-poisoned model trips the non-finite guard, and the
ServeConfig validation rejects unusable combinations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.api import PTQConfig
from repro.models import init_lm
from repro.models.quantize import quantize_model_params
from repro.quant.base import QuantizerConfig
from repro.serve import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def tiny_q(tiny):
    """Quantized params: serving vs reference lowering only diverge in a
    measurable way once real SRR containers are in the tree."""
    cfg, params = tiny
    ptq = PTQConfig(method="srr", scaling="identity", rank=4,
                    quantizer=QuantizerConfig(kind="mxint", bits=3,
                                              block_size=32))
    qparams, _ = quantize_model_params(params, None, ptq)
    return cfg, qparams


def _reqs(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=5 + (i % 3))
                    .astype(np.int32))
            for i in range(n)]


def _engine(cfg, params, **kw):
    defaults = dict(max_len=64, decode_batch=2, max_new_tokens=6,
                    prefill_len=16, scheduler="continuous")
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


# ---------------------------------------------------------------------------
# token parity: the monitor must be behaviorally invisible
# ---------------------------------------------------------------------------
def test_monitor_is_token_invisible_paged_int4_fused(tiny_q):
    cfg, qparams = tiny_q

    def run(monitor):
        eng = _engine(cfg, qparams, paged=True, kv_dtype="int4",
                      page_size=8, fused="on", drift_monitor=monitor,
                      drift_sample_rate=1.0)
        out = eng.generate(_reqs(cfg, 4))
        return [list(r.tokens) for r in sorted(out, key=lambda r: r.uid)]

    assert run(False) == run(True)


# ---------------------------------------------------------------------------
# metric surface
# ---------------------------------------------------------------------------
def test_monitor_populates_drift_series(tiny_q):
    cfg, qparams = tiny_q
    eng = _engine(cfg, qparams, drift_monitor=True, drift_sample_rate=1.0)
    eng.generate(_reqs(cfg, 3))
    st = eng.stats()
    assert st["drift_checks"] > 0
    assert 0.0 <= st["drift_top1_agreement_rate"] <= 1.0
    assert st["drift_top1_agree"] <= st["drift_checks"]
    # clean weights on both lowerings: nothing non-finite, no OOB tokens
    assert st["drift_nonfinite"] == 0
    assert st["guard_token_oob"] == 0
    assert st["drift_kl"]["count"] == st["drift_checks"]
    assert st["drift_logit_delta"]["count"] == st["drift_checks"]
    # the dequant reference sees the same containers: divergence is
    # lowering round-off, not model error
    assert st["drift_kl"]["max"] < 1e-2


def test_sample_rate_thins_checks(tiny_q):
    cfg, qparams = tiny_q

    def checks(rate):
        eng = _engine(cfg, qparams, drift_monitor=True,
                      drift_sample_rate=rate)
        eng.generate(_reqs(cfg, 3))
        return eng.stats()["drift_checks"]

    full, thinned = checks(1.0), checks(0.25)
    assert full > 0
    assert thinned < full


def test_monitor_off_publishes_zeroed_series(tiny_q):
    """The metric names exist either way (schema pins them); off means
    zero checks and a vacuous agreement rate of 1.0."""
    cfg, qparams = tiny_q
    eng = _engine(cfg, qparams)
    eng.generate(_reqs(cfg, 2))
    st = eng.stats()
    assert st["drift_checks"] == 0
    assert st["drift_top1_agree"] == 0
    assert st["drift_nonfinite"] == 0
    assert st["drift_top1_agreement_rate"] == 1.0
    assert st["drift_kl"]["count"] == 0


def test_nan_injection_trips_guard(tiny):
    """Poison every float leaf: the shadow probe must count non-finite
    logits instead of letting the collapse pass silently."""
    cfg, params = tiny
    bad = jax.tree_util.tree_map(
        lambda x: (jnp.full_like(x, jnp.nan)
                   if jnp.issubdtype(x.dtype, jnp.floating) else x),
        params)
    eng = _engine(cfg, bad, drift_monitor=True, drift_sample_rate=1.0,
                  max_new_tokens=3)
    eng.generate(_reqs(cfg, 2))
    st = eng.stats()
    assert st["drift_checks"] > 0
    assert st["drift_nonfinite"] > 0


# ---------------------------------------------------------------------------
# configuration validation
# ---------------------------------------------------------------------------
def test_monitor_requires_continuous_scheduler(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="drift_monitor"):
        Engine(params, cfg, ServeConfig(scheduler="bucketed",
                                        drift_monitor=True))


@pytest.mark.parametrize("rate", [0.0, -0.5, 1.5])
def test_monitor_rejects_bad_sample_rate(tiny, rate):
    cfg, params = tiny
    with pytest.raises(ValueError, match="drift_sample_rate"):
        Engine(params, cfg, ServeConfig(scheduler="continuous",
                                        drift_monitor=True,
                                        drift_sample_rate=rate))


def test_rejects_unknown_reference_lowering(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="drift_ref_fused"):
        Engine(params, cfg, ServeConfig(drift_ref_fused="kernelz"))
