"""repro-lint (tools/analysis): fixture corpora, baseline round-trip,
exit codes, and the api-drift repo contracts.

The analyzer is pure stdlib-AST — these tests never execute the fixture
code, so they run in milliseconds and need no accelerator.
"""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.analysis import api_drift                      # noqa: E402
from tools.analysis.core import (BaselineError, load_baseline,  # noqa: E402
                                 load_constraints, parse_modules,
                                 save_baseline)
from tools.analysis.run import analyze, main              # noqa: E402

FIXTURES = os.path.join(REPO, "tools", "analysis", "fixtures")
KNOWN_BAD = os.path.join(FIXTURES, "known_bad")
KNOWN_CLEAN = os.path.join(FIXTURES, "known_clean")


# ---------------------------------------------------------------------------
# fixture corpora: every expected code fires, clean stays clean
# ---------------------------------------------------------------------------
def test_known_bad_fires_every_pass():
    codes = {f.code for f in analyze([KNOWN_BAD], REPO)}
    assert {"PAL001", "PAL002", "PAL003", "PAL004",
            "JIT001", "JIT002", "JIT003", "JIT004",
            "LCK001", "LCK002"} <= codes


def test_known_bad_finding_details():
    findings = analyze([KNOWN_BAD], REPO)
    by_code = {}
    for f in findings:
        by_code.setdefault(f.code, []).append(f)
    # PAL001 names the unguarded numerator and the offending function
    (pal1,) = by_code["PAL001"]
    assert "s // bs" in pal1.message and "unguarded_grid" in pal1.message
    # LCK002 only fires inside the handler class
    assert all("Handler" in f.message for f in by_code["LCK002"])
    # the alias `eng = self.engine` does not launder the missing lock
    assert any("stats" in f.message for f in by_code["LCK001"])
    # keys carry no line numbers — stable across unrelated edits
    assert all(":" + str(f.line) not in f.key.split(" ", 1)[1]
               or True for f in findings)
    assert all(str(f.line) not in f.key.split(":", 1)[0]
               for f in findings)


def test_known_clean_is_clean():
    assert analyze([KNOWN_CLEAN], REPO) == []


def test_repo_is_clean_against_checked_in_baseline(capsys):
    rc = main([os.path.join(REPO, "src"), os.path.join(REPO, "tests"),
               os.path.join(REPO, "benchmarks"), "--root", REPO])
    out = capsys.readouterr()
    assert rc == 0, out.out + out.err
    assert "0 new" in out.err


def test_checked_in_baseline_is_fully_justified():
    baseline = load_baseline(os.path.join(REPO, "tools", "analysis",
                                          "baseline.txt"))
    assert baseline, "baseline should carry the documented suppressions"
    assert all(why and "TODO" not in why for why in baseline.values())


# ---------------------------------------------------------------------------
# exit codes and baseline round-trip
# ---------------------------------------------------------------------------
def test_exit_codes(tmp_path, capsys):
    assert main([KNOWN_BAD, "--root", REPO, "--baseline", "none"]) == 1
    assert main([KNOWN_CLEAN, "--root", REPO, "--baseline", "none"]) == 0
    bad = tmp_path / "baseline.txt"
    bad.write_text("PAL001 some/file.py:fn:x\n")   # no justification
    assert main([KNOWN_CLEAN, "--root", REPO,
                 "--baseline", str(bad)]) == 2
    capsys.readouterr()


def test_baseline_suppresses_and_reports_stale(tmp_path, capsys):
    findings = analyze([KNOWN_BAD], REPO)
    path = tmp_path / "baseline.txt"
    save_baseline(str(path), findings, {k.key: "expected by fixture"
                                        for k in findings})
    # everything suppressed -> clean
    assert main([KNOWN_BAD, "--root", REPO, "--baseline", str(path)]) == 0
    capsys.readouterr()
    # an entry whose finding no longer fires is stale: reported, and
    # --strict turns it into a failure
    with open(path, "a") as f:
        f.write("PAL001 gone/file.py:fn:x  # obsolete\n")
    assert main([KNOWN_BAD, "--root", REPO, "--baseline", str(path)]) == 0
    assert "stale" in capsys.readouterr().err
    assert main([KNOWN_BAD, "--root", REPO, "--baseline", str(path),
                 "--strict"]) == 1
    capsys.readouterr()


def test_update_baseline_keeps_justifications(tmp_path, capsys):
    path = tmp_path / "baseline.txt"
    rc = main([KNOWN_BAD, "--root", REPO, "--baseline", str(path),
               "--update-baseline"])
    assert rc == 0
    entries = load_baseline(str(path))
    assert entries and all("TODO" in why for why in entries.values())
    # hand-justify one entry; regeneration must preserve it
    key = sorted(entries)[0]
    text = path.read_text().replace(
        f"{key}  # TODO: justify or fix", f"{key}  # fixture-intended")
    path.write_text(text)
    main([KNOWN_BAD, "--root", REPO, "--baseline", str(path),
          "--update-baseline"])
    assert load_baseline(str(path))[key] == "fixture-intended"
    capsys.readouterr()


def test_unjustified_baseline_entry_rejected(tmp_path):
    path = tmp_path / "b.txt"
    path.write_text("JIT001 a.py:f:x\n")
    with pytest.raises(BaselineError):
        load_baseline(str(path))


def test_output_artifact(tmp_path, capsys):
    out = tmp_path / "findings.txt"
    main([KNOWN_BAD, "--root", REPO, "--baseline", "none",
          "--output", str(out)])
    text = out.read_text()
    assert "NEW" in text and "PAL001" in text
    capsys.readouterr()


# ---------------------------------------------------------------------------
# constraints are shared, not copied
# ---------------------------------------------------------------------------
def test_analyzer_imports_kernel_constraints():
    from repro.kernels import constraints
    kc = load_constraints(REPO)
    assert kc.min_sublane_tile == constraints.MIN_SUBLANE_TILE
    assert kc.min_sublane_tile_packed4 == constraints.MIN_SUBLANE_TILE_PACKED4
    assert kc.vmem_budget_bytes == constraints.VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# api-drift: both directions actually trip
# ---------------------------------------------------------------------------
def _modules_from(tmp_path, name, source):
    p = tmp_path / name
    p.write_text(source)
    mods, errs = parse_modules([str(p)], str(tmp_path))
    assert not errs
    return mods


def test_api_drift_metric_missing_from_schema(tmp_path):
    mods = _modules_from(tmp_path, "src_tel.py",
                         'reg.counter("brand_new_metric")\n')
    schema = tmp_path / "schema.json"
    schema.write_text(json.dumps({"properties": {"known": {}}}))
    findings = api_drift.check_metrics(mods, str(schema))
    assert {"API001", "API002"} == {f.code for f in findings}
    assert any("brand_new_metric" in f.message for f in findings)
    assert any("known" in f.message for f in findings)


def test_api_drift_fstring_family_covers_schema(tmp_path):
    mods = _modules_from(
        tmp_path, "src_tel.py",
        'for p in phases:\n'
        '    reg.histogram(f"step_{p}_seconds")\n')
    schema = tmp_path / "schema.json"
    schema.write_text(json.dumps(
        {"properties": {"step_decode_seconds": {},
                        "step_prefill_seconds": {}}}))
    assert api_drift.check_metrics(mods, str(schema)) == []


def test_api_drift_serve_config_contract(tmp_path):
    engine = _modules_from(
        tmp_path, "engine.py",
        "class ServeConfig:\n"
        "    plumbed: int = 0\n"
        "    orphaned: int = 1\n")[0]
    launch = _modules_from(
        tmp_path, "launch_cli.py",
        "cfg = ServeConfig(plumbed=args.plumbed)\n")
    readme = tmp_path / "README.md"
    readme.write_text("only `plumbed` is documented\n")
    findings = api_drift.check_serve_config(engine, launch, str(readme))
    assert {(f.code, "orphaned" in f.message) for f in findings} == \
        {("API003", True), ("API004", True)}
