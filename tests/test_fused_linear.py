"""Numerics tier for the fused Q+LR serving path.

Pins the fused matmul — every entry point (per-weight kernel, in-kernel
sliver, batched stack, fused-XLA lowering) — to the pure-jnp oracle in
``kernels/ref.py``, across quantizer families (MXINT, uniform, GPTQ):
the kernel only assumes the ``codes × per-block-scale`` layout, so any
symmetric block quantizer must round-trip through it exactly. On top,
mode-parity tests assert that ``linear()`` / MoE dispatch / the serving
engine emit identical results whichever ``fused`` mode executes them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    mxint_lowrank_matmul,
    mxint_lowrank_matmul_batched,
    qlr_matmul,
    qlr_matmul_batched,
)
from repro.kernels.ref import mxint_lowrank_matmul_ref
from repro.models.linear import Ctx, fused_mode, linear
from repro.quant import MXIntQuantizer, UniformQuantizer
from repro.quant.gptq import GPTQQuantizer
from repro.quant.mxint import pack_codes_4bit


def _quantize(kind: str, bits: int, w: jax.Array, block: int = 32):
    """(codes, scale) in the kernel layout for any supported quantizer."""
    if kind == "mxint":
        p = MXIntQuantizer(bits=bits, block_size=block).quantize(w)
        return p.codes, jnp.exp2(p.exponents.astype(jnp.float32))
    if kind == "uniform":
        p = UniformQuantizer(bits=bits, group_size=block,
                             symmetric=True).quantize(w)
        return p.codes, p.scales
    if kind == "gptq":
        k = w.shape[0]
        x = jax.random.normal(jax.random.PRNGKey(3), (4 * k, k))
        h = x.T @ x / x.shape[0]
        q = GPTQQuantizer(bits=bits, group_size=block,
                          symmetric=True).make_bound(h)
        p = q.quantize(w)
        return p.codes, p.scales
    raise ValueError(kind)


def _qlr_case(kind: str, bits: int, m=16, k=128, n=96, r=8):
    key = jax.random.PRNGKey(bits + len(kind))
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    codes, scale = _quantize(kind, bits, w)
    l = jax.random.normal(jax.random.fold_in(key, 2), (k, r)) * 0.1
    rr = jax.random.normal(jax.random.fold_in(key, 3), (r, n)) * 0.1
    return x, codes, scale, l, rr


# ---------------------------------------------------------------------------
# Kernel entry points vs the jnp oracle, across quantizer families
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind,bits", [
    ("mxint", 2), ("mxint", 3), ("mxint", 4),
    ("uniform", 3), ("uniform", 4),
    ("gptq", 3),
])
def test_kernel_matches_ref_across_quantizers(kind, bits):
    x, codes, scale, l, rr = _qlr_case(kind, bits)
    ref = mxint_lowrank_matmul_ref(x, codes, scale, l, rr)
    for label, y in [
        ("kernel", mxint_lowrank_matmul(x, codes, scale, l, rr)),
        ("kernel+sliver", mxint_lowrank_matmul(x, codes, scale, l, rr,
                                               fuse_sliver=True)),
        ("xla", qlr_matmul(x, codes, scale, l, rr, kernel=False)),
    ]:
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3, err_msg=label)


@pytest.mark.parametrize("m,k,n,r", [
    (8, 256, 128, 16),
    (1, 512, 256, 0),       # decode row, rank-0
    (130, 512, 384, 64),    # ragged M
])
def test_fused_sliver_kernel_matches_plain(m, k, n, r):
    """In-kernel sliver accumulation ≡ precomputed-xl kernel."""
    key = jax.random.PRNGKey(m + k)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    codes, scale = _quantize("mxint", 3, w)
    l = (jax.random.normal(jax.random.fold_in(key, 2), (k, r))
         if r else jnp.zeros((k, 0)))
    rr = (jax.random.normal(jax.random.fold_in(key, 3), (r, n))
          if r else jnp.zeros((0, n)))
    y0 = mxint_lowrank_matmul(x, codes, scale, l, rr)
    y1 = mxint_lowrank_matmul(x, codes, scale, l, rr, fuse_sliver=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("g,m,k,n,r", [(3, 16, 256, 128, 8),
                                       (2, 8, 96, 64, 0)])
def test_batched_kernel_matches_ref(g, m, k, n, r):
    key = jax.random.PRNGKey(g * m)
    x = jax.random.normal(key, (g, m, k))
    qz = MXIntQuantizer(bits=3, block_size=32)
    packs = [qz.quantize(jax.random.normal(jax.random.fold_in(key, i), (k, n)))
             for i in range(g)]
    codes = jnp.stack([p.codes for p in packs])
    scale = jnp.stack([jnp.exp2(p.exponents.astype(jnp.float32))
                       for p in packs])
    l = (jax.random.normal(jax.random.fold_in(key, 7), (g, k, r))
         if r else jnp.zeros((g, k, 0)))
    rr = (jax.random.normal(jax.random.fold_in(key, 8), (g, r, n))
          if r else jnp.zeros((g, 0, n)))
    for kernel in (True, False):
        y = (mxint_lowrank_matmul_batched(x, codes, scale, l, rr) if kernel
             else qlr_matmul_batched(x, codes, scale, l, rr, kernel=False))
        for i in range(g):
            ref = mxint_lowrank_matmul_ref(x[i], codes[i], scale[i],
                                           l[i], rr[i])
            np.testing.assert_allclose(np.asarray(y[i]), np.asarray(ref),
                                       rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# linear() mode parity
# ---------------------------------------------------------------------------
def _linear_params(key, m, n, r, container="codes", bits=3):
    w = jax.random.normal(key, (m, n))
    p0 = MXIntQuantizer(bits=bits, block_size=32).quantize(w)
    p = {"scale": jnp.exp2(p0.exponents.astype(jnp.float32)),
         "l": jax.random.normal(jax.random.fold_in(key, 1), (m, r)) * 0.1,
         "r": jax.random.normal(jax.random.fold_in(key, 2), (r, n)) * 0.1,
         "b": jax.random.normal(jax.random.fold_in(key, 3), (n,))}
    if container == "packed":
        p["packed"] = pack_codes_4bit(p0.codes)
    else:
        p["codes"] = p0.codes
    return p


@pytest.mark.parametrize("m,container,bits", [
    (96, "codes", 3),
    (80, "codes", 3),      # MXINT row padding (80 → 96)
    (96, "packed", 4),
    (80, "packed", 4),     # padding + packed4 container
])
def test_linear_fused_modes_agree(m, container, bits):
    key = jax.random.PRNGKey(m + bits)
    params = _linear_params(key, m, 64, 8, container, bits)
    x = jax.random.normal(jax.random.fold_in(key, 9), (2, 5, m))
    y_off = linear(Ctx(fused="off"), params, x)
    y_auto = linear(Ctx(fused="auto"), params, x)
    y_on = linear(Ctx(fused="on"), params, x)
    np.testing.assert_allclose(np.asarray(y_off), np.asarray(y_auto),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_off), np.asarray(y_on),
                               rtol=1e-4, atol=1e-3)


def test_fused_mode_resolution():
    assert fused_mode(Ctx(fused="off")) == "off"
    assert fused_mode(Ctx(fused="on")) == "kernel"
    assert fused_mode(Ctx(fused="auto", use_pallas=True)) == "kernel"
    expected = "kernel" if jax.default_backend() == "tpu" else "xla"
    assert fused_mode(Ctx()) == expected
    with pytest.raises(ValueError):
        fused_mode(Ctx(fused="always"))


# ---------------------------------------------------------------------------
# MoE fused expert dispatch parity
# ---------------------------------------------------------------------------
def test_moe_fused_dispatch_parity():
    from repro.configs import get_config
    from repro.core.api import PTQConfig
    from repro.models import moe as moe_mod
    from repro.models.quantize import quantize_model_params
    from repro.quant.base import QuantizerConfig

    cfg = get_config("deepseek-moe-16b").reduced()
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    ptq = PTQConfig(method="srr", scaling="identity", rank=4,
                    quantizer=QuantizerConfig(kind="mxint", bits=3,
                                              block_size=32))
    qp, _ = quantize_model_params(p, None, ptq)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))
    y_off, aux_off = moe_mod.moe_apply(Ctx(fused="off"), qp, x, cfg)
    y_on, aux_on = moe_mod.moe_apply(Ctx(fused="on"), qp, x, cfg)
    np.testing.assert_allclose(np.asarray(y_off), np.asarray(y_on),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_off), float(aux_on), rtol=1e-6)


# ---------------------------------------------------------------------------
# Serving engine: fused decode emits the same tokens
# ---------------------------------------------------------------------------
def test_engine_fused_token_parity():
    from repro.configs import get_config
    from repro.core.api import PTQConfig
    from repro.models import init_lm
    from repro.models.quantize import quantize_model_params
    from repro.quant.base import QuantizerConfig
    from repro.serve import Engine, Request, ServeConfig

    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ptq = PTQConfig(method="srr", scaling="identity", rank=8,
                    quantizer=QuantizerConfig(kind="mxint", bits=3,
                                              block_size=32))
    qparams, _ = quantize_model_params(params, None, ptq)

    rng = np.random.default_rng(0)
    def reqs():
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, size=5 + 3 * i)
                        .astype(np.int32), max_new_tokens=4)
                for i in range(3)]

    outs = {}
    for mode in ("off", "auto"):
        sc = ServeConfig(max_len=48, decode_batch=2, max_new_tokens=4,
                         prefill_len=16, fused=mode)
        eng = Engine(qparams, cfg, sc)
        rng = np.random.default_rng(0)
        outs[mode] = eng.generate(reqs())
    for a, b in zip(outs["off"], outs["auto"]):
        assert a.uid == b.uid
        np.testing.assert_array_equal(a.tokens, b.tokens)
