"""Token-budget step scheduler, per-request sampling, abort lifecycle."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, SamplingParams, ServeConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _engine(cfg, params, **kw):
    defaults = dict(max_len=64, decode_batch=3, max_new_tokens=6,
                    prefill_len=16, scheduler="continuous")
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


def _reqs(cfg, n, base_len=5, budget=None, params=None):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=base_len + (i % 3))
                    .astype(np.int32),
                    max_new_tokens=budget[i] if budget else None,
                    params=params[i] if params else None)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Budget invariants
# ---------------------------------------------------------------------------
def _drain_counting(eng):
    """Step the engine to empty, asserting the per-step charge invariant
    from the stats deltas: prefill dispatches at compiled width + decode
    lanes never exceed max_step_tokens."""
    limit = eng.sc.max_step_tokens
    unit = eng._step_unit
    results = []
    while eng.sched.has_work:
        s0 = eng.sched.stats
        chunks0 = getattr(eng, "_prefill_chunks", 0)
        dec0, adm0 = s0.decode_slot_steps, s0.admitted
        results.extend(eng.step())
        s1 = eng.sched.stats
        chunks1 = getattr(eng, "_prefill_chunks", 0)
        spent = (chunks1 - chunks0) * unit if eng.sc.paged \
            else (s1.admitted - adm0) * unit
        spent += s1.decode_slot_steps - dec0
        assert spent <= limit, f"step spent {spent} > budget {limit}"
    results.sort(key=lambda r: r.uid)
    return results


def test_budget_never_exceeded_paged(tiny):
    """Under a burst of multi-chunk prompts the per-step work stays
    within max_step_tokens, and the deferral counters tick."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, page_size=8, max_len=160,
                  prefill_len=16, decode_batch=4, prefix_cache=False,
                  max_new_tokens=8, max_step_tokens=16 + 4)
    rng = np.random.default_rng(1)
    for i in range(6):   # all multi-chunk prompts, arriving at once
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab, size=40 + i).astype(np.int32)))
    res = _drain_counting(eng)
    assert [r.uid for r in res] == list(range(6))
    assert all(len(r.tokens) == 8 for r in res)
    st = eng.stats()
    assert st["budget_deferred_admissions"] > 0 \
        or st["budget_capped_chunks"] > 0


def test_budget_never_exceeded_unpaged(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, max_step_tokens=16 + 1, decode_batch=3)
    for r in _reqs(cfg, 6):
        eng.submit(r)
    res = _drain_counting(eng)
    assert [r.uid for r in res] == list(range(6))
    st = eng.stats()
    assert st["budget_deferred_admissions"] > 0


def test_budget_does_not_change_tokens(tiny):
    """Scheduling-independent sampling: the budget defers work but must
    never change any request's output."""
    cfg, params = tiny
    sp = [None, SamplingParams(temperature=0.8, seed=7), None,
          SamplingParams(temperature=1.2, top_k=9), None, None]
    outs = []
    for mst in (None, 17):
        eng = _engine(cfg, params, paged=True, page_size=8, max_len=160,
                      prefill_len=16, decode_batch=4, max_new_tokens=8,
                      max_step_tokens=mst)
        outs.append(eng.generate(_reqs(cfg, 6, base_len=30, params=sp)))
    for a, b in zip(*outs):
        assert a.uid == b.uid
        assert a.tokens.tolist() == b.tokens.tolist()
        assert a.finish_reason == b.finish_reason


def test_budget_validation():
    cfg = get_config("phi3-mini-3.8b").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="max_step_tokens"):
        _engine(cfg, params, max_step_tokens=8, prefill_len=16)
    with pytest.raises(ValueError, match="continuous"):
        _engine(cfg, params, scheduler="bucketed", max_step_tokens=64)


# ---------------------------------------------------------------------------
# Page quota + watermark eviction
# ---------------------------------------------------------------------------
def test_page_quota_clamps_budget(tiny):
    """max_pages_per_request caps prompt+generation pages: generation
    stops when the quota's last page fills."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, page_size=8, max_len=160,
                  prefill_len=16, max_new_tokens=64,
                  max_pages_per_request=2)
    rng = np.random.default_rng(0)
    res = eng.generate([Request(uid=0, prompt=rng.integers(
        0, cfg.vocab, size=10).astype(np.int32))])
    # 2 pages * 8 slots - 10 prompt tokens = 6 generated tokens
    assert len(res[0].tokens) == 6
    assert res[0].finish_reason == "length"

    with pytest.raises(ValueError, match="max_pages_per_request"):
        eng.submit(Request(uid=1, prompt=rng.integers(
            0, cfg.vocab, size=16).astype(np.int32)))


def test_quota_watermark_need_paged(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="paged"):
        _engine(cfg, params, max_pages_per_request=2)
    with pytest.raises(ValueError, match="paged"):
        _engine(cfg, params, free_watermark=0.5)


def test_watermark_evicts_cold_pages(tiny):
    """free_watermark drains cold prefix pages ahead of demand."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, page_size=8, max_len=64,
                  prefill_len=16, max_new_tokens=4, free_watermark=0.9)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=20).astype(np.int32)
    eng.generate([Request(uid=0, prompt=prompt)])
    # the retired request's full prompt blocks sit cold in the tree;
    # the next step's watermark pass must reclaim them
    assert eng.pool.n_cold > 0
    eng.submit(Request(uid=1, prompt=prompt[:5].copy()))
    eng.drain()
    st = eng.stats()
    assert st["watermark_evictions"] > 0
    assert eng.pool.n_cold == 0 or eng.pool.n_free >= int(
        0.9 * eng.pool.n_pages)


# ---------------------------------------------------------------------------
# Abort lifecycle (incl. the mid-prefill refcount regression)
# ---------------------------------------------------------------------------
def test_abort_queued_and_decoding(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, max_new_tokens=20)
    for r in _reqs(cfg, 4):
        eng.submit(r)
    eng.step()           # admits up to 3, request 3 still queued
    res_q = eng.abort(3)
    assert res_q.finish_reason == "abort" and len(res_q.tokens) == 0
    res_d = eng.abort(0)
    assert res_d.finish_reason == "abort"
    assert len(res_d.tokens) >= 1          # partial output returned
    assert eng.abort(99) is None
    rest = eng.drain()
    assert [r.uid for r in rest] == [1, 2]
    assert eng.sched.stats.aborted == 2


def test_abort_mid_prefill_releases_pages(tiny):
    """Regression: aborting a request whose chunked prefill has not
    finished must decref its mapped pages — before the fix the
    _PrefillJob kept the rows referenced and the pool leaked."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, page_size=8, max_len=160,
                  prefill_len=8, decode_batch=3, max_new_tokens=8)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=30).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt))
    eng.step()           # admit + first chunk only (8 of 30 tokens)
    assert 0 in [st.uid for st in eng.sched.table.active.values()]
    assert eng._prefill_jobs, "prefill must still be in flight"
    res = eng.abort(0)
    assert res.finish_reason == "abort" and len(res.tokens) == 0
    # only the parked pages stay hot: nothing leaked
    assert eng.pool.n_hot == eng.sc.decode_batch
    assert not eng._prefill_jobs
    # the engine still serves new work afterwards
    out = eng.generate([Request(uid=1, prompt=prompt.copy())])
    assert len(out[0].tokens) == 8


def test_abort_mid_prefill_with_prefix_match(tiny):
    """Same regression with prefix-matched pages in the row: the abort
    releases the reference the match took, so the shared pages go back
    to cold (revivable) instead of leaking hot."""
    cfg, params = tiny
    eng = _engine(cfg, params, paged=True, page_size=8, max_len=160,
                  prefill_len=8, decode_batch=3, max_new_tokens=8)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=30).astype(np.int32)
    eng.generate([Request(uid=0, prompt=prompt)])   # 3 blocks in the tree
    # shares 24 prompt tokens (3 full blocks), then diverges for 30 more
    # — the match leaves >1 chunk of prefill, so the job stays in flight
    tail = rng.integers(0, cfg.vocab, size=30).astype(np.int32)
    prompt2 = np.concatenate([prompt[:24], tail])
    eng.submit(Request(uid=1, prompt=prompt2))
    eng.step()
    job = next(iter(eng._prefill_jobs.values()), None)
    assert job is not None and job.matched_tokens == 24
    eng.abort(1)
    assert eng.pool.n_hot == eng.sc.decode_batch   # parked pages only
    assert eng.pool.n_cold == 3                    # match ref released


# ---------------------------------------------------------------------------
# Per-request sampling semantics
# ---------------------------------------------------------------------------
def test_mixed_greedy_temperature_parity(tiny):
    """A greedy lane co-batched with sampled lanes produces exactly the
    all-greedy output — filters and draws never touch greedy rows."""
    cfg, params = tiny
    reqs_greedy = _reqs(cfg, 3)
    ref = _engine(cfg, params).generate(reqs_greedy)

    sp = [None, SamplingParams(temperature=1.0, top_p=0.9, seed=3),
          SamplingParams(temperature=0.7, top_k=11, seed=4)]
    mixed = _engine(cfg, params).generate(_reqs(cfg, 3, params=sp))
    assert mixed[0].tokens.tolist() == ref[0].tokens.tolist()


def test_seed_determinism(tiny):
    cfg, params = tiny
    sp = [SamplingParams(temperature=1.0, seed=42) for _ in range(2)]
    eng = _engine(cfg, params)
    a = eng.generate(_reqs(cfg, 2, base_len=5, params=sp))
    b = eng.generate(_reqs(cfg, 2, base_len=5, params=sp))
    # same seed, same prompt → same tokens across runs; requests 0 and 1
    # share seed AND prompt-length-5? no — lengths differ by uid, so
    # only cross-run equality is asserted
    for x, y in zip(a, b):
        assert x.tokens.tolist() == y.tokens.tolist()

    sp2 = [SamplingParams(temperature=1.0, seed=43) for _ in range(2)]
    c = eng.generate(_reqs(cfg, 2, base_len=5, params=sp2))
    assert any(x.tokens.tolist() != y.tokens.tolist()
               for x, y in zip(a, c))


def test_stop_token_truncates_with_reason(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params, max_new_tokens=12)
    probe = eng.generate(_reqs(cfg, 1))
    stop = int(probe[0].tokens[2])
    # greedy decode may emit the stop id before position 2 too — the
    # truncation point is its first occurrence
    cut = probe[0].tokens.tolist().index(stop)
    res = eng.generate(_reqs(
        cfg, 1, params=[SamplingParams(stop=(stop,), max_new_tokens=12)]))
    assert res[0].tokens[-1] == stop
    assert len(res[0].tokens) == cut + 1
    assert res[0].finish_reason == "stop"
    full = eng.generate(_reqs(cfg, 1))
    assert full[0].finish_reason == "length"


def test_top_k1_equals_greedy(tiny):
    """top_k=1 leaves only the argmax in the kept set, so a sampled
    lane at any temperature degenerates to the greedy stream."""
    cfg, params = tiny
    ref = _engine(cfg, params).generate(_reqs(cfg, 2))
    sp = [SamplingParams(temperature=1.3, top_k=1, seed=5 + i)
          for i in range(2)]
    res = _engine(cfg, params).generate(_reqs(cfg, 2, params=sp))
    for r, g in zip(res, ref):
        assert r.tokens.tolist() == g.tokens.tolist()


def test_top_p_one_equals_plain_temperature(tiny):
    """top_p=1.0 must be a true no-op filter: identical draws to the
    same seed with no nucleus cut and to top_k=vocab (the other no-op
    spelling) — while a real cut (top_p=0.5) moves the stream, proving
    the filter is live and the equality isn't vacuous."""
    cfg, params = tiny
    mk = lambda sp: _engine(cfg, params).generate(   # noqa: E731
        _reqs(cfg, 2, params=[sp, sp]))
    plain = mk(SamplingParams(temperature=0.9, seed=11))
    nucleus_off = mk(SamplingParams(temperature=0.9, top_p=1.0, seed=11))
    topk_full = mk(SamplingParams(temperature=0.9, top_k=cfg.vocab,
                                  seed=11))
    for a, b, c in zip(plain, nucleus_off, topk_full):
        assert a.tokens.tolist() == b.tokens.tolist()
        assert a.tokens.tolist() == c.tokens.tolist()
    cut = mk(SamplingParams(temperature=0.9, top_p=0.5, seed=11))
    assert any(a.tokens.tolist() != d.tokens.tolist()
               for a, d in zip(plain, cut))


def test_params_validation(tiny):
    cfg, params = tiny
    eng = _engine(cfg, params)
    bad = [SamplingParams(temperature=-1.0), SamplingParams(top_p=0.0),
           SamplingParams(top_k=-2), SamplingParams(max_new_tokens=-1)]
    for sp in bad:
        with pytest.raises(ValueError, match="request 0"):
            eng.submit(Request(uid=0,
                               prompt=np.zeros((3,), np.int32), params=sp))


def test_bucketed_matches_continuous_with_sampling(tiny):
    """The bucketed baseline and the continuous engine agree token-for-
    token per request under mixed per-request sampling params."""
    cfg, params = tiny
    sp = [None, SamplingParams(temperature=0.9, top_p=0.85, seed=5),
          SamplingParams(temperature=1.1, top_k=6)]
    reqs = lambda: _reqs(cfg, 3, base_len=5, params=sp)   # noqa: E731
    cont = _engine(cfg, params).generate(reqs(), seed=9)
    buck = _engine(cfg, params, scheduler="bucketed").generate(
        reqs(), seed=9)
    for c, b in zip(cont, buck):
        assert c.uid == b.uid
        assert c.tokens.tolist() == b.tokens.tolist()
        assert c.finish_reason == b.finish_reason
