"""Serve telemetry: percentiles, registry, tracing, and engine wiring.

Acceptance-criteria coverage for the observability PR: the shared
interpolating percentile against numpy oracles (including the exact
small-n bias the old index shortcut had), histogram bucketing against
``np.searchsorted``, registry snapshot/prometheus form, Chrome-trace
well-formedness, telemetry-on vs -off token parity on the paged int4
fused engine, the zero-budget ``Result`` timing regression, compile
tracking, and the checked-in metrics schema via the CI validator.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import (Engine, MetricsRegistry, Request, ServeConfig,
                         latency_summary, percentile)
from repro.serve.telemetry import Histogram, Telemetry, log_buckets

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Percentile helper vs numpy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 3, 10, 17, 100])
@pytest.mark.parametrize("q", [0.0, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0])
def test_percentile_matches_numpy(n, q):
    rng = np.random.default_rng(n * 1000 + int(q * 100))
    vals = rng.exponential(size=n).tolist()
    assert percentile(vals, q) == pytest.approx(
        float(np.percentile(vals, q * 100)), rel=1e-12)


def test_percentile_fixes_the_old_index_bias():
    """The replaced shortcuts: ``v[int(.95*n)]`` returned the maximum
    of 10 samples for p95, and ``v[n//2]`` is not the even-n median."""
    v = list(range(1, 11))                     # 1..10
    assert v[min(len(v) - 1, int(0.95 * len(v)))] == 10   # old: the max
    assert percentile(v, 0.95) == pytest.approx(9.55)     # interpolated
    assert v[len(v) // 2] == 6                 # old "median" of 10
    assert percentile(v, 0.50) == pytest.approx(5.5)
    assert percentile([1, 2, 3, 4], 0.50) == pytest.approx(2.5)


def test_percentile_rejects_bad_input():
    with pytest.raises(ValueError):
        percentile([], 0.5)
    with pytest.raises(ValueError):
        percentile([1.0], 1.5)


def test_latency_summary():
    s = latency_summary([0.1, 0.2, 0.3], scale=1e3)
    assert s["p50"] == pytest.approx(200.0)
    assert s["max"] == pytest.approx(300.0)
    assert latency_summary([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                                   "mean": 0.0, "max": 0.0}


# ---------------------------------------------------------------------------
# Histogram vs numpy bucketing
# ---------------------------------------------------------------------------
def test_log_buckets_shape():
    b = log_buckets(1e-5, 100.0, per_decade=4)
    assert b[0] == 1e-5 and b[-1] == 100.0
    assert b == sorted(b)
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** 0.25, rel=1e-6) for r in ratios)


def test_histogram_counts_match_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-6, sigma=2, size=500)
    h = Histogram("h")
    for v in samples:
        h.observe(float(v))
    # counts[i] tallies v <= bounds[i] (bisect_left), overflow last
    idx = np.searchsorted(h.bounds, samples, side="left")
    expect = np.bincount(idx, minlength=len(h.bounds) + 1)
    assert h.counts == expect.tolist()
    assert h.count == 500
    assert h.sum == pytest.approx(float(samples.sum()))
    assert h.min == pytest.approx(float(samples.min()))
    assert h.max == pytest.approx(float(samples.max()))


def test_histogram_quantiles_bracket_numpy():
    rng = np.random.default_rng(1)
    samples = rng.lognormal(mean=-3, sigma=1, size=2000)
    h = Histogram("h")
    for v in samples:
        h.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        est = h.quantile(q)
        exact = float(np.quantile(samples, q))
        # bucket-resolution estimate: within one geometric bucket width
        assert est / exact == pytest.approx(1.0, rel=10 ** 0.25 - 1)
        assert h.min <= est <= h.max
    empty = Histogram("e")
    assert empty.quantile(0.5) is None
    assert empty.snapshot()["p50"] is None and empty.snapshot()["count"] == 0


def test_single_observation_quantile_is_the_observation():
    h = Histogram("h")
    h.observe(0.0123)
    for q in (0.5, 0.95, 0.99):
        assert h.quantile(q) == pytest.approx(0.0123)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registry_type_collision_raises():
    reg = MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_registry_snapshot_and_prometheus():
    reg = MetricsRegistry()
    reg.counter("reqs", "requests").inc(3)
    reg.gauge("occ", "occupancy").set(0.5)
    h = reg.histogram("lat", "latency")
    for v in (0.001, 0.01, 0.01, 4.2):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["reqs"] == 3 and isinstance(snap["reqs"], int)
    assert snap["occ"] == 0.5
    assert snap["lat"]["count"] == 4
    assert json.loads(json.dumps(snap)) == snap       # JSON-serializable
    text = reg.prometheus()
    assert "# TYPE reqs counter" in text
    assert "# TYPE occ gauge" in text
    assert "# TYPE lat histogram" in text
    assert 'lat_bucket{le="+Inf"} 4' in text
    assert "lat_count 4" in text
    # cumulative bucket counts must be non-decreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_bucket")]
    assert cums == sorted(cums)


# ---------------------------------------------------------------------------
# Engine wiring: parity, traces, compile tracking, schema
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("phi3-mini-3.8b").reduced()
    return cfg, init_lm(jax.random.PRNGKey(0), cfg)


def _reqs(cfg, n, seed=0, budget=None):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=5 + (i % 3))
                    .astype(np.int32),
                    max_new_tokens=budget)
            for i in range(n)]


def _engine(cfg, params, **kw):
    defaults = dict(max_len=64, decode_batch=2, max_new_tokens=5,
                    prefill_len=16, scheduler="continuous")
    defaults.update(kw)
    return Engine(params, cfg, ServeConfig(**defaults))


@pytest.fixture(scope="module")
def paged_runs(tiny):
    """Paged int4 fused engine run twice: telemetry off and fully on."""
    cfg, params = tiny
    base = dict(kv_dtype="int4", fused="on", paged=True, page_size=8)
    res_off = _engine(cfg, params, **base).generate(_reqs(cfg, 5))
    eng_on = _engine(cfg, params, telemetry=True, trace_sync=True, **base)
    res_on = eng_on.generate(_reqs(cfg, 5))
    return eng_on, res_on, res_off


def test_telemetry_is_behaviorally_invisible(paged_runs):
    _, res_on, res_off = paged_runs
    for a, b in zip(res_off, res_on):
        assert a.uid == b.uid
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_unified_snapshot_preserves_legacy_keys(tiny, paged_runs):
    """Telemetry must only *add* series: every key the disabled engine
    reports appears with the identical value in the enabled snapshot."""
    cfg, params = tiny
    base = dict(kv_dtype="int4", fused="on", paged=True, page_size=8)
    st_off = _engine(cfg, params, **base)
    st_off.generate(_reqs(cfg, 5))
    st_off = st_off.stats()
    st_on = paged_runs[0].stats()
    for key, val in st_off.items():
        assert st_on[key] == val, f"legacy key {key} drifted"
    for key in ("step_seconds", "ttft_seconds", "itl_seconds",
                "prefill_chunk_seconds", "request_latency_seconds"):
        assert key not in st_off           # histograms are telemetry-only
        assert st_on[key]["count"] > 0, f"{key} never observed"
    for ph in ("admission", "prefill", "decode", "transfer"):
        assert st_on[f"step_{ph}_seconds"]["count"] > 0


def test_bucketed_stats_emit_common_keys(tiny):
    """Satellite: the bucketed scheduler reports the same admission /
    retirement counters as continuous, not just occupancy."""
    cfg, params = tiny
    eng = _engine(cfg, params, scheduler="bucketed", decode_batch=4)
    res = eng.generate(_reqs(cfg, 5))
    st = eng.stats()
    assert st["admitted"] == st["retired"] == len(res) == 5
    assert st["eos_retired"] >= 0
    assert st["decode_slot_steps"] > 0


def test_compile_tracking(paged_runs):
    st = paged_runs[0].stats()
    # one decode shape (the whole point of lockstep decode), one chunk
    # shape; first-call wall time recorded as the compile fallback
    assert st["compiled_shapes_decode"] == 1
    assert st["compiled_shapes_prefill_chunk"] == 1
    assert st["dispatches_decode"] > st["compiled_shapes_decode"]
    assert st["first_call_seconds_decode"] > 0
    assert st["compile_seconds_decode"] >= 0


def test_trace_well_formed(paged_runs, tmp_path):
    eng, res_on, _ = paged_runs
    path = tmp_path / "trace.json"
    jsonl = tmp_path / "trace.jsonl"
    eng.write_trace(str(path), jsonl_path=str(jsonl))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events and doc["displayTimeUnit"] == "ms"
    for ev in events:
        assert set(ev) >= {"ph", "name", "pid", "tid", "ts"}
        assert ev["ts"] >= 0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    names = {e["name"] for e in events}
    assert {"queued", "prefill", "first_token", "decode", "retired",
            "step", "admission", "transfer"} <= names
    # every request got its own lifecycle lane (pid 1, tid = uid)
    uids = {e["tid"] for e in events
            if e["pid"] == 1 and e["name"] == "retired"}
    assert uids == {r.uid for r in res_on}
    # the decode span starts at/after first_token on each lane
    for uid in uids:
        ft = [e for e in events if e["pid"] == 1 and e["tid"] == uid
              and e["name"] == "first_token"]
        dec = [e for e in events if e["pid"] == 1 and e["tid"] == uid
               and e["name"] == "decode"]
        assert len(ft) == 1 and len(dec) == 1
        assert dec[0]["ts"] >= ft[0]["ts"] - 1e-3
    lines = jsonl.read_text().strip().splitlines()
    assert [json.loads(ln) for ln in lines] == events


def test_write_trace_requires_telemetry(tiny, tmp_path):
    cfg, params = tiny
    eng = _engine(cfg, params)
    with pytest.raises(RuntimeError):
        eng.write_trace(str(tmp_path / "t.json"))


def test_zero_budget_result_timing(tiny):
    """Regression: ``max_new_tokens=0`` retires without decoding —
    ``decode_s``/``ttft_s`` must be None (not a fake 0.0), latency
    still measured, zero tokens emitted."""
    cfg, params = tiny
    res = _engine(cfg, params).generate(_reqs(cfg, 2, budget=0))
    for r in res:
        assert len(r.tokens) == 0
        assert r.decode_s is None
        assert r.ttft_s is None
        assert r.latency_s is not None and r.latency_s > 0


def test_result_timings_populated_when_decoding(tiny):
    cfg, params = tiny
    res = _engine(cfg, params).generate(_reqs(cfg, 2))
    for r in res:
        assert r.ttft_s is not None and r.ttft_s > 0
        assert r.latency_s >= r.ttft_s
        assert r.decode_s is not None and r.decode_s >= 0


def test_metrics_snapshot_matches_checked_in_schema(paged_runs, tmp_path):
    """The CI smoke's contract: a paged telemetry snapshot validates
    against tools/metrics_schema.json via the repo validator."""
    path = tmp_path / "metrics.json"
    path.write_text(json.dumps(paged_runs[0].stats()))
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "validate_metrics.py"),
         str(path)], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    # and the validator actually rejects drift
    bad = dict(paged_runs[0].stats())
    del bad["occupancy"]
    path.write_text(json.dumps(bad))
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "validate_metrics.py"),
         str(path)], capture_output=True, text=True)
    assert proc.returncode == 1
    assert "occupancy" in proc.stderr


def test_null_telemetry_interface_is_complete():
    """Every public method/attr the engine calls on a live Telemetry
    must exist on the null recorder (and vice versa stay no-op)."""
    from repro.serve.telemetry import NULL_TELEMETRY
    live = [n for n in dir(Telemetry) if not n.startswith("_")
            and callable(getattr(Telemetry, n))]
    for name in live:
        assert hasattr(NULL_TELEMETRY, name), f"NullTelemetry lacks {name}"
    assert NULL_TELEMETRY.enabled is False
    with NULL_TELEMETRY.phase("decode"):
        pass
    with NULL_TELEMETRY.entry("decode", (1, 2)):
        pass
