#!/usr/bin/env python
"""Validate a serve metrics snapshot against a JSON-schema subset.

    python tools/validate_metrics.py METRICS.json [SCHEMA.json]

CI's tier-1 smoke runs ``repro.launch.serve --paged --kv int4
--metrics-json`` and feeds the snapshot through this validator with the
checked-in ``tools/metrics_schema.json`` — a drift tripwire: renaming or
dropping a metrics key, or changing a histogram summary's shape, fails
the smoke instead of silently breaking downstream dashboards.

The validator is dependency-free on purpose (the container has no
``jsonschema``). Supported schema keywords — a strict subset of JSON
Schema draft 2020-12 with identical semantics:

  * ``type`` (string or list of strings; "object", "number", "integer",
    "string", "boolean", "array", "null")
  * ``required``, ``properties``, ``additionalProperties`` (boolean or
    sub-schema) on objects
  * ``minimum`` / ``maximum`` on numbers
  * ``$defs`` at the root + ``$ref: "#/$defs/<name>"`` anywhere

Unknown keywords raise immediately — a schema edit outside the subset
must extend the validator, not silently not-validate.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, List

SUPPORTED = {"$defs", "$ref", "type", "required", "properties",
             "additionalProperties", "minimum", "maximum", "description"}

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


def _type_ok(value: Any, t: str) -> bool:
    if t == "number":
        return isinstance(value, (int, float)) \
            and not isinstance(value, bool)
    if t == "integer":
        return isinstance(value, int) and not isinstance(value, bool)
    return isinstance(value, _TYPES[t])


def validate(value: Any, schema: Dict, root: Dict,
             path: str = "$") -> List[str]:
    """All violations of ``schema`` by ``value`` (empty list = valid)."""
    unknown = set(schema) - SUPPORTED
    if unknown:
        raise ValueError(f"{path}: unsupported schema keywords {unknown}")
    if "$ref" in schema:
        ref = schema["$ref"]
        if not ref.startswith("#/$defs/"):
            raise ValueError(f"{path}: only #/$defs/* refs are supported, "
                             f"got {ref!r}")
        return validate(value, root["$defs"][ref.split("/")[-1]], root, path)

    errors: List[str] = []
    t = schema.get("type")
    if t is not None:
        types = [t] if isinstance(t, str) else t
        if not any(_type_ok(value, x) for x in types):
            return [f"{path}: expected {'|'.join(types)}, got "
                    f"{type(value).__name__} ({value!r})"]

    if isinstance(value, (int, float)) and not isinstance(value, bool):
        if "minimum" in schema and value < schema["minimum"]:
            errors.append(f"{path}: {value} < minimum {schema['minimum']}")
        if "maximum" in schema and value > schema["maximum"]:
            errors.append(f"{path}: {value} > maximum {schema['maximum']}")

    if isinstance(value, dict):
        for key in schema.get("required", []):
            if key not in value:
                errors.append(f"{path}: missing required key {key!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties", True)
        for key, sub in value.items():
            if key in props:
                errors.extend(validate(sub, props[key], root,
                                       f"{path}.{key}"))
            elif extra is False:
                errors.append(f"{path}: unexpected key {key!r}")
            elif isinstance(extra, dict):
                errors.extend(validate(sub, extra, root, f"{path}.{key}"))
    return errors


DEFAULT_SCHEMA = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "metrics_schema.json")


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not 1 <= len(argv) <= 2:
        print(__doc__.strip().splitlines()[2].strip(), file=sys.stderr)
        return 2
    metrics_path = argv[0]
    schema_path = argv[1] if len(argv) == 2 else DEFAULT_SCHEMA
    with open(metrics_path) as f:
        metrics = json.load(f)
    with open(schema_path) as f:
        schema = json.load(f)
    errors = validate(metrics, schema, schema)
    if errors:
        print(f"[validate-metrics] FAIL: {metrics_path} violates "
              f"{schema_path}:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"[validate-metrics] OK: {metrics_path} matches {schema_path} "
          f"({len(metrics)} series)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
