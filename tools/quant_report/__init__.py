#!/usr/bin/env python
"""Render a quantization-quality report (repro.obs.quant) as tables.

    python -m tools.quant_report REPORT.json [--worst N] [--no-validate]

Reads the schema-pinned JSON written by ``--quant-report``, validates it
against ``tools/quant_report_schema.json`` (same engine as the serve
metrics snapshot — ``tools/validate_metrics.py``), then prints a
per-layer table, the aggregate summary, and the worst-N layers by
activation-scaled relative reconstruction error — the layers where the
paper's rank budget is spent least effectively.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List

TOOLS = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_SCHEMA = os.path.join(TOOLS, "quant_report_schema.json")

_COLS = ("layer", "shape", "k/r", "bits", "pres%", "s-rel-err",
         "w-rel-err", "KiB")


def _rows(layers: Dict[str, Dict[str, Any]]) -> List[List[str]]:
    rows = []
    for name in sorted(layers):
        rec = layers[name]
        rows.append([
            name,
            "x".join(str(s) for s in rec["shape"]),
            f"{rec['k']}/{rec['rank']}",
            f"{rec['bits']:.2f}",
            f"{100.0 * rec['preserved_energy_fraction']:.1f}",
            f"{rec['scaled_rel_err']:.4f}",
            f"{rec['weight_rel_err']:.4f}",
            f"{rec['total_bytes'] / 1024:.1f}",
        ])
    return rows


def _print_table(rows: List[List[str]], out) -> None:
    widths = [max(len(c), *(len(r[i]) for r in rows)) if rows else len(c)
              for i, c in enumerate(_COLS)]
    def line(cells):
        print("  ".join(c.ljust(w) if i == 0 else c.rjust(w)
                        for i, (c, w) in enumerate(zip(cells, widths))),
              file=out)
    line(_COLS)
    line(["-" * w for w in widths])
    for r in rows:
        line(r)


def render(report: Dict[str, Any], worst: int = 5, out=None) -> None:
    out = out or sys.stdout
    cfg = report.get("config", {})
    if cfg:
        knobs = ", ".join(f"{k}={cfg[k]}" for k in sorted(cfg))
        print(f"[quant-report] config: {knobs}", file=out)
    layers = report["layers"]
    _print_table(_rows(layers), out)
    s = report["summary"]
    print(f"[quant-report] {s['layers']} layers, "
          f"{s['total_bytes'] / 1024:.1f} KiB total "
          f"({s['quant_bytes'] / 1024:.1f} quant + "
          f"{s['lowrank_bytes'] / 1024:.1f} low-rank), "
          f"{s['total_seconds']:.2f}s", file=out)
    if "mean_scaled_rel_err" in s:
        print(f"[quant-report] scaled rel err mean "
              f"{s['mean_scaled_rel_err']:.4f} max "
              f"{s['max_scaled_rel_err']:.4f}; preserved energy mean "
              f"{s['mean_preserved_energy_fraction']:.3f}; "
              f"mean k {s['mean_k']:.1f} @ {s['mean_bits']:.2f} bits",
              file=out)
    if layers and worst > 0:
        ranked = sorted(layers.values(), key=lambda r: -r["scaled_rel_err"])
        print(f"[quant-report] worst {min(worst, len(ranked))} layers by "
              "scaled relative error:", file=out)
        for rec in ranked[:worst]:
            print(f"  {rec['name']}: s-rel-err {rec['scaled_rel_err']:.4f} "
                  f"(k={rec['k']}, preserved "
                  f"{100.0 * rec['preserved_energy_fraction']:.1f}%, "
                  f"exposed {100.0 * rec['quant_exposed_energy_fraction']:.1f}%)",
                  file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools.quant_report",
        description="Render a --quant-report JSON as per-layer tables.")
    ap.add_argument("report", help="report JSON written by --quant-report")
    ap.add_argument("--worst", type=int, default=5,
                    help="how many worst layers to highlight (0 = skip)")
    ap.add_argument("--schema", default=DEFAULT_SCHEMA,
                    help="schema to validate against before rendering")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip schema validation")
    args = ap.parse_args(argv)

    with open(args.report) as f:
        report = json.load(f)
    if not args.no_validate:
        from tools.validate_metrics import validate
        with open(args.schema) as f:
            schema = json.load(f)
        errors = validate(report, schema, schema)
        if errors:
            print(f"[quant-report] FAIL: {args.report} violates "
                  f"{args.schema}:", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
            return 1
    render(report, worst=args.worst)
    return 0
