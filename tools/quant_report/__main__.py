from tools.quant_report import main

if __name__ == "__main__":
    raise SystemExit(main())
