"""pallas-contract: static checks over the Pallas kernel entry points.

Codes:
  PAL001  a grid / BlockSpec dimension computed with ``//`` whose
          numerator is never guarded by a divisibility check (``x % b``
          in an if/assert that raises, or a ``validate_*`` helper from
          ``kernels.constraints``) — the silent-tail-drop class fixed in
          the paged-decode PR.
  PAL002  a BlockSpec index-map lambda closing over non-scalar state
          (an array-typed parameter or a value produced by jnp/jax/np) —
          index maps must be pure functions of grid indices + scalars.
  PAL003  estimated VMEM working set (block tiles + scratch) above the
          shared budget from ``kernels.constraints.VMEM_BUDGET_BYTES``.
  PAL004  a bare 32/64 tile-floor literal in a guard inside kernels
          code — the minimum-tile constants live in
          ``kernels/constraints.py`` and must be imported from there.

The pass runs on any module that calls ``pallas_call``; PAL004 also
covers every module under a ``kernels/`` directory.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.analysis.core import (Context, Finding, call_name, dotted,
                                 enclosing_function, make_finding, parents,
                                 qualname)

_SCALAR_CALLS = {"len", "min", "max", "int", "abs", "cdiv", "range", "sum"}
_ARRAYISH_ANN = ("Array", "ndarray", "ArrayLike", "Tensor")
_DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "float64": 8,
                "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
                "int8": 1, "uint8": 1, "bool_": 1, "bool": 1}
_DEFAULT_DIM = 128   # unknown symbolic block dims assume one full lane tile


def run(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for mod in ctx.modules:
        has_pallas = "pallas_call" in mod.source
        in_kernels = "/kernels/" in f"/{mod.path}" \
            and not mod.path.endswith("constraints.py")
        if not (has_pallas or in_kernels):
            continue
        if has_pallas:
            for fn in _functions(mod.tree):
                calls = _pallas_calls(fn)
                if not calls:
                    continue
                out.extend(_check_divisibility(mod, fn, calls))
                out.extend(_check_index_maps(mod, fn))
                out.extend(_check_vmem(mod, fn, calls, ctx))
        if in_kernels:
            out.extend(_check_tile_literals(mod, ctx))
    return out


# ----------------------------------------------------------------------------
# helpers


def _functions(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]


def _pallas_calls(fn: ast.FunctionDef) -> List[ast.Call]:
    return [n for n in ast.walk(fn)
            if isinstance(n, ast.Call) and call_name(n) == "pallas_call"
            and enclosing_function(n) is fn]


def _assignments(fn: ast.FunctionDef) -> Dict[str, ast.expr]:
    """name -> last simple assignment value, within this function only."""
    env: Dict[str, ast.expr] = {}
    for node in ast.walk(fn):
        if enclosing_function(node) is not fn:
            continue
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            env[node.targets[0].id] = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and isinstance(node.target, ast.Name):
            env[node.target.id] = node.value
    return env


# ----------------------------------------------------------------------------
# PAL001: unguarded floor divisions feeding grid / block shapes


def _guarded_names(fn: ast.FunctionDef) -> Set[str]:
    """Names whose divisibility is checked before kernel dispatch:
    ``x % b`` inside an if/assert test (the if must raise), or passed to
    a ``validate_*`` / ``_check_*`` helper."""
    guarded: Set[str] = set()
    for node in ast.walk(fn):
        test = None
        if isinstance(node, ast.If) and _raises(node.body):
            test = node.test
        elif isinstance(node, ast.Assert):
            test = node.test
        if test is not None:
            for sub in ast.walk(test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    if isinstance(sub.left, ast.Name):
                        guarded.add(sub.left.id)
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name.startswith(("validate_", "_check", "check_")):
                for arg in node.args:
                    for sub in ast.walk(arg):
                        if isinstance(sub, ast.Name):
                            guarded.add(sub.id)
    return guarded


def _raises(body: List[ast.stmt]) -> bool:
    return any(isinstance(s, ast.Raise) for s in body)


def _floor_divs(expr: ast.expr, env: Dict[str, ast.expr],
                depth: int = 0) -> List[ast.BinOp]:
    """FloorDiv nodes inside expr, following one level of name
    indirection (``n_s = s // bs`` then ``grid=(n_s,)``)."""
    out: List[ast.BinOp] = []
    for sub in ast.walk(expr):
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.FloorDiv):
            out.append(sub)
        elif isinstance(sub, ast.Name) and depth < 2 and sub.id in env:
            out.extend(_floor_divs(env[sub.id], env, depth + 1))
    return out


def _grid_and_block_exprs(fn: ast.FunctionDef,
                          calls: List[ast.Call]) -> List[ast.expr]:
    exprs: List[ast.expr] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if node in calls or "GridSpec" in name:
            for kw in node.keywords:
                if kw.arg == "grid":
                    exprs.append(kw.value)
        if name == "BlockSpec" and node.args:
            exprs.append(node.args[0])
    return exprs


def _ceil_div(node: ast.BinOp) -> bool:
    """-(-a // b) never drops a tail."""
    for p in parents(node):
        if isinstance(p, ast.UnaryOp) and isinstance(p.op, ast.USub):
            return True
        if not isinstance(p, (ast.UnaryOp, ast.BinOp)):
            break
    return isinstance(node.left, ast.UnaryOp) \
        and isinstance(node.left.op, ast.USub)


def _check_divisibility(mod, fn: ast.FunctionDef,
                        calls: List[ast.Call]) -> List[Finding]:
    guarded = _guarded_names(fn)
    env = _assignments(fn)
    out: List[Finding] = []
    seen: Set[str] = set()
    for expr in _grid_and_block_exprs(fn, calls):
        for div in _floor_divs(expr, env):
            if _ceil_div(div) or not isinstance(div.left, ast.Name):
                continue
            num = div.left.id
            if num in guarded or num in seen:
                continue
            seen.add(num)
            den = dotted(div.right) or ast.dump(div.right)
            out.append(make_finding(
                mod.path, div.lineno, "PAL001",
                f"grid/block dim '{num} // {den}' in {fn.name} drops the "
                f"tail silently: guard with '{num} % {den}' (raise "
                f"ValueError) or a kernels.constraints validate_* helper",
                fn.name, num))
    return out


# ----------------------------------------------------------------------------
# PAL002: index-map lambdas closing over non-scalar state


def _check_index_maps(mod, fn: ast.FunctionDef) -> List[Finding]:
    env = _assignments(fn)
    params = {a.arg: a for a in
              fn.args.args + fn.args.kwonlyargs + fn.args.posonlyargs}
    out: List[Finding] = []
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call)
                and call_name(node) == "BlockSpec"):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if not isinstance(arg, ast.Lambda):
                continue
            bound = {a.arg for a in arg.args.args}
            free = {n.id for n in ast.walk(arg.body)
                    if isinstance(n, ast.Name)
                    and isinstance(n.ctx, ast.Load)} - bound
            for name in sorted(free):
                why = _nonscalar_reason(name, env, params)
                if why:
                    out.append(make_finding(
                        mod.path, arg.lineno, "PAL002",
                        f"BlockSpec index map in {fn.name} closes over "
                        f"'{name}' which {why}; index maps must be pure "
                        f"functions of grid indices and prefetched "
                        f"scalars", fn.name, name))
    return out


def _nonscalar_reason(name: str, env: Dict[str, ast.expr],
                      params: Dict[str, ast.arg]) -> Optional[str]:
    if name in params:
        ann = params[name].annotation
        if ann is not None and any(t in dotted(ann) for t in _ARRAYISH_ANN):
            return f"is an array-typed parameter ({dotted(ann)})"
        return None
    val = env.get(name)
    if val is None:
        return None                      # unknown: assume scalar
    for sub in ast.walk(val):
        if isinstance(sub, ast.Call):
            root = dotted(sub.func).split(".")[0]
            leaf = call_name(sub)
            if root in ("jnp", "jax", "np", "numpy") \
                    and leaf not in _SCALAR_CALLS:
                return f"is built by {dotted(sub.func)}() (device/array " \
                       f"state, not a Python scalar)"
    return None


# ----------------------------------------------------------------------------
# PAL003: static VMEM working-set estimate


def _check_vmem(mod, fn: ast.FunctionDef, calls: List[ast.Call],
                ctx: Context) -> List[Finding]:
    env = _assignments(fn)
    defaults = _param_defaults(fn)
    out: List[Finding] = []
    for call in calls:
        total = 0
        for spec in ast.walk(call):
            if not isinstance(spec, ast.Call):
                continue
            name = call_name(spec)
            if name == "BlockSpec" and spec.args \
                    and isinstance(spec.args[0], ast.Tuple):
                total += _tuple_elems(spec.args[0], env, defaults) * 4
            elif name == "VMEM" and spec.args:
                shape = spec.args[0]
                elems = _tuple_elems(shape, env, defaults) \
                    if isinstance(shape, ast.Tuple) else _DEFAULT_DIM
                total += elems * _dtype_bytes(spec.args[1:])
        budget = ctx.constraints.vmem_budget_bytes
        if total > budget:
            out.append(make_finding(
                mod.path, call.lineno, "PAL003",
                f"pallas_call in {fn.name}: estimated VMEM working set "
                f"~{total // 1024} KiB exceeds the "
                f"{budget // 1024} KiB budget "
                f"(kernels.constraints.VMEM_BUDGET_BYTES) — shrink block "
                f"shapes or split the kernel", fn.name, "vmem"))
    return out


def _param_defaults(fn: ast.FunctionDef) -> Dict[str, int]:
    env: Dict[str, int] = {}
    pos = fn.args.args
    for arg, default in zip(pos[len(pos) - len(fn.args.defaults):],
                            fn.args.defaults):
        if isinstance(default, ast.Constant) and isinstance(default.value,
                                                            int):
            env[arg.arg] = default.value
    for arg, default in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if isinstance(default, ast.Constant) and isinstance(default.value,
                                                            int):
            env[arg.arg] = default.value
    return env


def _tuple_elems(node: ast.Tuple, env: Dict[str, ast.expr],
                 defaults: Dict[str, int]) -> int:
    total = 1
    for el in node.elts:
        total *= _eval_dim(el, env, defaults)
    return total


def _eval_dim(node: ast.expr, env: Dict[str, ast.expr],
              defaults: Dict[str, int], depth: int = 0) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return max(1, node.value)
    if isinstance(node, ast.Name):
        if node.id in defaults:
            return defaults[node.id]
        if depth < 3 and node.id in env:
            return _eval_dim(env[node.id], env, defaults, depth + 1)
        return _DEFAULT_DIM
    if isinstance(node, ast.BinOp):
        a = _eval_dim(node.left, env, defaults, depth + 1)
        b = _eval_dim(node.right, env, defaults, depth + 1)
        if isinstance(node.op, ast.Mult):
            return a * b
        if isinstance(node.op, ast.FloorDiv):
            return max(1, a // max(1, b))
        if isinstance(node.op, ast.Add):
            return a + b
        if isinstance(node.op, ast.Sub):
            return max(1, a - b)
        return _DEFAULT_DIM
    if isinstance(node, ast.IfExp):
        return max(_eval_dim(node.body, env, defaults, depth + 1),
                   _eval_dim(node.orelse, env, defaults, depth + 1))
    if isinstance(node, ast.Call) and call_name(node) in ("min", "max"):
        vals = [_eval_dim(a, env, defaults, depth + 1) for a in node.args]
        if vals:
            return min(vals) if call_name(node) == "min" else max(vals)
    return _DEFAULT_DIM


def _dtype_bytes(args: List[ast.expr]) -> int:
    for a in args:
        leaf = dotted(a).split(".")[-1]
        if leaf in _DTYPE_BYTES:
            return _DTYPE_BYTES[leaf]
    return 4


# ----------------------------------------------------------------------------
# PAL004: inlined tile-floor literals in kernels guards


def _check_tile_literals(mod, ctx: Context) -> List[Finding]:
    floors = {ctx.constraints.min_sublane_tile,
              ctx.constraints.min_sublane_tile_packed4}
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        test = None
        if isinstance(node, ast.If):
            test = node.test
        elif isinstance(node, ast.Assert):
            test = node.test
        if test is None:
            continue
        for sub in ast.walk(test):
            bad = None
            if isinstance(sub, ast.Compare):
                for cmp in sub.comparators:
                    if isinstance(cmp, ast.Constant) and cmp.value in floors:
                        bad = cmp
            elif isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod) \
                    and isinstance(sub.right, ast.Constant) \
                    and sub.right.value in floors:
                bad = sub.right
            if bad is not None:
                out.append(make_finding(
                    mod.path, getattr(bad, "lineno", node.lineno), "PAL004",
                    f"bare tile-floor literal {bad.value} in a guard in "
                    f"{qualname(node)}; import MIN_SUBLANE_TILE / "
                    f"MIN_SUBLANE_TILE_PACKED4 from kernels.constraints",
                    qualname(node), str(bad.value)))
    return out
