"""repro-lint: dependency-free AST static analysis for the repro stack.

    python -m tools.analysis.run src/ tests/ benchmarks/

Four passes (see the sibling modules), each emitting
``file:line CODE message`` findings that are diffed against the
checked-in ``tools/analysis/baseline.txt`` — CI fails only on *new*
violations. The runtime twin is ``repro.serve.sanitizer``
(``--sanitize``), which checks at serve time the invariants these
passes prove conventions for statically.
"""
from tools.analysis.core import Finding, load_baseline  # noqa: F401
