"""lock-discipline: convention-driven thread-safety for the serve layer.

The contract (see ``serve/http.py``): one pump thread drives
``Engine.step()``; HTTP handler threads may touch the engine only for
the methods named in the module-level ``ENGINE_MUTATORS`` registry, and
only while holding ``EngineServer.cv``. This pass *proves* the module
follows the contract lexically:

  LCK000  a serve ``http.py`` module with no ``ENGINE_MUTATORS``
          registry — the contract itself is missing.
  LCK001  a registered mutator invoked through ``.engine`` (or a local
          alias of it) outside a ``with ...cv:`` block and outside
          ``__init__`` — an unlocked engine mutation.
  LCK002  a request-handler class (``BaseHTTPRequestHandler``
          subclass) reaching a mutator directly — handlers must go
          through the EngineServer wrappers, which take the lock.

Reads of non-registered attributes (``engine.cfg``, ``engine.sched``)
are allowed anywhere; the registry is the single place that decides
what counts as a mutation.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from tools.analysis.core import (Context, Finding, dotted, make_finding,
                                 parents, qualname)


def run(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for mod in ctx.modules:
        registry = _registry(mod.tree)
        if registry is None:
            if mod.path.endswith("serve/http.py"):
                out.append(make_finding(
                    mod.path, 1, "LCK000",
                    "no ENGINE_MUTATORS registry: declare the engine "
                    "methods that require EngineServer.cv in one "
                    "module-level frozenset", "<module>", "registry"))
            continue
        out.extend(_check_module(mod, registry))
    return out


def _registry(tree: ast.Module) -> Optional[Set[str]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "ENGINE_MUTATORS":
            return {c.value for c in ast.walk(node.value)
                    if isinstance(c, ast.Constant)
                    and isinstance(c.value, str)}
    return None


def _engine_aliases(fn: ast.AST) -> Set[str]:
    """Local names bound to an engine reference: ``eng = self.engine``."""
    aliases: Set[str] = {"engine"}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and dotted(node.value).endswith(".engine"):
            aliases.add(node.targets[0].id)
    return aliases


def _under_cv(node: ast.AST) -> bool:
    for p in parents(node):
        if isinstance(p, ast.With):
            for item in p.items:
                d = dotted(item.context_expr)
                if d.endswith(".cv") or d == "cv" or ".cv." in d:
                    return True
    return False


def _in_init(node: ast.AST) -> bool:
    for p in parents(node):
        if isinstance(p, ast.FunctionDef):
            return p.name == "__init__"
    return False


def _handler_classes(tree: ast.Module) -> Set[ast.ClassDef]:
    return {n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
            and any("Handler" in dotted(b) for b in n.bases)}


def _owning_class(node: ast.AST) -> Optional[ast.ClassDef]:
    for p in parents(node):
        if isinstance(p, ast.ClassDef):
            return p
    return None


def _check_module(mod, registry: Set[str]) -> List[Finding]:
    out: List[Finding] = []
    handlers = _handler_classes(mod.tree)
    aliases = _engine_aliases(mod.tree)
    for node in ast.walk(mod.tree):
        target = None
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in registry:
            target = node.func.value
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Attribute) \
                and node.targets[0].attr in registry:
            target = node.targets[0].value
        if target is None:
            continue
        d = dotted(target)
        base = d.split(".")[-1]
        if not (d.endswith(".engine") or base in aliases and "." not in d
                or base == "engine"):
            continue
        attr = node.func.attr if isinstance(node, ast.Call) \
            else node.targets[0].attr
        cls = _owning_class(node)
        where = qualname(node)
        if cls in handlers:
            out.append(make_finding(
                mod.path, node.lineno, "LCK002",
                f"handler {where} calls engine mutator '{attr}' directly; "
                f"handlers must use the EngineServer wrappers, which take "
                f"cv", where, attr))
        elif not (_under_cv(node) or _in_init(node)):
            out.append(make_finding(
                mod.path, node.lineno, "LCK001",
                f"engine mutator '{attr}' called in {where} without "
                f"holding cv: wrap the call in 'with self.cv:' (the pump "
                f"thread owns unlocked stepping only via the cv wait "
                f"loop)", where, attr))
    return out
