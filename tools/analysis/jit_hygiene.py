"""jit-hygiene: tracing / host-sync / recompile checks.

Codes:
  JIT001  a Python ``if``/``while`` on a value that is traced inside a
          jitted function (a non-static parameter used directly in the
          test) — the branch freezes at trace time or raises a
          ConcretizationTypeError.
  JIT002  a host synchronisation (``jax.device_get``,
          ``jax.block_until_ready``, ``.item()``, ``np.asarray`` on
          device values) inside a function reachable from
          ``Engine.step()``, outside the documented fence contexts
          (``with tel.phase("transfer")``, an ``if ...sync:`` guard, or
          a ``with jax.named_scope(...)`` block naming the sync).
  JIT003  recompile churn: ``jax.jit`` invoked inside a step-reachable
          function (a fresh compiled callable per call), or an
          unhashable literal (list/dict/set) passed at a known static
          position of a jitted closure.
  JIT004  a jitted function threading a KV cache (a parameter named
          ``cache``/``*_cache``) without ``donate_argnums`` — every
          decode step copies the whole cache.

Reachability: roots are ``Engine.step`` plus (for fixture/library
modules with no Engine) every jit-wrapped function; edges follow simple
callee names across all scanned modules, an over-approximation that is
cheap and safe.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis.core import (Context, Finding, call_name, dotted,
                                 enclosing_function, make_finding, parents,
                                 qualname)

_SYNC_CALLS = {"device_get", "block_until_ready"}
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_EXEMPT_CALLS = {"isinstance", "hasattr", "callable", "len", "getattr",
                 "issubclass"}


def run(ctx: Context) -> List[Finding]:
    jits = _collect_jits(ctx)
    reachable = _reachable(ctx, jits)
    out: List[Finding] = []
    out.extend(_check_traced_branches(ctx, jits))
    out.extend(_check_host_syncs(ctx, reachable))
    out.extend(_check_recompiles(ctx, jits, reachable))
    out.extend(_check_donation(ctx, jits))
    return out


# ----------------------------------------------------------------------------
# jit call-site discovery


class Jit:
    def __init__(self, mod, call: ast.Call, target: Optional[ast.FunctionDef],
                 static_pos: Set[int], static_names: Set[str],
                 bound_attr: Optional[str], donated: bool,
                 decorator: bool = False):
        self.mod = mod
        self.call = call
        self.target = target            # resolved wrapped FunctionDef
        self.static_pos = static_pos
        self.static_names = static_names
        self.bound_attr = bound_attr    # 'self._decode = jax.jit(...)'
        self.donated = donated
        self.decorator = decorator      # @jax.jit — compiled once at import


def _is_jit_call(node: ast.Call) -> bool:
    d = dotted(node.func)
    return d.endswith("jax.jit") or d == "jit"


def _literal_ints(node: ast.expr) -> Set[int]:
    out: Set[int] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, int) \
                and not isinstance(sub.value, bool):
            out.add(sub.value)
    return out


def _literal_strs(node: ast.expr) -> Set[str]:
    return {s.value for s in ast.walk(node)
            if isinstance(s, ast.Constant) and isinstance(s.value, str)}


def _collect_jits(ctx: Context) -> List[Jit]:
    jits: List[Jit] = []
    for mod in ctx.modules:
        if "jit" not in mod.source:
            continue
        local_funcs = {n.name: n for n in ast.walk(mod.tree)
                       if isinstance(n, ast.FunctionDef)}
        for node in ast.walk(mod.tree):
            call, target, deco_target = None, None, None
            if isinstance(node, ast.Call) and _is_jit_call(node):
                call = node
            elif isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) and (
                            _is_jit_call(dec)
                            or (dotted(dec.func).endswith("partial")
                                and dec.args
                                and dotted(dec.args[0]).endswith("jit"))):
                        call, deco_target = dec, node
                    elif dotted(dec).endswith("jit"):
                        jits.append(Jit(mod, ast.Call(func=dec, args=[],
                                                      keywords=[]),
                                        node, set(), set(), None, False,
                                        decorator=True))
            if call is None:
                continue
            static_pos: Set[int] = set()
            static_names: Set[str] = set()
            donated = False
            for kw in call.keywords:
                if kw.arg in ("static_argnums", "static_argnames"):
                    static_pos |= _literal_ints(kw.value)
                    static_names |= _literal_strs(kw.value)
                if kw.arg in ("donate_argnums", "donate_argnames"):
                    donated = True
            if deco_target is not None:
                target = deco_target
            else:
                wrapped = None
                args = [a for a in call.args
                        if not dotted(a).endswith("jit")]
                if args:
                    wrapped = args[0]
                if isinstance(wrapped, ast.Name):
                    target = local_funcs.get(wrapped.id)
            bound = None
            for p in parents(call):
                if isinstance(p, ast.Assign) and p.value is call \
                        and len(p.targets) == 1 \
                        and isinstance(p.targets[0], ast.Attribute):
                    bound = p.targets[0].attr
                break
            jits.append(Jit(mod, call, target, static_pos, static_names,
                            bound, donated, decorator=deco_target is not None))
    return jits


# ----------------------------------------------------------------------------
# reachability from Engine.step()


def _func_index(ctx: Context) -> Dict[str, List[Tuple[object,
                                                      ast.FunctionDef]]]:
    idx: Dict[str, List[Tuple[object, ast.FunctionDef]]] = {}
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.FunctionDef):
                idx.setdefault(node.name, []).append((mod, node))
    return idx


def _reachable(ctx: Context, jits: List[Jit]) -> Set[ast.FunctionDef]:
    idx = _func_index(ctx)
    roots: List[ast.FunctionDef] = []
    for mod, fn in idx.get("step", []):
        if "Engine" in qualname(fn):
            roots.append(fn)
    if not roots:
        # library/fixture mode: jit targets are the entry points
        roots = [j.target for j in jits if j.target is not None]
    seen: Set[ast.FunctionDef] = set()
    work = list(roots)
    while work:
        fn = work.pop()
        if fn in seen:
            continue
        seen.add(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                for _, callee in idx.get(call_name(node), []):
                    if callee not in seen:
                        work.append(callee)
    return seen


# ----------------------------------------------------------------------------
# JIT001: python control flow on traced values


def _static_params(jit: Jit) -> Set[str]:
    fn = jit.target
    assert fn is not None
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    static = {params[i] for i in jit.static_pos if i < len(params)}
    static |= jit.static_names & set(params)
    static |= {a.arg for a in fn.args.kwonlyargs}   # bound via partial
    return static


def _test_exempt_names(test: ast.expr) -> Set[str]:
    """Names whose use inside the test cannot touch traced values:
    isinstance/hasattr/len-style calls, ``x is None``, ``k in d``,
    ``a.shape``-style attribute reads."""
    exempt: Set[str] = set()
    for sub in ast.walk(test):
        if isinstance(sub, ast.Call) and call_name(sub) in _EXEMPT_CALLS:
            for n in ast.walk(sub):
                if isinstance(n, ast.Name):
                    exempt.add(n.id)
        elif isinstance(sub, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                for op in sub.ops):
            for n in ast.walk(sub):
                if isinstance(n, ast.Name):
                    exempt.add(n.id)
        elif isinstance(sub, ast.Attribute) and sub.attr in _SHAPE_ATTRS:
            for n in ast.walk(sub):
                if isinstance(n, ast.Name):
                    exempt.add(n.id)
    return exempt


def _check_traced_branches(ctx: Context, jits: List[Jit]) -> List[Finding]:
    out: List[Finding] = []
    seen: Set[Tuple[str, str, str]] = set()
    for jit in jits:
        if jit.target is None:
            continue
        fn = jit.target
        static = _static_params(jit)
        traced = {a.arg for a in fn.args.posonlyargs + fn.args.args} - static
        for node in ast.walk(fn):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            names = {n.id for n in ast.walk(node.test)
                     if isinstance(n, ast.Name)
                     and isinstance(n.ctx, ast.Load)}
            hot = (names & traced) - _test_exempt_names(node.test)
            for name in sorted(hot):
                key = (jit.mod.path, fn.name, name)
                if key in seen:
                    continue
                seen.add(key)
                out.append(make_finding(
                    jit.mod.path, node.lineno, "JIT001",
                    f"Python {'while' if isinstance(node, ast.While) else 'if'}"
                    f" on '{name}' inside jitted {fn.name}: the value is "
                    f"traced (not in static_argnums) so the branch freezes "
                    f"at trace time or raises ConcretizationTypeError",
                    fn.name, name))
    return out


# ----------------------------------------------------------------------------
# JIT002: host syncs reachable from step()


def _fenced(node: ast.AST) -> bool:
    """Inside `with ...phase("transfer"):`, an `if ...sync:` guard, or a
    `with jax.named_scope(...)` block — the documented places the engine
    is allowed to block on device work (a named scope marks the sync as
    deliberate and keeps it attributable in profiles)."""
    for p in parents(node):
        if isinstance(p, ast.With):
            for item in p.items:
                c = item.context_expr
                if not isinstance(c, ast.Call):
                    continue
                if call_name(c) == "phase" and c.args \
                        and isinstance(c.args[0], ast.Constant) \
                        and c.args[0].value == "transfer":
                    return True
                if dotted(c.func).endswith("named_scope"):
                    return True
        if isinstance(p, ast.If):
            if any(isinstance(s, ast.Attribute) and s.attr == "sync"
                   for s in ast.walk(p.test)):
                return True
    return False


def _check_host_syncs(ctx: Context,
                      reachable: Set[ast.FunctionDef]) -> List[Finding]:
    out: List[Finding] = []
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = enclosing_function(node)
            if fn is None or fn not in reachable:
                continue
            name = call_name(node)
            sync = None
            if name in _SYNC_CALLS and dotted(node.func).startswith(
                    ("jax.", "block_until_ready", "device_get")):
                sync = dotted(node.func)
            elif name == "item" and isinstance(node.func, ast.Attribute) \
                    and not node.args:
                sync = ".item()"
            elif name in ("asarray", "array") \
                    and dotted(node.func).split(".")[0] in ("np", "numpy") \
                    and node.args \
                    and not (isinstance(node.args[0], ast.Call)
                             and call_name(node.args[0]) in _SYNC_CALLS) \
                    and not isinstance(node.args[0],
                                       (ast.List, ast.Tuple, ast.Dict,
                                        ast.Constant, ast.ListComp,
                                        ast.GeneratorExp)):
                sync = dotted(node.func)
            if sync is None or _fenced(node):
                continue
            out.append(make_finding(
                mod.path, node.lineno, "JIT002",
                f"host sync {sync} in {qualname(node)} (reachable from "
                f"Engine.step); move it under tel.phase(\"transfer\"), an "
                f"explicit ...sync fence, or a jax.named_scope block so "
                f"the step loop never blocks silently",
                qualname(node), sync))
    return out


# ----------------------------------------------------------------------------
# JIT003: recompile churn


_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp, ast.GeneratorExp)


def _check_recompiles(ctx: Context, jits: List[Jit],
                      reachable: Set[ast.FunctionDef]) -> List[Finding]:
    out: List[Finding] = []
    for jit in jits:
        if jit.decorator:       # @jax.jit compiles once at import time
            continue
        fn = enclosing_function(jit.call)
        if fn is not None and fn in reachable and fn.name != "__init__":
            out.append(make_finding(
                jit.mod.path, jit.call.lineno, "JIT003",
                f"jax.jit called inside step-reachable {qualname(jit.call)}: "
                f"this builds a fresh compiled callable every call; hoist "
                f"the jit to __init__ or module scope", qualname(jit.call),
                "fresh-jit"))
    # unhashable literals at known static positions of jitted callables:
    # 'self._decode = jax.jit(...)' attr closures and decorator-jitted
    # module functions called by name
    bound = {j.bound_attr: j for j in jits if j.bound_attr}
    by_name = {j.target.name: j for j in jits
               if j.decorator and j.target is not None}
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Attribute):
                jit = bound.get(node.func.attr)
            elif isinstance(node.func, ast.Name):
                jit = by_name.get(node.func.id)
            else:
                continue
            if jit is None:
                continue
            callee = dotted(node.func)
            for i in jit.static_pos:
                if i < len(node.args) \
                        and isinstance(node.args[i], _UNHASHABLE):
                    out.append(make_finding(
                        mod.path, node.lineno, "JIT003",
                        f"unhashable literal at static arg {i} of "
                        f"{callee} in {qualname(node)}: every "
                        f"call re-traces; pass a tuple or a hashable "
                        f"scalar", qualname(node),
                        f"{callee}:static{i}"))
    return out


# ----------------------------------------------------------------------------
# JIT004: cache threaded without donation


def _check_donation(ctx: Context, jits: List[Jit]) -> List[Finding]:
    out: List[Finding] = []
    for jit in jits:
        if jit.target is None or jit.donated:
            continue
        cache_params = [a.arg for a in jit.target.args.args
                        if a.arg == "cache" or a.arg.endswith("_cache")]
        if not cache_params:
            continue
        label = jit.bound_attr or jit.target.name
        out.append(make_finding(
            jit.mod.path, jit.call.lineno, "JIT004",
            f"jit of {jit.target.name} threads '{cache_params[0]}' without "
            f"donate_argnums: each dispatch copies the KV buffers instead "
            f"of updating them in place", label, jit.target.name))
    return out
