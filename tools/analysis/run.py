"""repro-lint CLI.

    python -m tools.analysis.run src/ tests/ benchmarks/

Runs the four passes over the given files/directories, diffs the
findings against ``tools/analysis/baseline.txt`` and exits non-zero on
anything new. Stale baseline entries (suppressing findings that no
longer fire) are reported so the baseline shrinks over time instead of
fossilising.

Exit codes: 0 clean, 1 new findings (or stale baseline with --strict),
2 usage/internal error.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

from tools.analysis import api_drift, jit_hygiene, lock_discipline, \
    pallas_contract
from tools.analysis.core import (BaselineError, Context, Finding,
                                 iter_py_files, load_baseline,
                                 load_constraints, parse_modules,
                                 save_baseline)

PASSES = (("pallas-contract", pallas_contract),
          ("jit-hygiene", jit_hygiene),
          ("lock-discipline", lock_discipline),
          ("api-drift", api_drift))

DEFAULT_BASELINE = os.path.join("tools", "analysis", "baseline.txt")


def analyze(paths: List[str], root: str) -> List[Finding]:
    files = iter_py_files(paths)
    modules, findings = parse_modules(files, root)
    ctx = Context(modules=modules, root=root,
                  constraints=load_constraints(root))
    for _, mod in PASSES:
        findings.extend(mod.run(ctx))
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis.run",
        description="repro-lint: jit/Pallas/concurrency/API static checks")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to analyze")
    ap.add_argument("--root", default=".",
                    help="repo root findings are reported relative to")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default {DEFAULT_BASELINE} "
                         f"under --root; 'none' disables)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings, "
                         "keeping existing justifications")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale baseline entries")
    ap.add_argument("--output", default=None,
                    help="write the full findings list to this file "
                         "(for CI artifacts)")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root)
    if args.baseline == "none":
        baseline_path = None
    else:
        baseline_path = args.baseline or os.path.join(root, DEFAULT_BASELINE)

    try:
        findings = analyze(args.paths, root)
    except (OSError, RecursionError) as e:
        print(f"repro-lint: internal error: {e}", file=sys.stderr)
        return 2

    baseline = {}
    if baseline_path:
        try:
            baseline = load_baseline(baseline_path)
        except BaselineError as e:
            print(f"repro-lint: {e}", file=sys.stderr)
            return 2

    if args.update_baseline:
        if not baseline_path:
            print("repro-lint: --update-baseline needs a baseline path",
                  file=sys.stderr)
            return 2
        save_baseline(baseline_path, findings, baseline)
        print(f"repro-lint: wrote {len(set(f.key for f in findings))} "
              f"entries to {baseline_path}")
        return 0

    new = [f for f in findings if f.key not in baseline]
    suppressed = [f for f in findings if f.key in baseline]
    stale = sorted(set(baseline) - set(f.key for f in findings))

    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            for f in findings:
                mark = "baseline" if f.key in baseline else "NEW"
                fh.write(f"{mark:8s} {f.render()}\n")

    for f in new:
        print(f.render())
    for key in stale:
        print(f"stale baseline entry (finding no longer fires): {key}",
              file=sys.stderr)
    n_files = len(iter_py_files(args.paths))
    print(f"repro-lint: {n_files} files, {len(new)} new, "
          f"{len(suppressed)} baselined, {len(stale)} stale",
          file=sys.stderr)
    if new:
        return 1
    if stale and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
