"""api-drift: keep the public surfaces that cannot be type-checked in
sync — telemetry names vs their JSON schema, ServeConfig fields vs CLI
flags and README docs.

Codes:
  API001  a metric name registered in ``src/`` (``.counter/.gauge/
          .histogram`` first argument) that matches nothing in
          ``tools/metrics_schema.json``. f-string names are expanded to
          patterns, so ``f"step_{p}_seconds"`` covers the whole phase
          family.
  API002  a ``metrics_schema.json`` property no source registration can
          produce — a dead schema entry.
  API003  a ``ServeConfig`` field that no ``src/repro/launch`` CLI
          plumbs (never passed as a keyword to a ServeConfig(...) call
          there).
  API004  a ``ServeConfig`` field undocumented in README.md.

The pass is repo-shaped: it activates only when the scanned set
includes modules under ``src/`` and the schema / README exist at the
analysis root.
"""
from __future__ import annotations

import ast
import json
import os
import re
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis.core import (Context, Finding, Module, dotted,
                                 make_finding, qualname)

_REGISTER = {"counter", "gauge", "histogram"}


def run(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    schema_path = os.path.join(ctx.root, "tools", "metrics_schema.json")
    src_mods = [m for m in ctx.modules if m.path.startswith("src/")]
    if src_mods and os.path.exists(schema_path):
        out.extend(check_metrics(src_mods, schema_path))
    engine = ctx.module("serve/engine.py")
    if engine is not None:
        launch = [m for m in ctx.modules if "/launch/" in f"/{m.path}"]
        readme = os.path.join(ctx.root, "README.md")
        out.extend(check_serve_config(engine, launch,
                                      readme if os.path.exists(readme)
                                      else None))
    return out


# ----------------------------------------------------------------------------
# telemetry registry <-> tools/metrics_schema.json


def _metric_names(mods: List[Module]) -> List[Tuple[Module, int, str,
                                                    Optional[str]]]:
    """(module, line, display, regex) per registration; regex is None
    for literal names."""
    found = []
    for mod in mods:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _REGISTER and node.args):
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                found.append((mod, node.lineno, arg.value, None))
            elif isinstance(arg, ast.JoinedStr):
                pat, disp = "", ""
                for part in arg.values:
                    if isinstance(part, ast.Constant):
                        pat += re.escape(str(part.value))
                        disp += str(part.value)
                    else:
                        pat += r"[A-Za-z0-9_]+"
                        disp += "{*}"
                found.append((mod, node.lineno, disp, f"^{pat}$"))
            # computed names (variables) are invisible to this pass;
            # the schema's additionalProperties covers them at runtime
    return found


def check_metrics(mods: List[Module], schema_path: str) -> List[Finding]:
    with open(schema_path, encoding="utf-8") as fh:
        schema = json.load(fh)
    keys: Set[str] = set(schema.get("properties", {}))
    names = _metric_names(mods)
    out: List[Finding] = []
    covered: Set[str] = set()
    for mod, line, disp, pat in names:
        if pat is None:
            if disp in keys:
                covered.add(disp)
            else:
                out.append(make_finding(
                    mod.path, line, "API001",
                    f"metric '{disp}' is registered but missing from "
                    f"tools/metrics_schema.json properties", "metrics",
                    disp))
        else:
            hits = {k for k in keys if re.match(pat, k)}
            if hits:
                covered |= hits
            else:
                out.append(make_finding(
                    mod.path, line, "API001",
                    f"metric family '{disp}' matches no "
                    f"tools/metrics_schema.json property", "metrics", disp))
    rel = "tools/metrics_schema.json"
    for key in sorted(keys - covered):
        out.append(make_finding(
            rel, 1, "API002",
            f"schema property '{key}' has no registration site in src/ "
            f"(dead schema entry, or the registration uses a computed "
            f"name — rename one side)", "schema", key))
    return out


# ----------------------------------------------------------------------------
# ServeConfig <-> CLI flags <-> README


def _serve_config_fields(engine: Module) -> Dict[str, int]:
    for node in ast.walk(engine.tree):
        if isinstance(node, ast.ClassDef) and node.name == "ServeConfig":
            return {s.target.id: s.lineno for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)}
    return {}


def _plumbed_fields(launch: List[Module]) -> Set[str]:
    plumbed: Set[str] = set()
    for mod in launch:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and dotted(node.func).endswith("ServeConfig"):
                plumbed |= {kw.arg for kw in node.keywords if kw.arg}
    return plumbed


def check_serve_config(engine: Module, launch: List[Module],
                       readme: Optional[str]) -> List[Finding]:
    fields = _serve_config_fields(engine)
    out: List[Finding] = []
    if launch:
        plumbed = _plumbed_fields(launch)
        for name, line in sorted(fields.items()):
            if name not in plumbed:
                out.append(make_finding(
                    engine.path, line, "API003",
                    f"ServeConfig.{name} is not plumbed by any launch CLI "
                    f"(no ServeConfig({name}=...) under src/repro/launch/)",
                    "ServeConfig", name))
    if readme is not None:
        with open(readme, encoding="utf-8") as fh:
            text = fh.read()
        for name, line in sorted(fields.items()):
            if not re.search(rf"\b{re.escape(name)}\b", text):
                out.append(make_finding(
                    engine.path, line, "API004",
                    f"ServeConfig.{name} is undocumented in README.md",
                    "ServeConfig", name))
    return out
