"""Shared plumbing for the repro-lint passes.

A pass is a module exposing ``run(ctx) -> List[Finding]``. Findings
carry a *stable key* that deliberately excludes the line number, so a
baseline entry survives unrelated edits to the file; the printed form
(``file:line CODE message``) is for humans and CI logs only.

Baseline format (``tools/analysis/baseline.txt``): one finding key per
line, followed by ``  # justification``. Unjustified entries are
rejected — a suppression must say *why* the finding is intentional.
Blank lines and lines starting with ``#`` are comments.
"""
from __future__ import annotations

import ast
import dataclasses
import importlib.util
import os
from typing import Dict, Iterable, List, Optional, Tuple

# ----------------------------------------------------------------------------
# findings


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit.

    ``key`` is the baseline identity: ``CODE path:scope:detail`` with no
    line number, so renumbering a file does not churn the baseline.
    """
    path: str           # repo-relative, forward slashes
    line: int
    code: str           # e.g. PAL001
    message: str
    key: str

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.code} {self.message}"


def make_finding(path: str, line: int, code: str, message: str,
                 scope: str, detail: str) -> Finding:
    key = f"{code} {path}:{scope}:{detail}"
    return Finding(path=path, line=line, code=code, message=message, key=key)


# ----------------------------------------------------------------------------
# baseline


class BaselineError(ValueError):
    pass


def load_baseline(path: str) -> Dict[str, str]:
    """Return {finding key: justification}. Every entry must carry a
    ``# why`` justification — raise :class:`BaselineError` otherwise."""
    entries: Dict[str, str] = {}
    if not os.path.exists(path):
        return entries
    with open(path, encoding="utf-8") as fh:
        for n, raw in enumerate(fh, 1):
            line = raw.rstrip("\n")
            if not line.strip() or line.lstrip().startswith("#"):
                continue
            key, sep, why = line.partition("  # ")
            key, why = key.strip(), why.strip()
            if not sep or not why:
                raise BaselineError(
                    f"{path}:{n}: baseline entry lacks a justification "
                    f"('<key>  # why it is intentional'): {line!r}")
            if key in entries:
                raise BaselineError(f"{path}:{n}: duplicate key {key!r}")
            entries[key] = why
    return entries


def save_baseline(path: str, findings: Iterable[Finding],
                  old: Dict[str, str]) -> None:
    """Write the current findings as the new baseline, keeping existing
    justifications and stamping new entries with a TODO marker."""
    lines = ["# repro-lint baseline: one suppressed finding per line,",
             "# '<key>  # justification'. Regenerate entries with",
             "#   python -m tools.analysis.run --update-baseline <paths>",
             "# then replace every TODO with a real justification.", ""]
    for f in sorted(set(fd.key for fd in findings)):
        why = old.get(f, "TODO: justify or fix")
        lines.append(f"{f}  # {why}")
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("\n".join(lines) + "\n")


# ----------------------------------------------------------------------------
# file walking / parsing


def iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
    return sorted(set(os.path.normpath(p) for p in out))


@dataclasses.dataclass
class Module:
    path: str            # repo-relative with forward slashes
    source: str
    tree: ast.Module


@dataclasses.dataclass
class Context:
    """Everything a pass needs: parsed modules plus repo-level config."""
    modules: List[Module]
    root: str                       # directory findings are relative to
    constraints: "KernelConstraints"

    def module(self, suffix: str) -> Optional[Module]:
        for m in self.modules:
            if m.path.endswith(suffix):
                return m
        return None


def parse_modules(files: Iterable[str], root: str) -> Tuple[List[Module],
                                                            List[Finding]]:
    mods: List[Module] = []
    errors: List[Finding] = []
    for f in files:
        rel = os.path.relpath(f, root).replace(os.sep, "/")
        try:
            with open(f, encoding="utf-8") as fh:
                src = fh.read()
            tree = ast.parse(src, filename=f)
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 1) or 1
            errors.append(make_finding(rel, line, "GEN000",
                                       f"unparseable module: {e}",
                                       "<module>", "parse"))
            continue
        attach_parents(tree)
        mods.append(Module(path=rel, source=src, tree=tree))
    return mods, errors


def attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._parent = node  # type: ignore[attr-defined]


def parents(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_parent", None)


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    for p in parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p
    return None


def qualname(node: ast.AST) -> str:
    """Dotted path of the enclosing defs/classes, '<module>' at top."""
    names = []
    cur: Optional[ast.AST] = node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            names.append(cur.name)
        cur = getattr(cur, "_parent", None)
    return ".".join(reversed(names)) or "<module>"


def call_name(node: ast.Call) -> str:
    """Trailing name of the callee: jnp.zeros -> 'zeros', foo -> 'foo'."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def dotted(node: ast.AST) -> str:
    """Best-effort dotted rendering of a Name/Attribute chain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


# ----------------------------------------------------------------------------
# kernel constraints (shared with the kernels themselves)


@dataclasses.dataclass
class KernelConstraints:
    min_sublane_tile: int = 32
    min_sublane_tile_packed4: int = 64
    packed4_slot_align: int = 2
    vmem_budget_bytes: int = 4 * 1024 * 1024


def load_constraints(root: str) -> KernelConstraints:
    """Import ``src/repro/kernels/constraints.py`` by path so analyzer
    and kernels agree on one set of numbers; fall back to the packaged
    defaults when analyzing a tree that does not contain it."""
    path = os.path.join(root, "src", "repro", "kernels", "constraints.py")
    kc = KernelConstraints()
    if not os.path.exists(path):
        return kc
    spec = importlib.util.spec_from_file_location("_repro_constraints", path)
    if spec is None or spec.loader is None:     # pragma: no cover
        return kc
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return KernelConstraints(
        min_sublane_tile=mod.MIN_SUBLANE_TILE,
        min_sublane_tile_packed4=mod.MIN_SUBLANE_TILE_PACKED4,
        packed4_slot_align=mod.PACKED4_SLOT_ALIGN,
        vmem_budget_bytes=mod.VMEM_BUDGET_BYTES)
