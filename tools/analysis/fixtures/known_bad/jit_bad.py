"""Known-bad jit-hygiene fixture: every finding here is expected."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


def _decode(cache, x, flag):
    # JIT001: `flag` is traced (not static) — Python branch on it
    if flag:
        x = x * 2
    # JIT002: .item() host sync inside a jit-rooted call chain
    peek = x[0].item()
    # JIT003: fresh jax.jit per call
    inner = jax.jit(lambda v: v + peek)
    return inner(x), cache


# JIT004: cache threaded without donate_argnums
decode = jax.jit(_decode)


@functools.partial(jax.jit, static_argnums=(1,))
def windowed(x, sizes):
    return x


def caller(x):
    y, _ = _decode({}, x, True)
    # JIT002: device_get in the step path
    host = np.asarray(jax.device_get(y))
    # JIT003: unhashable list literal at a static position
    return windowed(jnp.asarray(host), [1, 2, 3])


run = jax.jit(caller)       # makes caller an analysis entry point
