"""Known-bad lock-discipline fixture: every finding here is expected."""
import threading
from http.server import BaseHTTPRequestHandler

ENGINE_MUTATORS = frozenset({"submit", "abort", "step", "stats"})


class Server:
    def __init__(self, engine):
        self.engine = engine
        self.cv = threading.Condition()

    def pump(self):
        # LCK001: mutator call without holding cv
        self.engine.step()

    def submit(self, req):
        with self.cv:
            self.engine.submit(req)      # correctly locked

    def stats_unlocked(self):
        eng = self.engine
        # LCK001: alias does not launder the missing lock
        return eng.stats()


class Handler(BaseHTTPRequestHandler):
    def do_POST(self):
        # LCK002: handlers must not reach mutators directly
        self.server.owner.engine.abort(1)
