"""Known-bad pallas-contract fixture: every finding here is expected.

Never imported — the analyzer parses it; CI asserts repro-lint fails
on this directory.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def unguarded_grid(x, bs=128):
    s = x.shape[0]
    # PAL001: s // bs with no divisibility guard — tail silently dropped
    return pl.pallas_call(
        _kernel,
        grid=(s // bs,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def array_in_index_map(x, table, bs=128):
    n = x.shape[0] // bs
    if x.shape[0] % bs:
        raise ValueError("pad first")
    # PAL002: offsets is a device array; the index map must depend only
    # on grid indices and prefetched scalars
    offsets = jnp.cumsum(table)
    return pl.pallas_call(
        _kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (offsets[i],))],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def vmem_hog(x):
    n = x.shape[0] // 4096
    if x.shape[0] % 4096:
        raise ValueError("pad first")
    # PAL003: a (4096, 4096) f32 block is 64 MiB of VMEM
    return pl.pallas_call(
        _kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((4096, 4096), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 4096), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
