"""Known-bad PAL004 fixture: bare tile-floor literals in kernels code."""


def check_block(bs: int, packed: bool) -> None:
    # PAL004: the 32/64 sublane floors must come from kernels.constraints
    if packed and bs < 64:
        raise ValueError("packed4 block too small")
    if not packed and bs < 32:
        raise ValueError("block too small")
