"""Known-clean jit-hygiene fixture: zero findings expected."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


def _decode(cache, x, *, want_extra: bool):
    # keyword-only params are partial-bound statics — branching is fine
    if want_extra:
        x = x + 1
    # shape/ndim reads never touch traced values
    if x.ndim == 2:
        x = x[None]
    return x, cache


decode = jax.jit(_decode, static_argnums=(2,),
                 donate_argnums=(0,))


def collect(results):
    # np.asarray over a host list is not a device sync
    return np.asarray([r for r in results], np.int32)


def fenced(tel, tok):
    with tel.phase("transfer"):
        return jnp.asarray(jax.device_get(tok))


def guarded_fence(tel, tok):
    if tel.sync:
        jax.block_until_ready(tok)
    return tok


def scoped_fence(tok):
    # jax.named_scope is the third documented fence for host syncs
    with jax.named_scope("drift_probe"):
        return np.asarray(jax.device_get(tok))
