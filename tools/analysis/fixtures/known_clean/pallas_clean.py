"""Known-clean pallas-contract fixture: zero findings expected."""
import jax
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def guarded_grid(x, bs=128):
    s = x.shape[0]
    if s % bs:
        raise ValueError("pad to a block multiple first")
    return pl.pallas_call(
        _kernel,
        grid=(s // bs,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def ceil_div_grid(x, bs=128):
    # -(-s // bs) never drops a tail; no guard needed
    return pl.pallas_call(
        _kernel,
        grid=(-(-x.shape[0] // bs),),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def scalar_closure(x, bs=128, heads=4):
    s = x.shape[0]
    if s % bs:
        raise ValueError("pad to a block multiple first")
    hd = x.shape[1] // heads
    # closing over python scalars (bs, hd) is the supported pattern
    return pl.pallas_call(
        _kernel,
        grid=(s // bs, heads),
        in_specs=[pl.BlockSpec((bs, hd), lambda i, h: (i, h))],
        out_specs=pl.BlockSpec((bs, hd), lambda i, h: (i, h)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
