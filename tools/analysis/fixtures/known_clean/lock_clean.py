"""Known-clean lock-discipline fixture: zero findings expected."""
import threading

ENGINE_MUTATORS = frozenset({"submit", "abort", "step", "stats"})


class Server:
    def __init__(self, engine):
        self.engine = engine
        self.cv = threading.Condition()
        engine.submit(None)              # __init__ runs pre-thread

    def pump(self):
        with self.cv:
            self.engine.step()

    def submit(self, req):
        with self.cv:
            self.engine.submit(req)

    def peek(self):
        # non-mutator reads are free
        return self.engine.cfg
