"""Aggregate the dry-run JSONs (experiments/dryrun/*.json) into the
EXPERIMENTS.md §Roofline markdown table."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "experiments/dryrun"):
    cells = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def fmt_ms(x):
    return f"{x * 1e3:,.1f}"


def markdown(cells, mesh: str = "pod16x16") -> str:
    rows = [c for c in cells if c.get("mesh") == mesh
            and c.get("status", "ok") != "fail"]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "bound | useful-FLOPs | roofline frac | fix |",
           "|---|---|---:|---:|---:|---|---:|---:|---|"]
    for c in rows:
        out.append(
            f"| {c['arch']} | {c['shape']} | {fmt_ms(c['t_compute'])} | "
            f"{fmt_ms(c['t_memory'])} | {fmt_ms(c['t_collective'])} | "
            f"{c['bottleneck']} | {100 * c['useful_flops_frac']:.1f}% | "
            f"{100 * c['roofline_frac']:.2f}% | "
            f"{suggestion(c)} |")
    return "\n".join(out)


def suggestion(c) -> str:
    b = c["bottleneck"]
    if b == "memory":
        if c["kind"] == "train":
            return "fuse attention softmax chain (flash kernel)"
        return "pack weights (3-bit) / fuse dequant into matmul"
    if b == "collective":
        if c["kind"] == "decode":
            return "shard KV heads not head_dim; batch more decode steps"
        return "overlap FSDP gathers with compute; bigger microbatch"
    return "increase per-chip work (larger batch) or reduce remat"


def main():
    cells = load()
    ok = [c for c in cells if c.get("status") == "ok" or "t_compute" in c]
    fail = [c for c in cells if c.get("status") == "fail"]
    skip = [c for c in cells if c.get("status") == "skip"]
    print(f"{len(ok)} ok / {len(skip)} skip / {len(fail)} FAIL")
    for c in fail:
        print("  FAIL:", c.get("cell"), c.get("error", "")[:100])
    print()
    print(markdown(cells))


if __name__ == "__main__":
    main()
