"""Self-speculative decoding: tok/s with speculation on vs off.

    PYTHONPATH=src python benchmarks/serve_spec.py [--spec-k ...]

Workload: deterministic greedy requests through the continuous-batching
engine, one off-lane and one on-lane per batch size, identical prompts.
The speculative lane drafts ``spec_k - 1`` tokens with the Q-only graph
in one compiled dispatch, scores them in one full-model verify chunk
per lane, and emits the accepted prefix; the off-lane is plain
per-token decode. Each lane is timed best-of-``--repeats`` on a warmed
engine (every draft-span width and the plain-decode correction path are
pre-compiled by ``Engine.warmup``), so the numbers are steady-state.

The model is the **unquantized** reduced config (the ``--method none``
serving artifact): it carries no low-rank correction, so the Q-only
draft IS the target model and the gains measured here isolate the
speculative *mechanism* — per-round dispatch/host overhead amortized
over k accepted tokens — at its acceptance-rate ceiling. That is also
the regime where greedy parity is structural (read-only verify; every
emitted token and every stored K/V entry comes out of the step graph),
so the per-request token-parity assert holds on any workload, not a
hand-picked seed. With a real Q+LR model the acceptance rate — and
whether speculation pays at all — depends on how well the quantized
base tracks the corrected model; ``examples/ptq_serve.py`` reports that
rate for the paper pipeline.

The gate metric is the **batch-1** tok/s ratio (spec on / off):
speculative decoding is a low-batch latency optimization. Per-token
verify chunks are per-lane dispatches, so at higher batch the off-lane's
single batched decode dispatch wins on CPU — those lanes are reported
for the record but not gated (on TPU the crossover sits elsewhere;
re-measure on hardware contact).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks.common import write_csv, write_summary
except ImportError:  # run as a loose script with benchmarks/ on sys.path
    from common import write_csv, write_summary

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, ServeConfig


def make_reqs(seed: int, vocab: int, n: int, new: int):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(
                0, vocab, size=8 + i % 5).astype(np.int32),
                    max_new_tokens=new) for i in range(n)]


def run_lane(params, cfg, sc: ServeConfig, seed: int, nreq: int, new: int,
             repeats: int, label: str):
    eng = Engine(params, cfg, sc)
    eng.warmup()
    best, results = 0.0, None
    for _ in range(repeats):
        reqs = make_reqs(seed, cfg.vocab, nreq, new)
        t0 = time.perf_counter()
        out = eng.generate(reqs)
        wall = time.perf_counter() - t0
        best = max(best, sum(len(r.tokens) for r in out) / wall)
        results = out
    results.sort(key=lambda r: r.uid)
    st = eng.stats()
    row = {
        "lane": label,
        "batch": sc.decode_batch,
        "tok_per_s": round(best, 1),
        "spec_rounds": st["spec_rounds"],
        "spec_draft_tokens": st["spec_draft_tokens"],
        "spec_accepted_tokens": st["spec_accepted_tokens"],
        "spec_acceptance_rate": round(st["spec_acceptance_rate"], 4),
    }
    return row, results


def _bench(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi3-mini-3.8b")
    p.add_argument("--batches", default="1,2,4,8",
                   help="comma-separated decode_batch sizes; batch 1 "
                        "(the gated lane) must be present")
    p.add_argument("--spec-k", type=int, default=8,
                   help="verify chunk width: 1 fed token + k-1 drafts. "
                        "Larger k amortizes per-round host/dispatch "
                        "overhead over more accepted tokens")
    p.add_argument("--new-tokens", type=int, default=64)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--prefill-len", type=int, default=16)
    p.add_argument("--kv", default="f32",
                   choices=["f32", "bf16", "int8", "int4"])
    p.add_argument("--fused", default="auto", choices=["auto", "on", "off"])
    p.add_argument("--repeats", type=int, default=3,
                   help="timed runs per lane; best-of is reported")
    p.add_argument("--seed", type=int, default=3)
    p.add_argument("--min-speedup", type=float, default=None,
                   help="fail unless batch-1 spec-on tok/s is at least "
                        "this multiple of spec-off (the CI gate)")
    p.add_argument("--quick", action="store_true",
                   help="CI profile: batches 1,2 and 2 repeats "
                        "(overrides --batches/--repeats)")
    args = p.parse_args(argv)
    if args.quick:
        args.batches, args.repeats = "1,2", 2

    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batches = [int(b) for b in args.batches.split(",")]
    assert 1 in batches, "the gate reads the batch-1 ratio"
    print(f"[bench] self-speculative decode, spec_k={args.spec_k}, "
          f"kv={args.kv}, batches {batches}, "
          f"{args.new_tokens} new tokens/request, "
          f"best of {args.repeats} runs per lane")

    rows, ratios = [], {}
    for batch in batches:
        nreq = 6 if batch == 1 else 2 * batch
        base = dict(max_len=args.max_len, decode_batch=batch,
                    max_new_tokens=args.new_tokens,
                    prefill_len=args.prefill_len, kv_dtype=args.kv,
                    fused=args.fused)
        off_row, off_res = run_lane(
            params, cfg, ServeConfig(**base), args.seed, nreq,
            args.new_tokens, args.repeats, "spec_off")
        on_row, on_res = run_lane(
            params, cfg, ServeConfig(speculative=True, spec_k=args.spec_k,
                                     **base),
            args.seed, nreq, args.new_tokens, args.repeats, "spec_on")
        # per-request token parity: greedy speculative output must be
        # the non-speculative output, token for token
        mismatch = [a.uid for a, b in zip(off_res, on_res)
                    if not np.array_equal(a.tokens, b.tokens)]
        assert not mismatch, \
            f"speculation changed outputs at batch={batch}: uids {mismatch}"
        ratio = on_row["tok_per_s"] / max(off_row["tok_per_s"], 1e-9)
        ratios[batch] = ratio
        rows += [off_row, on_row]
        print(f"  batch={batch}: off {off_row['tok_per_s']:7.1f} tok/s  "
              f"on {on_row['tok_per_s']:7.1f} tok/s  ratio {ratio:.2f}x  "
              f"accept {on_row['spec_acceptance_rate']:.3f}  "
              f"parity OK")

    gate_ratio = ratios[1]
    print(f"[bench] batch-1 speculative speedup: {gate_ratio:.2f}x "
          f"(higher batches reported, not gated)")
    if args.min_speedup is not None and gate_ratio < args.min_speedup:
        raise SystemExit(
            f"[bench-gate] FAIL: batch-1 spec speedup {gate_ratio:.2f}x "
            f"is below the floor {args.min_speedup:.2f}x")

    header = ["lane", "batch", "tok_per_s", "spec_rounds",
              "spec_draft_tokens", "spec_accepted_tokens",
              "spec_acceptance_rate"]
    path = write_csv("serve_spec.csv", header,
                     [[r[k] for k in header] for r in rows])
    write_summary("serve_spec", {
        "arch": args.arch,
        "kv_dtype": args.kv,
        "spec_k": args.spec_k,
        "new_tokens": args.new_tokens,
        "repeats": args.repeats,
        "gate": {"spec_tok_per_s_ratio": gate_ratio},
        "ratios_by_batch": {str(b): round(r, 3) for b, r in ratios.items()},
        "lanes": rows,
    })
    print(f"[bench] wrote {path}")
    return path, rows


def run(quick: bool = False):
    """benchmarks.run protocol: returns (csv_path, rows)."""
    argv = ["--quick"] if quick else []
    path, rows = _bench(argv)
    return path, [[r[k] for k in ("lane", "batch", "tok_per_s",
                                  "spec_acceptance_rate")] for r in rows]


def main(argv=None):
    _bench(argv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
