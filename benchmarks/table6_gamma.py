"""Table 6 / App D — gradient scaling on preserved directions:
γ ∈ {0, 0.1, 0.5, 1} and SGP(α = 5) on SRR-based QPEFT.

Paper claim: both extremes lose (γ=1 drifts the preserved subspace, γ=0
over-constrains); moderate scaling and SGP are comparable and best.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import eval_ppl, trained_tiny_model, write_csv
from repro.core.api import PTQConfig
from repro.data import capture_calibration, host_batch
from repro.models import lm_loss
from repro.models.quantize import (merge_qpeft, quantize_model_params,
                                   set_qpeft_scaling, split_qpeft)
from repro.optim import AdamW, cosine_schedule
from repro.quant.base import QuantizerConfig
from repro.train import StepConfig, init_qpeft_state, make_qpeft_step


def run(quick: bool = False):
    steps = 30 if quick else 80
    cfg, params, dcfg = trained_tiny_model(steps=120 if quick else 300)
    dcfg_ft = dataclasses.replace(dcfg, seed=1)
    stats = capture_calibration(
        params, cfg, dcfg, lambda c, p, b, cc: lm_loss(c, p, b, cc),
        n_batches=2)
    srr, _ = quantize_model_params(
        params, stats,
        PTQConfig(method="srr", scaling="qera-exact", rank=8,
                  quantizer=QuantizerConfig("mxint", 3, 32)))

    settings = [("gamma=0", ("gamma", 0.0)), ("gamma=0.1", ("gamma", 0.1)),
                ("gamma=0.5", ("gamma", 0.5)), ("gamma=1", ("gamma", 1.0)),
                ("SGP(a=5)", ("sgp", 5.0))]
    rows = []
    for label, (mode, val) in settings:
        qp = set_qpeft_scaling(srr, mode=mode,
                               **({"gamma": val} if mode == "gamma"
                                  else {"alpha": val}))
        trainable, frozen = split_qpeft(qp)
        opt = AdamW(learning_rate=cosine_schedule(3e-3, 5, steps))
        state = init_qpeft_state(trainable, frozen, opt)
        step = jax.jit(make_qpeft_step(
            cfg, opt, StepConfig(compute_dtype=jnp.float32)))
        for s in range(steps):
            state, _ = step(state, host_batch(dcfg_ft, s))
        merged = merge_qpeft(state.trainable, state.frozen)
        ppl = eval_ppl(merged, cfg, dcfg_ft, start_step=10_000)
        rows.append((label, f"{ppl:.3f}"))
    path = write_csv("table6_gamma.csv", ["scaling", "ppl_tuned"], rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r in rows:
        print(r)
    print("->", path)
