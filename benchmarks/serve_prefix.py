"""Paged serving under shared-prefix traffic: prefix-cache wins by overlap.

    PYTHONPATH=src python benchmarks/serve_prefix.py [--arch ...]

Workload: bursts of requests whose prompts share a leading "system
prompt" covering 0% / 50% / 90% of the prompt, with unique tails — the
dominant production pattern (same scaffold in front of every user turn).
The paged engine's radix-tree prefix cache maps the shared blocks into
each new request's block table and skips their prefill compute; the
benchmark reports, per overlap lane, TTFT p50, aggregate tok/s, the
prefix hit rate, and — the deterministic gate metric — how much prefill
work (prompt tokens actually computed) the cache removed vs the same
workload with the prefix cache disabled:

    prefix_prefill_skip_90 = tokens_computed(no cache) /
                             tokens_computed(cache)   at 90% overlap

The first ``decode_batch`` admissions necessarily miss (the donor
request inserts its blocks only once its own prefill completes), so the
ratio is below the ideal 1/(1-overlap); the floor in ``gate.py``
accounts for that. A parity check asserts the 90% lane's tokens are
identical with and without reuse — mapped prefix pages must be
behaviorally invisible.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks.common import write_csv, write_summary
except ImportError:  # run as a loose script with benchmarks/ on sys.path
    from common import write_csv, write_summary

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, ServeConfig, percentile


def make_workload(rng: np.random.Generator, n: int, vocab: int,
                  prompt_len: int, overlap: float, max_new: int):
    """Prompts share a leading ``overlap``-fraction system prefix."""
    shared = rng.integers(0, vocab, size=int(round(prompt_len * overlap)))
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab, size=prompt_len - len(shared))
        reqs.append(Request(
            uid=i,
            prompt=np.concatenate([shared, tail]).astype(np.int32),
            max_new_tokens=max_new))
    return reqs


def clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens) for r in reqs]


def run_lane(params, cfg, sc: ServeConfig, reqs, label: str):
    eng = Engine(params, cfg, sc)
    eng.warmup()                         # compile chunk + decode shapes
    t0 = time.perf_counter()
    res = eng.generate(clone(reqs))
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in res)
    ttfts = [r.ttft_s for r in res if r.ttft_s is not None]
    st = eng.stats()
    row = {
        "lane": label,
        "tok_per_s": toks / wall,
        "ttft_p50_ms": percentile(ttfts, 0.50) * 1e3,
        "ttft_p95_ms": percentile(ttfts, 0.95) * 1e3,
        "prefill_tokens_computed": st["prefill_tokens_computed"],
        "prompt_tokens_total": st["prompt_tokens_total"],
        "prefix_hit_rate": st["prefix_hit_rate"],
        "evictions": st["evictions"],
    }
    return row, res


def run(quick: bool = False):
    """benchmarks.run protocol: returns (csv_path, rows)."""
    argv = ["--requests", "8", "--new-tokens", "6"] if quick else []
    path, rows = _bench(argv)
    return path, [[r[k] for k in ("lane", "tok_per_s", "ttft_p50_ms",
                                  "prefix_hit_rate",
                                  "prefill_tokens_computed")] for r in rows]


def _bench(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi3-mini-3.8b")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--new-tokens", type=int, default=8)
    p.add_argument("--max-len", type=int, default=96)
    p.add_argument("--prompt-len", type=int, default=40)
    p.add_argument("--prefill-len", type=int, default=16,
                   help="chunk width: prompts stream in chunks this size")
    p.add_argument("--page-size", type=int, default=8)
    p.add_argument("--kv", default="bf16",
                   choices=["f32", "bf16", "int8", "int4"])
    p.add_argument("--fused", default="auto", choices=["auto", "on", "off"])
    p.add_argument("--min-skip", type=float, default=None,
                   help="fail unless the 90%%-overlap prefill-work "
                        "reduction is at least this (the CI gate floor)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    base = dict(max_len=args.max_len, decode_batch=args.batch,
                max_new_tokens=args.new_tokens, kv_dtype=args.kv,
                prefill_len=args.prefill_len, fused=args.fused,
                paged=True, page_size=args.page_size)
    print(f"[bench] {args.requests} requests × {args.prompt_len}-token "
          f"prompts, chunk={args.prefill_len}, page={args.page_size}, "
          f"batch={args.batch}, kv={args.kv}")

    rows = []
    by_lane = {}
    for overlap in (0.0, 0.5, 0.9):
        rng = np.random.default_rng(args.seed + int(overlap * 10))
        reqs = make_workload(rng, args.requests, cfg.vocab,
                             args.prompt_len, overlap, args.new_tokens)
        row, res = run_lane(params, cfg, ServeConfig(**base), reqs,
                            f"overlap_{int(overlap * 100)}")
        rows.append(row)
        by_lane[overlap] = (reqs, row, res)
        print(f"  {row['lane']:11s}: {row['tok_per_s']:8.1f} tok/s  "
              f"ttft p50 {row['ttft_p50_ms']:6.1f}ms  "
              f"hit {row['prefix_hit_rate']:.2f}  "
              f"computed {row['prefill_tokens_computed']}"
              f"/{row['prompt_tokens_total']}")

    # no-reuse baseline on the 90% workload: same prompts, prefix cache
    # off — the deterministic denominator for the gate, plus the token
    # parity check (reuse must be behaviorally invisible)
    reqs90, row90, res90 = by_lane[0.9]
    row_nr, res_nr = run_lane(
        params, cfg, ServeConfig(prefix_cache=False, **base), reqs90,
        "overlap_90_noreuse")
    rows.append(row_nr)
    print(f"  {row_nr['lane']:11s}: {row_nr['tok_per_s']:8.1f} tok/s  "
          f"ttft p50 {row_nr['ttft_p50_ms']:6.1f}ms  "
          f"computed {row_nr['prefill_tokens_computed']}"
          f"/{row_nr['prompt_tokens_total']}")

    mismatch = [a.uid for a, b in zip(res90, res_nr)
                if not np.array_equal(a.tokens, b.tokens)]
    assert not mismatch, \
        f"prefix reuse changed greedy outputs for uids {mismatch}"
    print("[bench] reuse parity: identical tokens with and without cache")

    skip = (row_nr["prefill_tokens_computed"]
            / max(row90["prefill_tokens_computed"], 1))
    ttft_speedup = row_nr["ttft_p50_ms"] / max(row90["ttft_p50_ms"], 1e-9)
    print(f"[bench] 90%-overlap prefill-work reduction: {skip:.2f}x "
          f"(ttft p50 speedup {ttft_speedup:.2f}x)")
    if args.min_skip is not None and skip < args.min_skip:
        raise SystemExit(
            f"[bench-gate] FAIL: 90%-overlap prefill-work reduction "
            f"{skip:.2f}x is below the floor {args.min_skip:.2f}x")

    header = ["lane", "tok_per_s", "ttft_p50_ms", "ttft_p95_ms",
              "prefill_tokens_computed", "prompt_tokens_total",
              "prefix_hit_rate", "evictions"]
    path = write_csv("serve_prefix.csv", header,
                     [[r[k] for k in header] for r in rows])
    write_summary("serve_prefix", {
        "arch": args.arch,
        "kv_dtype": args.kv,
        "page_size": args.page_size,
        "prompt_len": args.prompt_len,
        "gate": {"prefix_prefill_skip_90": skip},
        "ttft_p50_speedup_90": ttft_speedup,
        "lanes": rows,
    })
    print(f"[bench] wrote {path}")
    return path, rows


def main(argv=None):
    _bench(argv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
