"""Tables 3/4 — QPEFT: SRR init + γ-scaling vs QLoRA / LoftQ / QERA /
LQ-LoRA initializations.

All methods share the quantized backbone, rank budget, optimizer and
step count; only the adapter INIT (and gradient scaling) differs:

  QLoRA   : Q = 𝒬(W); L ~ N(0, σ), R = 0 (adapter starts at zero)
  LoftQ   : 5 alternating iterations of 𝒬 / SVD_r refitting
  QERA    : Q = 𝒬(W); LR = SVD_r(S(W−Q)) (k = 0)
  LQ-LoRA : preserve-only split (k = r): LR = SVD_r(SW), Q = 𝒬(W−LR)
  SRR     : Algorithm 1 init (k = k*) + γ = 0.1 gradient scaling

Reported: eval perplexity (Table 4 stand-in) and next-token accuracy
(Table 3 stand-in) after a short fine-tune on held-out-shifted data.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (eval_ppl, eval_top1, trained_tiny_model,
                               write_csv)
from repro.core.api import CalibStats, PTQConfig
from repro.data import capture_calibration, data_config_for, host_batch
from repro.models import lm_loss
from repro.models.quantize import (quantize_model_params, set_qpeft_scaling,
                                   split_qpeft, merge_qpeft)
from repro.optim import AdamW, cosine_schedule
from repro.quant import MXIntQuantizer
from repro.quant.base import QuantizerConfig
from repro.train import StepConfig, init_qpeft_state, make_qpeft_step

QZ = QuantizerConfig(kind="mxint", bits=3, block_size=32)


def _loftq_like(params, stats, rank, iters=5):
    """LoftQ-style alternating refinement applied matrix-wise."""
    import repro.models.quantize as MQ
    q = MXIntQuantizer(bits=3, block_size=32)

    def refit(w):
        w = jnp.asarray(w, jnp.float32)
        l = jnp.zeros((w.shape[0], rank), jnp.float32)
        r = jnp.zeros((rank, w.shape[1]), jnp.float32)
        for _ in range(iters):
            qw = q.fake_quant(w - l @ r)
            u, s, vt = jnp.linalg.svd(w - qw, full_matrices=False)
            l = u[:, :rank]
            r = s[:rank, None] * vt[:rank]
        return qw, l, r

    # reuse the SRR container by re-decomposing each quantized linear
    ptq = PTQConfig(method="qer", scaling="identity", rank=rank,
                    quantizer=QZ)
    qp, _ = quantize_model_params(params, None, ptq)

    def walk(orig, node):
        if isinstance(node, dict) and "codes" in node:
            w = jnp.asarray(orig["w"], jnp.float32)
            lead = w.shape[:-2]
            mats = w.reshape((-1,) + w.shape[-2:]) if lead else w[None]
            packs = []
            for i in range(mats.shape[0]):
                qw, l, r = refit(mats[i])
                packed = q.quantize(qw)
                packs.append(dict(
                    codes=packed.codes,
                    scale=jnp.exp2(packed.exponents.astype(jnp.float32)),
                    l=l, r=r, gscale=jnp.ones((rank,), jnp.float32)))
            out = dict(node)
            for key in ("codes", "scale", "l", "r", "gscale"):
                stacked = jnp.stack([pk[key] for pk in packs])
                out[key] = stacked.reshape(lead + stacked.shape[1:]) \
                    if lead else stacked[0]
            return out
        if isinstance(node, dict):
            return {k: walk(orig[k] if isinstance(orig, dict) else None, v)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(orig[i], v) for i, v in enumerate(node)]
        return node

    return walk(params, qp)


def _qlora_like(params, rank, seed=0):
    ptq = PTQConfig(method="w-only", scaling="identity", rank=rank,
                    quantizer=QZ)
    qp, _ = quantize_model_params(params, None, ptq)
    key = jax.random.PRNGKey(seed)

    def walk(node):
        nonlocal key
        if isinstance(node, dict) and "codes" in node:
            out = dict(node)
            key, sub = jax.random.split(key)
            out["l"] = jax.random.normal(sub, node["l"].shape) * 0.01
            out["r"] = jnp.zeros_like(node["r"])
            out["gscale"] = jnp.ones(node["gscale"].shape, jnp.float32)
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(qp)


def _finetune(cfg, qparams, dcfg_ft, steps, lr=3e-3):
    trainable, frozen = split_qpeft(qparams)
    opt = AdamW(learning_rate=cosine_schedule(lr, 5, steps))
    state = init_qpeft_state(trainable, frozen, opt)
    step = jax.jit(make_qpeft_step(
        cfg, opt, StepConfig(compute_dtype=jnp.float32)))
    for s in range(steps):
        state, _ = step(state, host_batch(dcfg_ft, s))
    return merge_qpeft(state.trainable, state.frozen)


def run(quick: bool = False):
    steps = 30 if quick else 80
    rank = 8
    cfg, params, dcfg = trained_tiny_model(steps=120 if quick else 300)
    # fine-tuning "task": a different-seed corpus (domain shift)
    dcfg_ft = dataclasses.replace(dcfg, seed=1)
    stats = capture_calibration(
        params, cfg, dcfg, lambda c, p, b, cc: lm_loss(c, p, b, cc),
        n_batches=2)

    inits = {}
    inits["QLoRA"] = _qlora_like(params, rank)
    inits["LoftQ"] = _loftq_like(params, stats, rank)
    qera, _ = quantize_model_params(
        params, stats, PTQConfig(method="qer", scaling="qera-exact",
                                 rank=rank, quantizer=QZ))
    inits["QERA"] = set_qpeft_scaling(qera, mode="none")
    lq, _ = quantize_model_params(
        params, stats, PTQConfig(method="srr", scaling="qera-exact",
                                 rank=rank, quantizer=QZ, forced_k=rank))
    inits["LQ-LoRA"] = set_qpeft_scaling(lq, mode="none")
    srr, _ = quantize_model_params(
        params, stats, PTQConfig(method="srr", scaling="qera-exact",
                                 rank=rank, quantizer=QZ))
    inits["SRR"] = set_qpeft_scaling(srr, mode="gamma", gamma=0.1)

    rows = []
    for name, qp in inits.items():
        ppl0 = eval_ppl(qp, cfg, dcfg_ft, start_step=10_000)
        tuned = _finetune(cfg, qp, dcfg_ft, steps)
        ppl1 = eval_ppl(tuned, cfg, dcfg_ft, start_step=10_000)
        acc1 = eval_top1(tuned, cfg, dcfg_ft, start_step=10_000)
        rows.append((name, f"{ppl0:.3f}", f"{ppl1:.3f}", f"{acc1:.4f}"))
    path = write_csv("table34_qpeft.csv",
                     ["init", "ppl_init", "ppl_tuned", "top1_tuned"], rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r in rows:
        print(r)
    print("->", path)
