"""Table 11 / App A.4 — computational overhead of SRR over QER.

Wall-clock on realistic matrix sizes: scaling-matrix construction (the
pipeline's dominant cost), QER decomposition, SRR decomposition (extra
SVDs via the randomized sketch, n_iter=4, oversample 2r — App A.4), and
the SRR/QER ratio. Paper reports ×1.06 on the quant+reconstruct stage.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import calib_activations, synthetic_weight, write_csv
from repro.core import make_scaling, qer_decompose, srr_decompose
from repro.quant import MXIntQuantizer

QZ = MXIntQuantizer(bits=3, block_size=32)


def _time(fn, reps=2):
    fn()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn()))
    return (time.perf_counter() - t0) / reps


def run(quick: bool = False):
    sizes = [(512, 512)] if quick else [(512, 512), (1024, 1024),
                                        (1024, 2048)]
    r = 64
    rows = []
    for m, n in sizes:
        w = synthetic_weight(jax.random.PRNGKey(0), m, n, "o")
        x = calib_activations(1, 2 * m, m)
        t_scale = _time(lambda: make_scaling("qera-exact", x))
        s = make_scaling("qera-exact", x)
        t_qer = _time(lambda: qer_decompose(w, s, QZ, r, exact=False,
                                            key=jax.random.PRNGKey(1)))
        t_srr = _time(lambda: srr_decompose(
            w, s, QZ, r, jax.random.PRNGKey(1), exact=False))
        ratio = t_srr / t_qer
        full = (t_scale + t_srr) / (t_scale + t_qer)
        rows.append((f"{m}x{n}", f"{t_scale * 1e3:.0f}",
                     f"{t_qer * 1e3:.0f}", f"{t_srr * 1e3:.0f}",
                     f"x{ratio:.2f}", f"x{full:.2f}"))
    path = write_csv(
        "table11_overhead.csv",
        ["matrix", "scaling_ms", "QER_ms", "SRR_ms", "QERvsSRR",
         "full_pipeline"], rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r_ in rows:
        print(r_)
    print("->", path)
