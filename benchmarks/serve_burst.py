"""Token-budget step scheduler under a long-prompt burst: tail latency.

    PYTHONPATH=src python benchmarks/serve_burst.py [--arch ...]

Workload: a deterministic, step-indexed open-loop arrival pattern on
the paged engine — short interactive requests are decoding when a burst
of multi-chunk long prompts lands on the same step, then more shorts
arrive behind the burst. Without a budget the engine admits the whole
burst at once and every one of its prefill chunks runs in the same
engine step, so the live decode lanes stall for the full burst width;
with ``max_step_tokens = chunk + decode_batch`` the chunks serialize
across steps and per-step work stays bounded.

The gate metric is the p95 **engine step time** ratio (budget off /
budget on), read from the telemetry ``step_seconds`` histogram — for a
decoding lane the step time *is* its inter-token latency, so this is
the p95 ITL a user sees during the burst. Both lanes run on warmed
engines with repeats interleaved, each lane keeping its best (lowest)
p95 — single-run percentile ratios swing ±15% with machine phase.
The per-request mean-ITL and
TTFT percentiles are reported alongside for context (the budget spreads
the same total prefill work, so means move far less than the tail).
No ad-hoc timers: every number comes out of ``Engine.stats()``.

A parity check asserts both lanes produce identical tokens — the
budget defers work but must never change any request's output
(counter-based per-lane sampling makes output scheduling-independent).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks.common import write_csv, write_summary
except ImportError:  # run as a loose script with benchmarks/ on sys.path
    from common import write_csv, write_summary

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, ServeConfig


def make_workload(rng: np.random.Generator, vocab: int, chunk: int,
                  n_chunks_long: int, short_new: int, long_new: int):
    """step index → requests arriving then (uids globally unique)."""
    def req(uid, n, new):
        return Request(uid=uid, prompt=rng.integers(
            0, vocab, size=n).astype(np.int32), max_new_tokens=new)

    long_len = chunk * n_chunks_long - 4     # multi-chunk, uneven tail
    return {
        0: [req(i, 8 + i, short_new) for i in range(3)],
        3: [req(3 + i, long_len, long_new) for i in range(4)],
        6: [req(7 + i, 10 + i, short_new) for i in range(3)],
    }


def clone_workload(arrivals):
    return {s: [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens) for r in reqs]
            for s, reqs in arrivals.items()}


def run_lane(eng: Engine, arrivals, label: str):
    """One measured pass of the arrival pattern on a warmed engine.
    ``reset_stats()`` opens a fresh histogram window so repeats on the
    same engine don't pollute each other's percentiles."""
    eng.reset_stats()
    t0 = time.perf_counter()
    step, results = 0, []
    last = max(arrivals)
    while eng.sched.has_work or step <= last:
        for r in arrivals.get(step, []):
            eng.submit(r)
        results.extend(eng.step())
        step += 1
    wall = time.perf_counter() - t0
    results.sort(key=lambda r: r.uid)
    st = eng.stats()
    toks = sum(len(r.tokens) for r in results)
    row = {
        "lane": label,
        "tok_per_s": round(toks / wall, 1),
        "steps": st["decode_steps"],
        "step_p50_ms": round(st["step_seconds"]["p50"] * 1e3, 3),
        "step_p95_ms": round(st["step_seconds"]["p95"] * 1e3, 3),
        "itl_p95_ms": round(st["itl_seconds"]["p95"] * 1e3, 3),
        "ttft_p50_ms": round(st["ttft_seconds"]["p50"] * 1e3, 3),
        "ttft_p95_ms": round(st["ttft_seconds"]["p95"] * 1e3, 3),
        "deferred_admissions": st["budget_deferred_admissions"],
        "capped_chunks": st["budget_capped_chunks"],
    }
    return row, results


def _bench(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi3-mini-3.8b")
    p.add_argument("--batch", type=int, default=6)
    p.add_argument("--max-len", type=int, default=192)
    p.add_argument("--prefill-len", type=int, default=32,
                   help="chunk width; the budget lane caps each step at "
                        "one chunk + the decode lanes")
    p.add_argument("--page-size", type=int, default=8)
    p.add_argument("--long-chunks", type=int, default=4,
                   help="burst prompt length in chunks")
    p.add_argument("--short-new", type=int, default=24)
    p.add_argument("--long-new", type=int, default=8)
    p.add_argument("--kv", default="bf16",
                   choices=["f32", "bf16", "int8", "int4"])
    p.add_argument("--fused", default="auto", choices=["auto", "on", "off"])
    p.add_argument("--repeats", type=int, default=5,
                   help="interleaved measured passes per lane; each "
                        "lane keeps its best (lowest) p95")
    p.add_argument("--min-improvement", type=float, default=None,
                   help="fail unless p95 step time improves at least "
                        "this much with the budget on (the CI gate)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    chunk = args.prefill_len
    base = dict(max_len=args.max_len, decode_batch=args.batch,
                kv_dtype=args.kv, prefill_len=chunk, fused=args.fused,
                paged=True, page_size=args.page_size, prefix_cache=False,
                telemetry=True)
    budget = chunk + args.batch
    print(f"[bench] burst of 4×{args.long_chunks}-chunk prompts into "
          f"{args.batch} lanes, chunk={chunk}, budget lane "
          f"max_step_tokens={budget}")

    rng = np.random.default_rng(args.seed)
    arrivals = make_workload(rng, cfg.vocab, chunk, args.long_chunks,
                             args.short_new, args.long_new)

    # both lanes warmed up front, then repeats interleaved (off, on,
    # off, ...) keeping each lane's best (lowest) p95 — the gate
    # compares the structural stall gap, and a noisy machine phase
    # landing entirely on one lane's timing window can swing a
    # single-run p95 ratio by ±15%
    engines = {}
    for mst, label in ((None, "budget_off"), (budget, "budget_on")):
        engines[label] = Engine(params, cfg,
                                ServeConfig(max_step_tokens=mst, **base))
        engines[label].warmup()       # compile chunk + decode shapes
    best, outs = {}, {}
    for _ in range(args.repeats):
        for label, eng in engines.items():
            row, res = run_lane(eng, clone_workload(arrivals), label)
            if label not in best \
                    or row["step_p95_ms"] < best[label]["step_p95_ms"]:
                best[label] = row
                outs[label] = res
    rows = [best["budget_off"], best["budget_on"]]
    for row in rows:
        print(f"  {row['lane']:10s}: step p95 {row['step_p95_ms']:7.2f}ms "
              f"p50 {row['step_p50_ms']:6.2f}ms  "
              f"ttft p95 {row['ttft_p95_ms']:7.1f}ms  "
              f"{row['steps']:.0f} steps  "
              f"deferred {row['deferred_admissions']:.0f} "
              f"capped {row['capped_chunks']:.0f}")

    mismatch = [a.uid for a, b in zip(outs["budget_off"], outs["budget_on"])
                if not np.array_equal(a.tokens, b.tokens)]
    assert not mismatch, \
        f"the step budget changed outputs for uids {mismatch}"
    print("[bench] budget parity: identical tokens with and without it")

    improvement = rows[0]["step_p95_ms"] / max(rows[1]["step_p95_ms"], 1e-9)
    print(f"[bench] p95 step-time (per-token ITL) improvement with the "
          f"budget: {improvement:.2f}x")
    if args.min_improvement is not None \
            and improvement < args.min_improvement:
        raise SystemExit(
            f"[bench-gate] FAIL: p95 step-time improvement "
            f"{improvement:.2f}x is below the floor "
            f"{args.min_improvement:.2f}x")

    header = ["lane", "tok_per_s", "steps", "step_p50_ms", "step_p95_ms",
              "itl_p95_ms", "ttft_p50_ms", "ttft_p95_ms",
              "deferred_admissions", "capped_chunks"]
    path = write_csv("serve_burst.csv", header,
                     [[r[k] for k in header] for r in rows])
    write_summary("serve_burst", {
        "arch": args.arch,
        "kv_dtype": args.kv,
        "chunk": chunk,
        "max_step_tokens": budget,
        "gate": {"budget_step_p95_improvement": improvement},
        "lanes": rows,
    })
    print(f"[bench] wrote {path}")
    return path, rows


def run(quick: bool = False):
    """benchmarks.run protocol: returns (csv_path, rows)."""
    # the CI bench-gate workload: a 4-chunk burst keeps the unbudgeted
    # stall step structurally wide, so the measured p95 ratio holds
    # ≈1.8-2.1x on CPU — comfortably above the 1.6x floor
    argv = ["--long-chunks", "4", "--short-new", "12",
            "--long-new", "4"] if quick else []
    path, rows = _bench(argv)
    return path, [[r[k] for k in ("lane", "step_p95_ms", "ttft_p95_ms",
                                  "deferred_admissions")] for r in rows]


def main(argv=None):
    _bench(argv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
