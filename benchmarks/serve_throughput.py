"""Serving throughput: continuous batching vs the bucketed baseline.

    PYTHONPATH=src python benchmarks/serve_throughput.py [--arch ...]

Workload: one burst of requests whose prompt lengths are Poisson-mixed
(4 + Poisson(mean 8), the realistic "no two prompts align" regime) and
whose per-request ``max_new_tokens`` budgets vary. The bucketed
scheduler degrades here by construction — every distinct prompt length
opens an under-full bucket padded to ``decode_batch``, and every bucket
decodes to its slowest member — while the continuous scheduler keeps
all slots busy by admitting the next queued request the moment a slot
retires.

Both engines are fully warmed (all shapes compiled) before timing, so
the measured gap is pure scheduling efficiency, not compile amortization.
Reported: aggregate tokens/s, p50/p95 end-to-end latency, lane occupancy
— plus a greedy-parity check (both schedulers must emit identical tokens
per request).

A third, ungated lane re-runs the continuous workload with full
telemetry (metrics + lifecycle tracing) enabled and asserts (a) tokens
stay identical and (b) throughput stays within 15% of the disabled run
(best-of-N on both sides, repeats interleaved, to absorb scheduler
jitter — per-step trace cost is proportionally larger on short-decode
workloads like the CI gate's 8-token bursts, where it measures ≈5-10%).
The telemetry run's trace and metrics snapshots are written to
``benchmarks/out/`` as CI artifacts.

A fourth, likewise ungated lane measures the accuracy-drift monitor
(``ServeConfig(drift_monitor=True)``, sample rate 0.25): the sampled
shadow probe must change zero tokens and keep throughput within 15% of
the unmonitored run (the ISSUE budget is ≤3% at the default 0.05 rate;
benching at 5x that rate with a 15% allowance absorbs CI jitter while
still catching a probe that leaks into the serving path).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

try:
    from benchmarks.common import out_path, write_csv, write_summary
except ImportError:  # run as a loose script with benchmarks/ on sys.path
    from common import out_path, write_csv, write_summary

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import Engine, Request, ServeConfig, percentile


def make_workload(rng: np.random.Generator, n: int, vocab: int,
                  max_new: int, prefill_len: int):
    reqs = []
    for i in range(n):
        plen = int(np.clip(4 + rng.poisson(8), 1, prefill_len))
        budget = int(rng.integers(max(2, max_new // 2), max_new + 1))
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
            max_new_tokens=budget))
    return reqs


def clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens) for r in reqs]


def run_pair(params, cfg, base: dict, reqs, repeats: int = 3):
    """Both schedulers, best-of-``repeats`` each, repeats *interleaved*
    (bucketed, continuous, bucketed, ...): the gate compares the two
    lanes' steady-state ceilings, and timing each lane's runs back to
    back lets one noisy machine phase land entirely on one side and
    move the ratio by ±15%. Interleaving spreads jitter across both."""
    engines, best, results = {}, {}, {}
    for label in ("bucketed", "continuous"):
        engines[label] = Engine(params, cfg,
                                ServeConfig(scheduler=label, **base))
        engines[label].generate(clone(reqs))  # warm: compile every shape
    for _ in range(repeats):
        for label, eng in engines.items():
            t0 = time.perf_counter()
            res = eng.generate(clone(reqs))
            wall = time.perf_counter() - t0
            tps = sum(len(r.tokens) for r in res) / wall
            if tps > best.get(label, 0.0):
                best[label] = tps
                results[label] = (res, wall)
    rows = {}
    for label, eng in engines.items():
        res, wall = results[label]
        toks = sum(len(r.tokens) for r in res)
        lats = [r.latency_s for r in res if r.latency_s is not None]
        rows[label] = {
            "scheduler": label,
            "tokens": toks,
            "wall_s": wall,
            "tok_per_s": toks / wall,
            "p50_ms": percentile(lats, 0.50) * 1e3,
            "p95_ms": percentile(lats, 0.95) * 1e3,
            "occupancy": eng.stats()["occupancy"],
        }
    return rows, {label: results[label][0] for label in results}


def telemetry_overhead(params, cfg, base, reqs, repeats: int = 5):
    """Best-of-``repeats`` tok/s with telemetry off vs fully on, repeats
    interleaved across the two warmed engines (a noisy machine phase
    must not land entirely on one side), plus the on-side engine for
    artifact export. Tokens must be identical — telemetry may only
    observe."""
    engines = {label: Engine(params, cfg, ServeConfig(
        scheduler="continuous", telemetry=tel, **base))
        for label, tel in (("off", False), ("on", True))}
    for eng in engines.values():
        eng.generate(clone(reqs))       # warm: compile every shape
    best = {}
    results = {}
    for _ in range(repeats):
        for label, eng in engines.items():
            t0 = time.perf_counter()
            res = eng.generate(clone(reqs))
            wall = time.perf_counter() - t0
            tps = sum(len(r.tokens) for r in res) / wall
            best[label] = max(best.get(label, 0.0), tps)
            results[label] = res
    mismatch = [a.uid for a, b in zip(results["off"], results["on"])
                if not np.array_equal(a.tokens, b.tokens)]
    assert not mismatch, \
        f"telemetry changed greedy outputs for uids {mismatch}"
    return best["on"] / best["off"], best, engines["on"]


def drift_overhead(params, cfg, base, reqs, repeats: int = 5,
                   sample_rate: float = 0.25):
    """Best-of-``repeats`` tok/s with the accuracy-drift monitor off vs
    on (sampled shadow probe at ``sample_rate``), repeats interleaved
    like the telemetry lane. The monitor is read-only by construction —
    tokens must be identical — and its cost is the probe dispatch plus
    one small host transfer per sampled step."""
    engines = {label: Engine(params, cfg, ServeConfig(
        scheduler="continuous", drift_monitor=mon,
        drift_sample_rate=sample_rate, **base))
        for label, mon in (("off", False), ("on", True))}
    for eng in engines.values():
        eng.generate(clone(reqs))       # warm: compile every shape
    best = {}
    results = {}
    for _ in range(repeats):
        for label, eng in engines.items():
            t0 = time.perf_counter()
            res = eng.generate(clone(reqs))
            wall = time.perf_counter() - t0
            tps = sum(len(r.tokens) for r in res) / wall
            best[label] = max(best.get(label, 0.0), tps)
            results[label] = res
    mismatch = [a.uid for a, b in zip(results["off"], results["on"])
                if not np.array_equal(a.tokens, b.tokens)]
    assert not mismatch, \
        f"drift monitor changed greedy outputs for uids {mismatch}"
    return best["on"] / best["off"], best, engines["on"]


def run(quick: bool = False):
    """benchmarks.run protocol: returns (csv_path, rows)."""
    # the CI bench-gate workload: 16 mixed-length requests over 8 decode
    # slots is where bucketed fragmentation is starkest (each distinct
    # prompt length opens a bucket padded to 8), keeping the measured
    # ratio comfortably above the 2.0x floor (≈2.3-2.5x on CPU)
    argv = ["--requests", "16", "--batch", "8", "--new-tokens", "8"] \
        if quick else []
    path, rows = _bench(argv)
    return path, [[r[k] for k in ("scheduler", "tok_per_s", "p50_ms",
                                  "p95_ms", "occupancy")] for r in rows]


def _bench(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi3-mini-3.8b")
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--max-len", type=int, default=96)
    p.add_argument("--prefill-len", type=int, default=32)
    p.add_argument("--kv", default="bf16",
                   choices=["f32", "bf16", "int8", "int4"])
    p.add_argument("--fused", default="auto", choices=["auto", "on", "off"],
                   help="fused Q+LR matmul path for both schedulers")
    p.add_argument("--min-speedup", type=float, default=None,
                   help="fail unless continuous/bucketed tok/s ≥ this "
                        "ratio (the CI bench-gate floor)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    reqs = make_workload(rng, args.requests, cfg.vocab, args.new_tokens,
                         args.prefill_len)
    n_lens = len({len(r.prompt) for r in reqs})
    print(f"[bench] {args.requests} requests, {n_lens} distinct prompt "
          f"lengths, batch={args.batch}, kv={args.kv}")

    base = dict(max_len=args.max_len, decode_batch=args.batch,
                max_new_tokens=args.new_tokens, kv_dtype=args.kv,
                prefill_len=args.prefill_len, fused=args.fused)
    pair_rows, pair_res = run_pair(params, cfg, base, reqs)
    row_b, res_b = pair_rows["bucketed"], pair_res["bucketed"]
    row_c, res_c = pair_rows["continuous"], pair_res["continuous"]
    rows = [row_b, row_c]

    for row in rows:
        print(f"  {row['scheduler']:10s}: {row['tok_per_s']:8.1f} tok/s  "
              f"p50 {row['p50_ms']:7.1f}ms  p95 {row['p95_ms']:7.1f}ms  "
              f"occupancy {row['occupancy']:.2f}")

    mismatch = [r.uid for (r, s) in zip(res_b, res_c)
                if not np.array_equal(r.tokens, s.tokens)]
    assert not mismatch, f"greedy outputs diverged for uids {mismatch}"
    print("[bench] greedy parity: identical tokens per request")

    speedup = row_c["tok_per_s"] / row_b["tok_per_s"]
    print(f"[bench] continuous/bucketed speedup: {speedup:.2f}x")
    assert row_c["tok_per_s"] > row_b["tok_per_s"], \
        "continuous batching must beat the bucketed baseline"
    if args.min_speedup is not None and speedup < args.min_speedup:
        raise SystemExit(
            f"[bench-gate] FAIL: continuous/bucketed speedup {speedup:.2f}x "
            f"is below the floor {args.min_speedup:.2f}x")

    # telemetry overhead lane (ungated — not a gate.py floor): full
    # tracing must cost ≤ 15% throughput and change zero tokens; the
    # per-step trace cost is proportionally larger on short-decode
    # workloads (the CI gate's 8-token bursts measure ≈5-10% here,
    # long-decode workloads ≈0-3%)
    ratio, best, eng_tel = telemetry_overhead(params, cfg, base, reqs)
    print(f"[bench] telemetry overhead: {best['on']:.1f} vs "
          f"{best['off']:.1f} tok/s (ratio {ratio:.3f})")
    assert ratio >= 0.85, \
        f"telemetry overhead ratio {ratio:.3f} below the 0.85 floor"
    with open(out_path("serve_metrics.json"), "w") as f:
        json.dump(eng_tel.stats(), f, indent=2, sort_keys=True)
        f.write("\n")
    with open(out_path("serve_metrics.prom"), "w") as f:
        f.write(eng_tel.prometheus())
    eng_tel.write_trace(out_path("serve_trace.json"),
                        jsonl_path=out_path("serve_trace.jsonl"))
    print("[bench] telemetry artifacts: serve_metrics.json/.prom, "
          "serve_trace.json/.jsonl")

    # drift-monitor overhead lane (ungated): the sampled shadow probe
    # must be token-invisible and cheap even at 5x the default rate
    dratio, dbest, eng_drift = drift_overhead(params, cfg, base, reqs)
    dstats = eng_drift.stats()
    print(f"[bench] drift-monitor overhead: {dbest['on']:.1f} vs "
          f"{dbest['off']:.1f} tok/s (ratio {dratio:.3f}); "
          f"{int(dstats['drift_checks'])} checks, top-1 agreement "
          f"{dstats['drift_top1_agreement_rate']:.3f}")
    assert dratio >= 0.85, \
        f"drift-monitor overhead ratio {dratio:.3f} below the 0.85 floor"
    assert dstats["drift_checks"] > 0, \
        "drift lane ran without a single sampled check"

    path = write_csv("serve_throughput.csv",
                     ["scheduler", "tokens", "wall_s", "tok_per_s",
                      "p50_ms", "p95_ms", "occupancy"],
                     [[r[k] for k in ("scheduler", "tokens", "wall_s",
                                      "tok_per_s", "p50_ms", "p95_ms",
                                      "occupancy")] for r in rows])
    write_summary("serve_throughput", {
        "backend": jax.default_backend(),
        "arch": args.arch,
        "kv_dtype": args.kv,
        "gate": {"continuous_vs_bucketed": speedup},
        "telemetry_overhead_ratio": ratio,
        "drift_overhead_ratio": dratio,
        "lanes": rows,
    })
    print(f"[bench] wrote {path}")
    return path, rows


def main(argv=None):
    _bench(argv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
