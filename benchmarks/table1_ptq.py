"""Table 1 — PTQ perplexity: {LQER, QERA-approx, QERA-exact} ± SRR.

Paper claim: under the same rank budget, SRR reduces perplexity for every
scaling choice. Here: a trained tiny transformer, MXINT-3 b32, ranks
{8, 16}; perplexity on held-out synthetic data. BF16 and w-only rows
bracket the table exactly as in the paper.
"""
from __future__ import annotations

import jax

from benchmarks.common import eval_ppl, trained_tiny_model, write_csv
from repro.core.api import PTQConfig
from repro.data import capture_calibration
from repro.models import lm_loss
from repro.models.quantize import quantize_model_params
from repro.quant.base import QuantizerConfig

SCALINGS = [("lqer", "LQER"), ("qera-approx", "QERA-approx"),
            ("qera-exact", "QERA-exact")]


def run(quick: bool = False):
    cfg, params, dcfg = trained_tiny_model(steps=120 if quick else 300)
    stats = capture_calibration(
        params, cfg, dcfg, lambda c, p, b, cc: lm_loss(c, p, b, cc),
        n_batches=2)
    rows = [("bf16", "-", "-", f"{eval_ppl(params, cfg, dcfg):.3f}")]
    qz = QuantizerConfig(kind="mxint", bits=3, block_size=32)

    ranks = [8] if quick else [8, 16]
    # w-only (rank-independent)
    qp, _ = quantize_model_params(
        params, stats, PTQConfig(method="w-only", scaling="identity",
                                 rank=8, quantizer=qz))
    rows.append(("w-only", "-", "-", f"{eval_ppl(qp, cfg, dcfg):.3f}"))

    for scaling, label in SCALINGS:
        for rank in ranks:
            for method, tag in (("qer", label), ("srr", f"{label} + SRR")):
                ptq = PTQConfig(method=method, scaling=scaling, rank=rank,
                                quantizer=qz, seed=0)
                qp, reps = quantize_model_params(params, stats, ptq)
                ppl = eval_ppl(qp, cfg, dcfg)
                kbar = sum(r.k_star for r in reps) / max(len(reps), 1)
                rows.append((tag, scaling, rank, f"{ppl:.3f}",
                             f"{kbar:.1f}"))
    path = write_csv("table1_ptq.csv",
                     ["method", "scaling", "rank", "ppl", "mean_k*"], rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r in rows:
        print(r)
    print("->", path)
