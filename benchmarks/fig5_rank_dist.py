"""Fig 5 / App B.1–B.2 — k* distribution per projection type + stability.

The paper finds k* varies systematically by projection (Q/K concentrated
spectra ⇒ larger preserved rank; V flatter ⇒ smaller) and is stable to
the probe seed (±1–3 at transformer dims). Reproduced on matrix-level
synthetic weights whose spectral profiles follow the same ordering.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import calib_activations, synthetic_layer, write_csv
from repro.core import make_scaling, select_rank


def run(quick: bool = False):
    d = 256 if quick else 384
    r = 32
    n_layers = 2 if quick else 4
    n_seeds = 2 if quick else 3
    per_proj: dict = {}
    stab: dict = {}
    for layer_seed in range(n_layers):
        layer = synthetic_layer(layer_seed, d=d)
        for name, w in layer.items():
            x = calib_activations(layer_seed * 31 + hash(name) % 97,
                                  4 * w.shape[0], w.shape[0])
            s = make_scaling("qera-exact", x)
            ks = [int(select_rank(w, s, r, jax.random.PRNGKey(seed),
                                  exact=True).k_star)
                  for seed in range(n_seeds)]
            per_proj.setdefault(name, []).append(ks[0])
            stab.setdefault(name, []).append(max(ks) - min(ks))
    rows = []
    for name in ("q", "k", "v", "o", "gate", "up", "down"):
        ks = per_proj[name]
        rows.append((name, f"{np.mean(ks):.1f}", min(ks), max(ks),
                     f"{np.mean(stab[name]):.1f}", max(stab[name])))
    path = write_csv(
        "fig5_rank_dist.csv",
        ["proj", "mean_k*", "min", "max", "mean_seed_dk", "max_seed_dk"],
        rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r_ in rows:
        print(r_)
    print("->", path)
