"""Fig 7 / App C.2 — layer-wise weight reconstruction error under
ZeroQuant-V2 (S = I): QER vs SRR on the trained tiny model.

Paper claim: SRR achieves lower ‖W − Q − LR‖_F on (nearly) every layer.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import trained_tiny_model, write_csv
from repro.core import identity_scaling, qer_decompose, srr_decompose, weight_error
from repro.quant import MXIntQuantizer

QZ = MXIntQuantizer(bits=3, block_size=32)


def run(quick: bool = False):
    cfg, params, _ = trained_tiny_model(steps=120 if quick else 300)
    s = identity_scaling()
    rows = []
    wins = 0
    total = 0
    # walk every projection of the trained model
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if not key.endswith("['w']") or leaf.ndim < 2:
            continue
        if "embed" in key or "lm_head" in key:
            continue
        mats = leaf.reshape((-1,) + leaf.shape[-2:]) if leaf.ndim > 2 \
            else leaf[None]
        for i in range(mats.shape[0]):
            w = mats[i]
            r = min(16, min(w.shape) // 2)
            eq = float(weight_error(
                w, qer_decompose(w, s, QZ, r, exact=True)))
            es = float(weight_error(
                w, srr_decompose(w, s, QZ, r, jax.random.PRNGKey(0),
                                 exact=True).decomposition))
            total += 1
            wins += es <= eq * 1.001
            rows.append((f"{key}[{i}]", r, f"{eq:.4f}", f"{es:.4f}",
                         f"{100 * (1 - es / eq):.1f}%"))
    rows.append(("SRR wins", "-", "-", "-", f"{wins}/{total}"))
    path = write_csv("fig7_layerwise.csv",
                     ["weight", "rank", "QER_err", "SRR_err", "improvement"],
                     rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r_ in rows[-6:]:
        print(r_)
    print("->", path)
