"""Fused Q+LR decode matmul vs its unfused lowerings.

    PYTHONPATH=src python benchmarks/fused_linear.py [--quick] [--min-speedup X]

The serving hot spot is ``y = x · dequant(Q) + (x · L) · R`` at decode
shapes (a handful of activation rows against a large quantized weight).
Three lowerings are timed per (m, k, n, r) shape:

  * ``fp_dense``       — full-precision ``x @ W`` (the no-quantization
    roofline reference);
  * ``dequant_matmul`` — materialize ``W' = dequant(Q) + L·R`` densely,
    then ``x @ W'``: the naive QER serving lowering (what the repo's MLA
    absorbed decode still does via ``weight_of``, and what LQER/QERA call
    the unfused baseline);
  * ``fused``          — ``repro.kernels.ops.qlr_matmul``, exactly what
    ``linear()`` executes under ``ctx.fused`` — the Pallas kernel on TPU
    (weight never materializes in HBM), the fused-XLA form elsewhere
    (blockwise dequant feeding the GEMM + activation-sliver correction,
    no dense ``L·R``).

Every path runs jitted and warmed; medians over repeated sweeps. CSV to
``benchmarks/out/fused_linear.csv`` with per-shape speedups. CI's
bench-gate job runs ``--quick`` and uploads the CSV; ``--min-speedup``
(default 1.5 under the gate) fails the run if the fused path does not
beat ``dequant_matmul`` by that factor at the batch-8 decode shape.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import write_csv, write_summary
except ImportError:  # run as a loose script with benchmarks/ on sys.path
    from common import write_csv, write_summary

from repro.kernels.ops import qlr_matmul
from repro.quant import MXIntQuantizer

GATE_M = 8  # the decode batch the speedup floor is enforced at


def _timeit(fn, args, iters: int) -> float:
    """Median wall time (ms) of a jitted call, warmed."""
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


@jax.jit
def _fp_dense(x, w):
    return x @ w


@jax.jit
def _dequant_matmul(x, codes, scale, l, r):
    """The unfused baseline: W' = dequant(Q) + L·R materialized densely,
    then one GEMM — two full (K, N) HBM round trips per call."""
    k, n = codes.shape
    nb = scale.shape[0]
    w = (codes.astype(jnp.float32).reshape(nb, k // nb, n)
         * scale[:, None, :]).reshape(k, n)
    w = w + l @ r
    return x @ w


def _fused(x, codes, scale, l, r):
    return qlr_matmul(x, codes, scale, l, r)


def bench_shape(key, m: int, k: int, n: int, r: int, iters: int):
    """Rows [(path, m, k, n, r, ms, speedup_vs_dequant), ...]."""
    kx, kw, kl, kr = jax.random.split(key, 4)
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    packed = MXIntQuantizer(bits=3, block_size=32).quantize(w)
    codes = packed.codes
    scale = jnp.exp2(packed.exponents.astype(jnp.float32))
    l = jax.random.normal(kl, (k, r)) * 0.02
    rr = jax.random.normal(kr, (r, n)) * 0.02

    ms = {
        "fp_dense": _timeit(_fp_dense, (x, w), iters),
        "dequant_matmul": _timeit(_dequant_matmul,
                                  (x, codes, scale, l, rr), iters),
        "fused": _timeit(_fused, (x, codes, scale, l, rr), iters),
    }
    base = ms["dequant_matmul"]
    return [(path, m, k, n, r, t, base / t) for path, t in ms.items()]


def _bench(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="small shapes / few iters (the CI bench-gate mode)")
    p.add_argument("--rank", type=int, default=32)
    p.add_argument("--iters", type=int, default=None)
    p.add_argument("--min-speedup", type=float, default=None,
                   help="fail unless fused beats dequant_matmul by this "
                        f"factor at the batch-{GATE_M} decode shape")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    if args.quick:
        # the gated batch-8 row keeps the full (2048²) weight: at 1024²
        # the dequant materialization is small enough that timer noise
        # eats into the contrast
        shapes = [(1, 1024, 1024), (GATE_M, 2048, 2048)]
        iters = args.iters or 15
    else:
        shapes = [(1, 2048, 2048), (GATE_M, 2048, 2048),
                  (64, 2048, 2048), (GATE_M, 4096, 4096)]
        iters = args.iters or 40

    backend = jax.default_backend()
    print(f"[bench] fused Q+LR matmul on backend={backend} "
          f"(fused path = {'pallas kernel' if backend == 'tpu' else 'fused-XLA'}), "
          f"rank={args.rank}, {iters} iters/shape")

    key = jax.random.PRNGKey(args.seed)
    rows = []
    gate_speedup = None
    for m, k, n in shapes:
        shape_rows = bench_shape(jax.random.fold_in(key, m * 131 + k), m, k,
                                 n, args.rank, iters)
        rows.extend(shape_rows)
        by_path = {row[0]: row for row in shape_rows}
        fused_speed = by_path["fused"][6]
        if m == GATE_M and gate_speedup is None:
            gate_speedup = fused_speed
        print(f"  m={m:3d} k={k} n={n}: "
              + "  ".join(f"{path} {row[5]:7.3f}ms" for path, row in by_path.items())
              + f"  → fused {fused_speed:.2f}x vs dequant")

    path = write_csv("fused_linear.csv",
                     ["path", "m", "k", "n", "r", "ms", "speedup_vs_dequant"],
                     rows)
    write_summary("fused_linear", {
        "backend": backend,
        "rank": args.rank,
        "gate": {f"fused_vs_dequant_b{GATE_M}": gate_speedup},
        "lanes": [{"path": r[0], "m": r[1], "k": r[2], "n": r[3],
                   "ms": r[5], "speedup_vs_dequant": r[6]} for r in rows],
    })
    print(f"[bench] wrote {path}")
    print(f"[bench] fused/dequant speedup at batch {GATE_M}: "
          f"{gate_speedup:.2f}x")
    if args.min_speedup is not None and gate_speedup < args.min_speedup:
        raise SystemExit(
            f"[bench-gate] FAIL: fused speedup {gate_speedup:.2f}x at batch "
            f"{GATE_M} is below the floor {args.min_speedup:.2f}x")
    return path, rows


def run(quick: bool = False):
    """benchmarks.run protocol: returns (csv_path, rows)."""
    return _bench(["--quick"] if quick else [])


def main(argv=None):
    _bench(argv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
