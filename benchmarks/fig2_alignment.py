"""Fig 2 / App B.3 — alignment of the rank-selection surrogate with the
true reconstruction error over k, per projection type.

For each projection: L(k) by brute force (quantize + SVD per k) and the
surrogate ρ_k(SW)·ρ_{r−k}(SE_probe); reports Spearman correlation and the
true-error regret of the surrogate's argmin.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import calib_activations, synthetic_layer, write_csv
from repro.core import make_scaling, select_rank
from repro.core.rank_alloc import true_reconstruction_error
from repro.quant import MXIntQuantizer

QZ = MXIntQuantizer(bits=3, block_size=32)


def _spearman(a, b):
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    return float((ra * rb).sum()
                 / np.sqrt((ra ** 2).sum() * (rb ** 2).sum() + 1e-12))


def run(quick: bool = False):
    d = 192 if quick else 320
    r = 24
    step = 8 if quick else 4
    layer = synthetic_layer(3, d=d)
    rows = []
    curves = []
    for name in ("q", "o", "v", "down"):
        w = layer[name]
        x = calib_activations(hash(name) % 991, 4 * w.shape[0], w.shape[0])
        s = make_scaling("qera-exact", x)
        sel = select_rank(w, s, r, jax.random.PRNGKey(0), exact=True)
        ks = list(range(0, r + 1, step))
        true = [float(true_reconstruction_error(w, s, QZ, r, k)) for k in ks]
        surr = [float(sel.objective[k]) for k in ks]
        for k, t, u in zip(ks, true, surr):
            curves.append((name, k, f"{t:.5f}", f"{u:.5f}"))
        k_sur = int(sel.k_star)
        t_at = float(true_reconstruction_error(w, s, QZ, r, k_sur))
        regret = t_at / min(true) - 1.0
        rows.append((name, f"{_spearman(true, surr):.3f}", k_sur,
                     ks[int(np.argmin(true))], f"{100 * regret:.2f}%"))
    write_csv("fig2_curves.csv", ["proj", "k", "true_L", "surrogate"],
              curves)
    path = write_csv("fig2_alignment.csv",
                     ["proj", "spearman", "k*_surrogate", "k*_true",
                      "regret"], rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r_ in rows:
        print(r_)
    print("->", path)
