"""Run every paper-table benchmark: ``python -m benchmarks.run [--quick]``.

One module per paper artifact (see DESIGN.md §7); CSVs land in
benchmarks/out/. The dry-run/roofline tables are produced separately by
``python -m repro.launch.dryrun`` + ``python -m benchmarks.roofline_table``
(they need the 512-device XLA flag set before jax init).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    decode_attention,
    fig2_alignment,
    fig5_rank_dist,
    fig7_layerwise,
    fused_linear,
    serve_burst,
    serve_prefix,
    serve_spec,
    serve_throughput,
    table1_ptq,
    table2_downstream,
    table34_qpeft,
    table5_quantizers,
    table6_gamma,
    table11_overhead,
    table20_assumptions,
)

BENCHES = [
    ("Table 1 (PTQ ppl: QER methods ± SRR)", table1_ptq),
    ("Table 2 (downstream acc proxy)", table2_downstream),
    ("Tables 3/4 (QPEFT inits)", table34_qpeft),
    ("Table 5 (quantizer-agnostic)", table5_quantizers),
    ("Table 6 (γ sweep + SGP)", table6_gamma),
    ("Table 11 (overhead)", table11_overhead),
    ("Tables 20/21 (assumptions)", table20_assumptions),
    ("Fig 2 (surrogate alignment)", fig2_alignment),
    ("Fig 5 (k* distribution)", fig5_rank_dist),
    ("Fig 7 (layer-wise error)", fig7_layerwise),
    ("Serving (continuous vs bucketed tok/s)", serve_throughput),
    ("Serving (paged prefix-cache reuse)", serve_prefix),
    ("Serving (token-budget burst tail latency)", serve_burst),
    ("Serving (self-speculative decode tok/s)", serve_spec),
    ("Fused Q+LR matmul (fused vs dequant-then-matmul)", fused_linear),
    ("Decode attention (flash-decode vs XLA-over-cache)", decode_attention),
]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--only", default=None,
                   help="substring filter on benchmark names")
    args = p.parse_args(argv)

    failures = 0
    for name, mod in BENCHES:
        if args.only and args.only.lower() not in name.lower():
            continue
        t0 = time.perf_counter()
        print(f"=== {name} ===")
        try:
            path, rows = mod.run(quick=args.quick)
            for r in rows:
                print("   ", *r)
            print(f"  -> {path}  ({time.perf_counter() - t0:.1f}s)\n")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"  FAILED ({time.perf_counter() - t0:.1f}s)\n")
    print(f"[benchmarks] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
