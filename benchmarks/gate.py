"""CI perf-regression gate over the machine-readable bench summaries.

    PYTHONPATH=src python benchmarks/gate.py [--require NAME ...]

Every perf benchmark writes ``benchmarks/out/BENCH_<name>.json`` beside
its CSV (``benchmarks.common.write_summary``); the ``gate`` dict inside
maps gate-metric names to measured speedups. This script is the single
place the floors live: it loads every summary present, checks each
metric it knows a floor for, and fails the run on any regression. CI's
bench-gate job runs the benchmarks *without* their inline
``--min-speedup`` flags and then runs this — so the JSON artifacts it
uploads are exactly what was enforced, and the perf trajectory stays
diffable across PRs.

Floors are **keyed per JAX backend** (every summary is stamped with the
backend it ran on): the CPU numbers gate today's CI; the ``tpu`` table
is the landing pad for the ROADMAP's hardware-validation item — seeded
at the CPU floors where a lane exists there, to be re-measured and
raised on first hardware contact (the int4 lane especially: its HBM
halving is invisible on a compute-bound CPU). An unknown backend falls
back to the ``cpu`` table rather than passing silently.

Floors (raise them when a PR durably improves the measurement — don't
delete the gate):

  * continuous batching ≥ 2.0× bucketed tok/s (PR 1 measured ≈1.4× and
    set 1.2×; later scheduler/telemetry work pushed the margin well
    past 2× durably, so the floor followed);
  * fused Q+LR matmul ≥ 1.5× dequant-then-matmul at batch 8 (PR 2);
  * fused decode attention ≥ 1.3× XLA-over-int8-cache at the batch-8
    long-context shape (PR 3 measured ≈1.5–1.8× on CPU);
  * fused decode attention over the **int4 packed cache** ≥ 1.3× the
    same XLA-over-int8-cache baseline (PR 4 measured ≈1.9× on CPU);
  * paged prefix cache at 90% prompt overlap removes ≥ 1.8× the
    prefill work of the same workload with reuse disabled (PR 5; the
    metric is a deterministic token count, not a timing — the first
    ``decode_batch`` admissions always miss, which is why the floor
    sits below the ideal 1/(1-overlap) ≈ 5×);
  * the token-budget step scheduler cuts p95 engine step time (the
    per-token ITL a decoding lane sees) under a long-prompt burst by
    ≥ 1.6× vs the same workload unbudgeted (PR 7 measured ≈1.9–2.0×
    on CPU and set 1.3×; re-measurement showed the margin is durable,
    so the floor followed. It stays under the measured ratio because
    the off-lane p95 rides on how many burst chunks land in one step,
    which is timing-noisy);
  * self-speculative decoding ≥ 1.2× non-speculative tok/s at batch 1
    on a greedy workload, token parity asserted per request (PR 8
    measured ≈1.4–1.8× at spec_k=8 on CPU; batch 1 is where the
    per-lane verify chunks don't fight a batched decode dispatch).
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
TOOLS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "tools")
BENCH_SCHEMA = os.path.join(TOOLS_DIR, "bench_schema.json")

# backend → summary name → [(gate metric, floor), ...]. The cpu table
# gates CI; tpu entries are seeded (see module docstring) and expected
# to be re-measured upward on hardware.
FLOORS = {
    "cpu": {
        "serve_throughput": [("continuous_vs_bucketed", 2.0)],
        "fused_linear": [("fused_vs_dequant_b8", 1.5)],
        "decode_attention": [("fused_vs_xla_cache_int8_b8", 1.3),
                             ("fused_vs_xla_cache_int4_b8", 1.3)],
        "serve_prefix": [("prefix_prefill_skip_90", 1.8)],
        "serve_burst": [("budget_step_p95_improvement", 1.6)],
        "serve_spec": [("spec_tok_per_s_ratio", 1.2)],
    },
    "tpu": {
        "serve_throughput": [("continuous_vs_bucketed", 2.0)],
        "fused_linear": [("fused_vs_dequant_b8", 1.5)],
        "decode_attention": [("fused_vs_xla_cache_int8_b8", 1.3),
                             ("fused_vs_xla_cache_int4_b8", 1.3)],
        # deterministic work-count metric: backend-independent
        "serve_prefix": [("prefix_prefill_skip_90", 1.8)],
        "serve_burst": [("budget_step_p95_improvement", 1.6)],
        "serve_spec": [("spec_tok_per_s_ratio", 1.2)],
    },
}


def floors_for(backend: str):
    return FLOORS.get(backend, FLOORS["cpu"])


def _load_validator():
    """The schema validator lives in tools/validate_metrics.py (shared
    with the serve-metrics smoke); import it by path so this script
    works however it is invoked."""
    spec = importlib.util.spec_from_file_location(
        "_validate_metrics", os.path.join(TOOLS_DIR, "validate_metrics.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def validate_summary(path: str, data, validator, schema) -> list:
    """Schema-check one BENCH_*.json; returns the error list. A summary
    that does not parse against tools/bench_schema.json must fail the
    gate loudly — a malformed artifact silently skipping its floor is
    exactly the regression-hiding this gate exists to prevent."""
    return validator.validate(data, schema, schema,
                              path=os.path.basename(path))


def known_names():
    return sorted({n for table in FLOORS.values() for n in table})


def check(names=None) -> int:
    """Check all floors whose summaries exist; ``names`` makes the given
    summaries mandatory (missing file = failure). Each summary is gated
    against the floor table of the backend it ran on. Returns
    #failures."""
    failures = 0
    validator = _load_validator()
    with open(BENCH_SCHEMA) as f:
        schema = json.load(f)
    for name in known_names():
        path = os.path.join(OUT_DIR, f"BENCH_{name}.json")
        if not os.path.exists(path):
            if names and name in names:
                print(f"[gate] FAIL {name}: required summary {path} missing "
                      f"— did the benchmark run?")
                failures += 1
            else:
                print(f"[gate] skip {name}: no summary at {path}")
            continue
        with open(path) as f:
            data = json.load(f)
        errors = validate_summary(path, data, validator, schema)
        if errors:
            for e in errors:
                print(f"[gate] FAIL {name}: summary schema: {e}")
            failures += len(errors)
            continue
        backend = data.get("backend", "cpu")
        gate = data.get("gate", {})
        floors = floors_for(backend).get(name)
        if floors is None:
            print(f"[gate] skip {name}: no {backend} floors registered")
            continue
        for metric, floor in floors:
            got = gate.get(metric)
            if got is None:
                print(f"[gate] FAIL {name}.{metric}: not in summary "
                      f"(gate keys: {sorted(gate)})")
                failures += 1
            elif got < floor:
                print(f"[gate] FAIL {name}.{metric} [{backend}]: "
                      f"{got:.2f}x is below the floor {floor:.2f}x")
                failures += 1
            else:
                print(f"[gate] ok   {name}.{metric} [{backend}]: {got:.2f}x "
                      f"(floor {floor:.2f}x)")
    return failures


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--require", nargs="*", default=known_names(),
                   help="summaries that must exist (default: all known)")
    args = p.parse_args(argv)
    failures = check(set(args.require))
    if failures:
        print(f"[gate] {failures} floor(s) violated")
        return 1
    print("[gate] all floors hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
