"""CI perf-regression gate over the machine-readable bench summaries.

    PYTHONPATH=src python benchmarks/gate.py [--require NAME ...]

Every perf benchmark writes ``benchmarks/out/BENCH_<name>.json`` beside
its CSV (``benchmarks.common.write_summary``); the ``gate`` dict inside
maps gate-metric names to measured speedups. This script is the single
place the floors live: it loads every summary present, checks each
metric it knows a floor for, and fails the run on any regression. CI's
bench-gate job runs the benchmarks *without* their inline
``--min-speedup`` flags and then runs this — so the JSON artifacts it
uploads are exactly what was enforced, and the perf trajectory stays
diffable across PRs.

Floors (raise them when a PR durably improves the measurement — don't
delete the gate):

  * continuous batching ≥ 1.2× bucketed tok/s (PR 1 measured ≈1.4×);
  * fused Q+LR matmul ≥ 1.5× dequant-then-matmul at batch 8 (PR 2);
  * fused decode attention ≥ 1.3× XLA-over-int8-cache at the batch-8
    long-context shape (PR 3 measured ≈1.5–1.8× on CPU);
  * fused decode attention over the **int4 packed cache** ≥ 1.3× the
    same XLA-over-int8-cache baseline — the cache a server would run
    without the packed container, at twice the HBM (PR 4 measured
    ≈1.9× on CPU: fused int4 matches or beats fused int8 wall-clock
    while halving the cache bytes).
"""
from __future__ import annotations

import argparse
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

# summary name → [(gate metric, floor), ...]
FLOORS = {
    "serve_throughput": [("continuous_vs_bucketed", 1.2)],
    "fused_linear": [("fused_vs_dequant_b8", 1.5)],
    "decode_attention": [("fused_vs_xla_cache_int8_b8", 1.3),
                         ("fused_vs_xla_cache_int4_b8", 1.3)],
}


def check(names=None) -> int:
    """Check all floors whose summaries exist; ``names`` makes the given
    summaries mandatory (missing file = failure). Returns #failures."""
    failures = 0
    for name, floors in FLOORS.items():
        path = os.path.join(OUT_DIR, f"BENCH_{name}.json")
        if not os.path.exists(path):
            if names and name in names:
                print(f"[gate] FAIL {name}: required summary {path} missing "
                      f"— did the benchmark run?")
                failures += 1
            else:
                print(f"[gate] skip {name}: no summary at {path}")
            continue
        with open(path) as f:
            gate = json.load(f).get("gate", {})
        for metric, floor in floors:
            got = gate.get(metric)
            if got is None:
                print(f"[gate] FAIL {name}.{metric}: not in summary "
                      f"(gate keys: {sorted(gate)})")
                failures += 1
            elif got < floor:
                print(f"[gate] FAIL {name}.{metric}: {got:.2f}x is below "
                      f"the floor {floor:.2f}x")
                failures += 1
            else:
                print(f"[gate] ok   {name}.{metric}: {got:.2f}x "
                      f"(floor {floor:.2f}x)")
    return failures


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--require", nargs="*", default=sorted(FLOORS),
                   help="summaries that must exist (default: all known)")
    args = p.parse_args(argv)
    failures = check(set(args.require))
    if failures:
        print(f"[gate] {failures} floor(s) violated")
        return 1
    print("[gate] all floors hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
