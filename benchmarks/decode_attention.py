"""Flash-decode attention vs its unfused lowerings over the slot cache.

    PYTHONPATH=src python benchmarks/decode_attention.py [--quick] [--min-speedup X]

With every quantized projection fused (PR 2), serving decode is
dominated by the attention read over the slot KV cache. Three lowerings
are timed per (batch, seq, kv_dtype) shape:

  * ``f32_dense``      — f32 cache, sequence-major einsum: the
    no-quantization roofline reference (4× the int8 cache bytes);
  * ``xla_cache``      — the dequantize-the-whole-cache serving
    lowering: for int8, the pre-PR sequence-major cache densified into
    f32 each step (which also forces XLA to relayout the cache for the
    batched GEMMs — two full HBM round trips over the largest live
    tensor per token); for int4, the packed head-major pages unpacked +
    dequantized densely before the einsums; for bf16, the cast;
  * ``fused``          — ``repro.kernels.ops.decode_attention_op``,
    exactly what ``attention_step`` executes under ``ctx.fused``: the
    Pallas flash-decode kernel on TPU (head-major cache streamed once,
    int8 dequant in VMEM, int4 nibbles unpacked in VMEM at 0.5 byte/elt
    of HBM traffic), the fused-XLA lowering elsewhere (head-major
    batched GEMMs straight over the codes, scales folded into the
    score/probability planes — no dense cache, no relayout).

Every path runs jitted and warmed; medians over repeated sweeps. CSV to
``benchmarks/out/decode_attention.csv`` plus a machine-readable
``benchmarks/out/BENCH_decode_attention.json`` summary whose ``gate``
dict carries the speedups at the batch-8 long-context shape — CI's
bench-gate (``benchmarks/gate.py``) enforces the floors from there.
Both gated lanes measure against the **int8 dense baseline** (the cache
a server would run without the respective fused path): int8 fused ≥
1.3×, and int4 fused ≥ 1.3× at *half the cache HBM* — on CPU the fused
int4 path matches or beats fused int8 (the shift-based nibble unpack is
cheaper than the halved-byte stream is on a compute-bound backend; on
TPU the halved HBM stream is the point). ``--min-speedup`` /
``--min-speedup-int4`` enforce inline for standalone runs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import write_csv, write_summary
except ImportError:  # run as a loose script with benchmarks/ on sys.path
    from common import write_csv, write_summary

from repro.kernels.ops import decode_attention_op
from repro.quant.mxint import pack_codes_4bit

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
GATE_B = 8  # the decode batch the speedup floor is enforced at


def _timeit(fn, args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


@jax.jit
def _xla_seq_major(q, k, v, q_pos, k_pos):
    """Pre-PR decode attention over a sequence-major dense cache."""
    hd = q.shape[-1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    mask = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


@jax.jit
def _xla_int8_cache(q, kc, ks, vc, vs, q_pos, k_pos):
    """The unfused baseline: dequantize the whole sequence-major int8
    cache into f32, then the dense einsums — what ``attention_step`` did
    before the head-major refactor."""
    k = kc.astype(jnp.float32) * ks[..., None]
    v = vc.astype(jnp.float32) * vs[..., None]
    return _xla_seq_major(q, k, v, q_pos, k_pos)


@jax.jit
def _xla_bf16_cache(q, k, v, q_pos, k_pos):
    """bf16 variant of the unfused baseline (cast instead of dequant)."""
    return _xla_seq_major(q, k.astype(jnp.float32), v.astype(jnp.float32),
                          q_pos, k_pos)


def _unpack_seq_major(p):
    """(B, S/2, KV, hd) packed → (B, S, KV, hd) int8 codes, nibbles
    interleaving along the sequence axis — no transposes, so the
    baseline einsum below really receives a sequence-major dense cache
    (a swapaxes round-trip would let XLA cancel the relayout the int8
    baseline pays)."""
    b, s2, kv, hd = p.shape
    lo = (p << 4).astype(jnp.int8) >> 4
    hi = p.astype(jnp.int8) >> 4
    return jnp.stack([lo, hi], axis=2).reshape(b, s2 * 2, kv, hd)


@jax.jit
def _xla_int4_cache(q, kp, ks, vp, vs, q_pos, k_pos):
    """Unfused int4 baseline, the same counterfactual the int8 lane
    uses: a *sequence-major* packed cache (B, S/2, KV, hd) unpacked and
    dequantized densely into f32 every step, then the sequence-major
    einsums (which, like the int8 baseline, force the relayout of the
    whole dense cache for the batched GEMMs)."""
    k = _unpack_seq_major(kp).astype(jnp.float32) * ks[..., None]
    v = _unpack_seq_major(vp).astype(jnp.float32) * vs[..., None]
    return _xla_seq_major(q, k, v, q_pos, k_pos)


def _fused_int8(q, kc, ks, vc, vs, q_pos, k_pos):
    return decode_attention_op(q[:, 0], kc, vc, q_pos, k_pos,
                               k_scale=ks, v_scale=vs)


def _fused_float(q, k, v, q_pos, k_pos):
    return decode_attention_op(q[:, 0], k, v, q_pos, k_pos)


def bench_shape(key, b: int, s_len: int, kv: int, g: int, hd: int,
                kv_dtype: str, iters: int):
    """Rows [(path, b, s, kv_dtype, kv, g, hd, ms, speedup), ...]."""
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, 1, kv, g, hd))
    k = jax.random.normal(kk, (b, s_len, kv, hd))        # sequence-major
    v = jax.random.normal(kv_, (b, s_len, kv, hd))
    q_pos = jnp.full((b,), s_len - 1, jnp.int32)
    k_pos = jnp.broadcast_to(jnp.arange(s_len)[None],
                             (b, s_len)).astype(jnp.int32)
    # head-major copies — the layout the refactored cache stores
    khm, vhm = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)

    ms = {"f32_dense": _timeit(_xla_seq_major, (q, k, v, q_pos, k_pos),
                               iters)}
    if kv_dtype in ("int8", "int4"):
        qmax = 127 if kv_dtype == "int8" else 7
        amax = jnp.max(jnp.abs(k), axis=-1)
        ks = jnp.maximum(amax, 1e-8) / qmax
        kc = jnp.clip(jnp.round(k / ks[..., None]), -qmax, qmax).astype(jnp.int8)
        amax = jnp.max(jnp.abs(v), axis=-1)
        vs = jnp.maximum(amax, 1e-8) / qmax
        vc = jnp.clip(jnp.round(v / vs[..., None]), -qmax, qmax).astype(jnp.int8)
        kchm, vchm = kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3)
        kshm, vshm = ks.transpose(0, 2, 1), vs.transpose(0, 2, 1)
        if kv_dtype == "int4":
            # pack slot pairs two-per-byte along the head-major slot axis
            kphm, vphm = pack_codes_4bit(kchm), pack_codes_4bit(vchm)
            # the baseline's sequence-major container (same bytes)
            kpsm, vpsm = kphm.swapaxes(1, 2), vphm.swapaxes(1, 2)
            ms["xla_cache"] = _timeit(
                _xla_int4_cache, (q, kpsm, ks, vpsm, vs, q_pos, k_pos),
                iters)
            ms["fused"] = _timeit(
                _fused_int8, (q, kphm, kshm, vphm, vshm, q_pos, k_pos),
                iters)
        else:
            ms["xla_cache"] = _timeit(
                _xla_int8_cache, (q, kc, ks, vc, vs, q_pos, k_pos), iters)
            ms["fused"] = _timeit(
                _fused_int8, (q, kchm, kshm, vchm, vshm, q_pos, k_pos),
                iters)
    else:  # bf16
        kb, vb = k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
        ms["xla_cache"] = _timeit(_xla_bf16_cache, (q, kb, vb, q_pos, k_pos),
                                  iters)
        ms["fused"] = _timeit(
            _fused_float,
            (q, khm.astype(jnp.bfloat16), vhm.astype(jnp.bfloat16),
             q_pos, k_pos), iters)
    base = ms["xla_cache"]
    return [(path, b, s_len, kv_dtype, kv, g, hd, t, base / t)
            for path, t in ms.items()]


def _bench(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="few shapes / few iters (the CI bench-gate mode)")
    p.add_argument("--iters", type=int, default=None)
    p.add_argument("--min-speedup", type=float, default=None,
                   help="fail unless fused beats xla_cache by this factor "
                        f"at the batch-{GATE_B} long-context int8 shape")
    p.add_argument("--min-speedup-int4", type=float, default=None,
                   help="same floor for the int4 (packed4) lane")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    kv, g, hd = 4, 8, 128
    if args.quick:
        shapes = [(1, 4096, "int8"), (GATE_B, 8192, "int8"),
                  (GATE_B, 8192, "int4"), (GATE_B, 4096, "bf16")]
        iters = args.iters or 8
    else:
        shapes = [(b, s, d) for d in ("int8", "int4", "bf16")
                  for b in (1, GATE_B) for s in (1024, 4096, 8192)]
        iters = args.iters or 20

    backend = jax.default_backend()
    print(f"[bench] decode attention on backend={backend} "
          f"(fused path = {'pallas flash-decode' if backend == 'tpu' else 'fused-XLA'}), "
          f"KV={kv} G={g} hd={hd}, {iters} iters/shape")

    key = jax.random.PRNGKey(args.seed)
    rows = []
    gate_ms = {}                 # kv_dtype → {path: ms} at the gate shape
    gate_s = max(s for _, s, d in shapes if d == "int8")
    for b, s_len, kv_dtype in shapes:
        shape_rows = bench_shape(jax.random.fold_in(key, b * 131 + s_len),
                                 b, s_len, kv, g, hd, kv_dtype, iters)
        rows.extend(shape_rows)
        by_path = {row[0]: row for row in shape_rows}
        fused_speed = by_path["fused"][8]
        if b == GATE_B and s_len == gate_s and kv_dtype in ("int8", "int4"):
            gate_ms[kv_dtype] = {p: r[7] for p, r in by_path.items()}
        print(f"  b={b:3d} s={s_len:5d} kv={kv_dtype:4s}: "
              + "  ".join(f"{path} {row[7]:8.3f}ms"
                          for path, row in by_path.items())
              + f"  → fused {fused_speed:.2f}x vs xla_cache")

    # Gate metrics. The int4 lane is gated against the *int8* dense
    # baseline at the same shape — the cache a server would actually run
    # without the packed container (twice the HBM) — because the int4
    # lane's own dense-unpack baseline never pays the int8 baseline's
    # relayout (XLA folds the unpack and transpose into one pass), so
    # "fused int4 vs its own unfused lowering" understates the change:
    # the claim is fused-int4 ≥ fused-int8's margin over XLA-over-cache,
    # at half the cache bytes. The own-baseline ratio still lands in the
    # CSV/JSON lanes for trend tracking.
    gate = {}
    if "int8" in gate_ms:
        gate[f"fused_vs_xla_cache_int8_b{GATE_B}"] = \
            gate_ms["int8"]["xla_cache"] / gate_ms["int8"]["fused"]
        if "int4" in gate_ms:
            gate[f"fused_vs_xla_cache_int4_b{GATE_B}"] = \
                gate_ms["int8"]["xla_cache"] / gate_ms["int4"]["fused"]

    path = write_csv("decode_attention.csv",
                     ["path", "b", "s", "kv_dtype", "kv_heads", "groups",
                      "head_dim", "ms", "speedup_vs_xla_cache"],
                     rows)
    write_summary("decode_attention", {
        "backend": backend,
        "gate_shape": {"b": GATE_B, "s": gate_s, "kv_heads": kv,
                       "groups": g, "head_dim": hd},
        "gate": gate,
        "gate_ms": gate_ms,
        "lanes": [{"path": r[0], "b": r[1], "s": r[2], "kv_dtype": r[3],
                   "ms": r[7], "speedup_vs_xla_cache": r[8]} for r in rows],
    })
    print(f"[bench] wrote {path}")
    for metric, spd in gate.items():
        print(f"[bench] {metric} (s={gate_s}): {spd:.2f}x")
    for d, floor in (("int8", args.min_speedup),
                     ("int4", args.min_speedup_int4)):
        got = gate.get(f"fused_vs_xla_cache_{d}_b{GATE_B}", 0.0)
        if floor is not None and got < floor:
            raise SystemExit(
                f"[bench-gate] FAIL: fused decode-attention {d} speedup "
                f"{got:.2f}x at batch {GATE_B} is below the floor "
                f"{floor:.2f}x")
    return path, rows


def run(quick: bool = False):
    """benchmarks.run protocol: returns (csv_path, rows)."""
    return _bench(["--quick"] if quick else [])


def main(argv=None):
    _bench(argv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
