"""Shared benchmark substrate.

Two weight regimes, mirroring how the paper evaluates:

  * **matrix-level** (Figs 2/5/7, Tables 5/11/20) — synthetic weights with
    projection-type-specific spectral profiles at 256–1024 dims: Q/K
    concentrated (strong low-rank structure, per Yuan et al. 2023b), V
    flat, MLP mixed. Calibration activations are correlated Gaussians.
  * **model-level** (Tables 1/2/3/4/6) — a small transformer *trained* on
    the deterministic synthetic corpus, so weights carry real learned
    structure and perplexity deltas are meaningful. Cached on first use.

No pretrained checkpoints exist in this container; the paper's absolute
numbers (WikiText2 ppl etc.) are not reproducible, but every *relative*
claim (SRR < QER at equal rank, quantizer-agnostic gains, γ-scaling
behaviour, assumption validity) is exercised on these stand-ins.
"""
from __future__ import annotations

import csv
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def out_path(name: str) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, name)


def write_csv(name: str, header: Sequence[str], rows: List[Sequence]) -> str:
    path = out_path(name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def write_summary(name: str, data: Dict) -> str:
    """Machine-readable run summary: ``benchmarks/out/BENCH_<name>.json``.

    Written alongside the CSV by every perf benchmark. Convention:
    ``data["gate"]`` maps gate-metric names to speedup floats — CI's
    bench-gate (``benchmarks/gate.py``) reads those instead of parsing
    stdout, and the JSON artifacts make the perf trajectory diffable
    across PRs. Every summary is stamped with the JAX backend it ran on
    (``"backend"``, unless the caller already set one): ``gate.py`` keys
    its floors per backend, so CPU-measured floors don't silently gate a
    TPU run (whose kernel-vs-XLA ratios sit elsewhere) and vice versa.
    Everything else in ``data`` is free-form context (shapes, per-lane
    medians)."""
    data = dict(data)
    data.setdefault("backend", jax.default_backend())
    path = out_path(f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# Matrix-level synthetic weights
# ---------------------------------------------------------------------------
PROJ_PROFILES = {
    # (rank_sig / d, signal strength): Q/K concentrated, V flat, rest mid
    "q": (0.03, 8.0), "k": (0.03, 8.0), "v": (0.15, 2.0), "o": (0.08, 4.0),
    "gate": (0.06, 5.0), "up": (0.06, 5.0), "down": (0.10, 3.0),
}


def synthetic_weight(key, m: int, n: int, proj: str = "o") -> jax.Array:
    frac, sig = PROJ_PROFILES[proj]
    rank_sig = max(2, int(min(m, n) * frac))
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (m, rank_sig))
    decay = jnp.exp(-jnp.arange(rank_sig) / max(rank_sig / 3, 1.0))
    v = jax.random.normal(k2, (rank_sig, n)) * decay[:, None]
    base = jax.random.normal(k3, (m, n)) * 0.02
    return base + (u @ v) * (sig / (m * n) ** 0.5)


def synthetic_layer(seed: int, d: int = 512, ffn_mult: int = 2
                    ) -> Dict[str, jax.Array]:
    """One transformer layer's worth of named projections."""
    key = jax.random.PRNGKey(seed)
    return {
        "q": synthetic_weight(jax.random.fold_in(key, 0), d, d, "q"),
        "k": synthetic_weight(jax.random.fold_in(key, 1), d, d, "k"),
        "v": synthetic_weight(jax.random.fold_in(key, 2), d, d, "v"),
        "o": synthetic_weight(jax.random.fold_in(key, 3), d, d, "o"),
        "gate": synthetic_weight(jax.random.fold_in(key, 4), d,
                                 ffn_mult * d, "gate"),
        "up": synthetic_weight(jax.random.fold_in(key, 5), d,
                               ffn_mult * d, "up"),
        "down": synthetic_weight(jax.random.fold_in(key, 6), ffn_mult * d,
                                 d, "down"),
    }


def calib_activations(seed: int, n: int, m: int,
                      correlated: bool = True) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, m))
    if correlated:
        mix = jax.random.normal(jax.random.fold_in(key, 1), (m, m)) * 0.4 \
            + jnp.eye(m)
        # heavy-tailed per-channel scales (outlier channels, as in LLMs)
        ch = jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (m,)))
        x = (x @ mix) * ch[None, :]
    return x


# ---------------------------------------------------------------------------
# Model-level: trained tiny transformer (cached)
# ---------------------------------------------------------------------------
_MODEL_CACHE: dict = {}


def trained_tiny_model(steps: int = 300, arch: str = "phi3-mini-3.8b"):
    """(cfg, params, data_cfg) — reduced config trained on synthetic data."""
    from repro.configs import get_config
    from repro.data import batches, data_config_for
    from repro.models import init_lm
    from repro.optim import AdamW, cosine_schedule
    from repro.train import (StepConfig, Trainer, init_train_state,
                             make_train_step)

    key = (arch, steps)
    if key in _MODEL_CACHE:
        return _MODEL_CACHE[key]
    cfg = get_config(arch).reduced()
    dcfg = data_config_for(cfg, seq_len=64, global_batch=8)
    opt = AdamW(learning_rate=cosine_schedule(3e-3, 20, steps),
                weight_decay=0.01)
    state = init_train_state(init_lm(jax.random.PRNGKey(0), cfg), opt)
    step = jax.jit(make_train_step(cfg, opt,
                                   StepConfig(compute_dtype=jnp.float32)))
    state, _ = Trainer(step, lambda s: batches(dcfg, s),
                       log_fn=lambda *_: None).run(state, steps)
    _MODEL_CACHE[key] = (cfg, state.params, dcfg)
    return _MODEL_CACHE[key]


def eval_ppl(params, cfg, dcfg, n_batches: int = 4,
             start_step: int = 10_000) -> float:
    """Perplexity on held-out steps of the deterministic corpus."""
    from repro.data import host_batch
    from repro.models import Ctx, lm_loss
    losses = []
    for s in range(n_batches):
        b = host_batch(dcfg, start_step + s)
        losses.append(float(lm_loss(Ctx(), params, b, cfg)))
    return float(np.exp(np.mean(losses)))


def eval_top1(params, cfg, dcfg, n_batches: int = 4,
              start_step: int = 10_000) -> float:
    """Next-token top-1 accuracy — the zero-shot-accuracy stand-in."""
    from repro.data import host_batch
    from repro.models import Ctx, forward
    from repro.models.linear import linear
    correct = total = 0
    ctx = Ctx()
    for s in range(n_batches):
        b = host_batch(dcfg, start_step + s)
        hidden, _, _ = forward(ctx, params, b, cfg)
        head = params.get("lm_head") or {"w": params["embed"]["w"].T}
        logits = linear(ctx, head, hidden)
        pred = jnp.argmax(logits, -1)
        correct += int(jnp.sum(pred == b["labels"]))
        total += b["labels"].size
    return correct / total


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)) \
        if jax.tree_util.tree_leaves(out) else None
    return out, time.perf_counter() - t0
