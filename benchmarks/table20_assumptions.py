"""Tables 20/21 / App E — empirical validation of Assumptions 4.1 & 4.2.

  * 4.1 (constant relative error scale): CV of η_Q = ‖S E_Q(A)‖/‖S A‖
    across a layer's projections, MXINT 3/4-bit + GPTQ-3.
  * 4.2 (random-matrix spectral proxy): MRE between ρ_{r−k}(SE_k) (true,
    per k) and ρ_{r−k}(SE_probe) (one-shot U[−1,1] probe).

Paper reports CV ≈ 0.21/0.12 (MXINT 3/4) and MRE ≈ 4.5%/2.3%.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import calib_activations, synthetic_layer, write_csv
from repro.core import make_scaling
from repro.core.rank_alloc import rho_prefix, sample_probe
from repro.core.svd import singular_values
from repro.quant import MXIntQuantizer
from repro.quant.gptq import GPTQQuantizer, hessian_from_activations


def _eta_cv(layer, scalings, qz_for):
    etas = []
    for name, w in layer.items():
        s = scalings[name]
        qz = qz_for(name)
        e = w - qz.fake_quant(w)
        etas.append(float(jnp.linalg.norm(s.apply(e))
                          / jnp.linalg.norm(s.apply(w))))
    etas = np.array(etas)
    return float(etas.std() / etas.mean())


def _proxy_mre(layer, scalings, qz_for, r=32, k_grid=(0, 8, 16, 24, 32)):
    mres = []
    for name, w in layer.items():
        s = scalings[name]
        qz = qz_for(name)
        sw = s.apply(w)
        u, sv, vt = jnp.linalg.svd(sw, full_matrices=False)
        probe = s.apply(sample_probe(jax.random.PRNGKey(0), w.shape))
        sv_p = singular_values(probe)
        rho_proxy = rho_prefix(sv_p, jnp.sum(probe ** 2), r)
        for k in k_grid:
            pres = s.apply_inv((u[:, :k] * sv[:k]) @ vt[:k]) if k else 0.0
            e_k = (w - pres) - qz.fake_quant(w - pres)
            se_k = s.apply(e_k)
            sv_t = singular_values(se_k)
            rho_true = rho_prefix(sv_t, jnp.sum(se_k ** 2), r)
            p = r - k
            mres.append(abs(float(rho_true[p]) - float(rho_proxy[p]))
                        / max(abs(float(rho_true[p])), 1e-9))
    return float(np.mean(mres))


def run(quick: bool = False):
    d = 192 if quick else 384
    layer = synthetic_layer(0, d=d)
    scalings, hessians = {}, {}
    for name, w in layer.items():
        x = calib_activations(hash(name) % 997, 4 * w.shape[0], w.shape[0])
        scalings[name] = make_scaling("qera-exact", x)
        hessians[name] = hessian_from_activations(x)

    rows = []
    for label, qz_for in [
        ("mxint3", lambda n: MXIntQuantizer(bits=3, block_size=32)),
        ("mxint4", lambda n: MXIntQuantizer(bits=4, block_size=32)),
        ("gptq3", lambda n: GPTQQuantizer(bits=3, group_size=32)
         .make_bound(hessians[n])),
    ]:
        cv = _eta_cv(layer, scalings, qz_for)
        mre = _proxy_mre(layer, scalings, qz_for,
                         k_grid=(0, 16, 32) if quick else (0, 8, 16, 24, 32))
        rows.append((label, f"{cv:.4f}", f"{mre:.4f}"))
    path = write_csv("table20_assumptions.csv",
                     ["quantizer", "CV_eta (Asm 4.1)", "MRE (Asm 4.2)"],
                     rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r in rows:
        print(r)
    print("->", path)
