"""Table 2 — zero-shot accuracy stand-in: next-token top-1 on held-out
data, QERA-exact vs QERA-exact + SRR (r = 16), plus BF16 / w-only refs."""
from __future__ import annotations

from benchmarks.common import eval_top1, trained_tiny_model, write_csv
from repro.core.api import PTQConfig
from repro.data import capture_calibration
from repro.models import lm_loss
from repro.models.quantize import quantize_model_params
from repro.quant.base import QuantizerConfig


def run(quick: bool = False):
    cfg, params, dcfg = trained_tiny_model(steps=120 if quick else 300)
    stats = capture_calibration(
        params, cfg, dcfg, lambda c, p, b, cc: lm_loss(c, p, b, cc),
        n_batches=2)
    qz = QuantizerConfig(kind="mxint", bits=3, block_size=32)
    rows = [("bf16", f"{eval_top1(params, cfg, dcfg):.4f}")]
    for method, label in (("w-only", "w-only"), ("qer", "QERA-exact"),
                          ("srr", "QERA-exact + SRR")):
        ptq = PTQConfig(method=method,
                        scaling="identity" if method == "w-only"
                        else "qera-exact",
                        rank=16, quantizer=qz)
        qp, _ = quantize_model_params(params, stats, ptq)
        rows.append((label, f"{eval_top1(qp, cfg, dcfg):.4f}"))
    path = write_csv("table2_downstream.csv", ["method", "top1_acc"], rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r in rows:
        print(r)
    print("->", path)
