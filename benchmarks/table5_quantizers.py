"""Table 5 — quantizer-agnostic gains: SRR applied over MXINT-3, uniform
int-3, GPTQ-3 and MXINT-2 on matrix-level synthetic weights.

Metric: scaled reconstruction error ‖S(W − Q − LR)‖_F (the paper's layer
objective), mean over a layer's seven projections, QER vs SRR per
quantizer. The paper's claim: SRR never loses, regardless of 𝒬.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import calib_activations, synthetic_layer, write_csv
from repro.core import make_scaling, qer_decompose, scaled_error, srr_decompose
from repro.quant import MXIntQuantizer, UniformQuantizer
from repro.quant.gptq import GPTQQuantizer, hessian_from_activations


def run(quick: bool = False):
    d = 256 if quick else 512
    r = 32
    layer = synthetic_layer(0, d=d)
    rows = []
    for qname in ("mxint3", "uniform3", "gptq3", "mxint2"):
        errs_qer, errs_srr = [], []
        for name, w in layer.items():
            m = w.shape[0]
            x = calib_activations(hash(name) % 1000, 4 * m, m)
            s = make_scaling("qera-exact", x)
            if qname == "mxint3":
                qz = MXIntQuantizer(bits=3, block_size=32)
            elif qname == "mxint2":
                qz = MXIntQuantizer(bits=2, block_size=32)
            elif qname == "uniform3":
                qz = UniformQuantizer(bits=3, group_size=32)
            else:
                h = hessian_from_activations(x)
                qz = GPTQQuantizer(bits=3, group_size=32).make_bound(h)
            eq = float(scaled_error(
                w, qer_decompose(w, s, qz, r, exact=True), s))
            es = float(scaled_error(
                w, srr_decompose(w, s, qz, r, jax.random.PRNGKey(0),
                                 exact=True).decomposition, s))
            errs_qer.append(eq)
            errs_srr.append(es)
        mq, ms = float(np.mean(errs_qer)), float(np.mean(errs_srr))
        rows.append((qname, f"{mq:.4f}", f"{ms:.4f}",
                     f"{100 * (1 - ms / mq):.1f}%"))
    path = write_csv("table5_quantizers.csv",
                     ["quantizer", "QER_err", "SRR_err", "improvement"],
                     rows)
    return path, rows


if __name__ == "__main__":
    path, rows = run()
    for r_ in rows:
        print(r_)
    print("->", path)
