import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Multi-pod lowering walkthrough (the dry-run, narrated).

    PYTHONPATH=src python examples/multipod_lowering.py [--arch phi3-mini-3.8b]

Shows the public distribution API: build the production mesh, derive
parameter/cache shardings from the rules, lower a full-size training and
serving step, and read the compiled artifact's memory/cost/roofline.
No arrays are allocated at any point.
"""
import argparse

import jax

from repro.configs import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.specs import DryrunOptions, build_lowering, input_specs


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi3-mini-3.8b")
    p.add_argument("--multi-pod", action="store_true")
    args = p.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(mesh.shape)}  ({mesh.devices.size} chips)")

    for shape_name in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_name]
        opts = DryrunOptions(remat="full", microbatch=8) \
            if shape.kind == "train" else DryrunOptions()
        spec = input_specs(cfg, shape, opts)
        print(f"\n=== {shape_name} ({shape.kind}) ===")
        print("inputs:", {k: getattr(v, 'shape', '<tree>')
                          for k, v in spec.items()})
        with mesh:
            lowered = build_lowering(cfg, shape, mesh, opts)
            compiled = lowered.compile()
        print("memory_analysis:", compiled.memory_analysis())
        r = analyze(compiled, cfg, shape,
                    "multi" if args.multi_pod else "single",
                    mesh.devices.size, args.arch)
        print(f"roofline: compute {r.t_compute * 1e3:.1f} ms | memory "
              f"{r.t_memory * 1e3:.1f} ms | collective "
              f"{r.t_collective * 1e3:.1f} ms → {r.bottleneck}-bound "
              f"(roofline frac {100 * r.roofline_frac:.2f}%)")


if __name__ == "__main__":
    main()
