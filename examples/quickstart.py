"""Quickstart: SRR on a single weight matrix in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's Algorithm 1 end to end on one matrix: calibration →
scaling S → rank split k* → preserve / quantize / reconstruct → compare
against the plain-QER baseline under the same rank budget.
"""
import jax
import jax.numpy as jnp

from repro.core import (make_scaling, qer_decompose, scaled_error,
                        select_rank, srr_decompose)
from repro.quant import MXIntQuantizer

# --- a weight with dominant low-rank structure (what transformers have) --
key = jax.random.PRNGKey(0)
m, n, r = 512, 512, 64
u = jax.random.normal(key, (m, 8))
v = jax.random.normal(jax.random.fold_in(key, 1), (8, n))
w = u @ v * (6.0 / (m * n) ** 0.5) \
    + jax.random.normal(jax.random.fold_in(key, 2), (m, n)) * 0.02

# --- calibration activations → activation-aware scaling S ----------------
x = jax.random.normal(jax.random.fold_in(key, 3), (2048, m))
scaling = make_scaling("qera-exact", x)

# --- the quantizer (paper's main setting: 3-bit MXINT, block 32) ----------
quantizer = MXIntQuantizer(bits=3, block_size=32)

# --- rank selection (Eq. 5): how much budget to preserve vs reconstruct --
sel = select_rank(w, scaling, r, jax.random.PRNGKey(7), exact=True)
print(f"rank budget r={r}, selected split k*={int(sel.k_star)} "
      f"(preserve {int(sel.k_star)}, reconstruct {r - int(sel.k_star)})")

# --- full SRR vs the QER baseline under the same budget -------------------
qer = qer_decompose(w, scaling, quantizer, r, exact=True)
srr = srr_decompose(w, scaling, quantizer, r, jax.random.PRNGKey(7),
                    exact=True).decomposition

e_qer = float(scaled_error(w, qer, scaling))
e_srr = float(scaled_error(w, srr, scaling))
print(f"scaled reconstruction error  QER: {e_qer:.4f}")
print(f"scaled reconstruction error  SRR: {e_srr:.4f} "
      f"({100 * (1 - e_srr / e_qer):.1f}% lower)")

# --- the deployed form: y = x·Q + (x·L)·R ---------------------------------
y_full = x[:4] @ w
y_srr = x[:4] @ srr.q + (x[:4] @ srr.l) @ srr.r
rel = float(jnp.linalg.norm(y_full - y_srr) / jnp.linalg.norm(y_full))
print(f"output-space relative error of the served Q+LR: {rel:.4f}")
