"""End-to-end driver: pretrain a ~100M-param LM for a few hundred steps
with checkpoint/restart, then QPEFT-adapt its SRR-quantized form.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--small]

Phase A — pretraining: a 12-layer, d=256 transformer (~110M params with
embeddings at the phi3 vocab; ``--small`` shrinks it for quick runs) on
the deterministic synthetic corpus, with the production trainer:
AdamW + cosine, remat, checkpoint-every-N, and an intentional mid-run
"preemption" that the resume path recovers from.

Phase B — the paper: calibrate, SRR-quantize (W ≈ Q + LR), fine-tune
adapters only with γ-scaled gradients, compare to the QER init.
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.api import PTQConfig
from repro.data import batches, capture_calibration, data_config_for, host_batch
from repro.models import Ctx, init_lm, lm_loss
from repro.models.quantize import (merge_qpeft, quantize_model_params,
                                   set_qpeft_scaling, split_qpeft)
from repro.optim import AdamW, cosine_schedule
from repro.quant.base import QuantizerConfig
from repro.train import (CheckpointManager, StepConfig, Trainer,
                         init_qpeft_state, init_train_state, make_qpeft_step,
                         make_train_step)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--small", action="store_true",
                   help="tiny model for a fast demo")
    args = p.parse_args()

    base = get_config("phi3-mini-3.8b")
    if args.small:
        cfg = base.reduced()
    else:
        cfg = dataclasses.replace(
            base.reduced(), n_layers=12, d_model=256, n_heads=8,
            n_kv_heads=8, head_dim=32, d_ff=1024, vocab=32064)
    n = cfg.n_params()
    print(f"[phase A] pretraining {n / 1e6:.0f}M params for "
          f"{args.steps} steps")

    dcfg = data_config_for(cfg, seq_len=128, global_batch=8)
    opt = AdamW(learning_rate=cosine_schedule(1e-3, 30, args.steps),
                weight_decay=0.01)
    sc = StepConfig(compute_dtype=jnp.float32, remat="none")
    step = jax.jit(make_train_step(cfg, opt, sc))
    state = init_train_state(init_lm(jax.random.PRNGKey(0), cfg), opt)

    ckpt_dir = tempfile.mkdtemp(prefix="srr_e2e_")
    mgr = CheckpointManager(ckpt_dir, keep=2)
    trainer = Trainer(step, lambda s: batches(dcfg, s), ckpt=mgr,
                      ckpt_every=50, log_every=25)

    # simulate a preemption at 60% of the run, then resume
    mid = max(args.steps * 3 // 5, 1)
    state, _ = trainer.run(state, mid)
    print(f"[phase A] -- simulated preemption at step {mid}; relaunching --")
    fresh = init_train_state(init_lm(jax.random.PRNGKey(0), cfg), opt)
    state, hist = trainer.run(fresh, args.steps)   # resumes from checkpoint
    params = state.params
    print(f"[phase A] done, final loss {hist[-1]['loss']:.4f}")

    print("[phase B] calibrate → SRR quantize → QPEFT")
    stats = capture_calibration(
        params, cfg, dcfg, lambda c, pp, b, cc: lm_loss(c, pp, b, cc),
        n_batches=2)
    qz = QuantizerConfig("mxint", 3, 32)
    dcfg_ft = dataclasses.replace(dcfg, seed=1)  # a shifted "task"

    rows = []
    for method, label, scale_mode in (("qer", "QERA-exact init", "none"),
                                      ("srr", "SRR init + γ=0.1", "gamma")):
        qp, reps = quantize_model_params(
            params, stats, PTQConfig(method=method, scaling="qera-exact",
                                     rank=16, quantizer=qz))
        qp = set_qpeft_scaling(qp, mode=scale_mode, gamma=0.1)
        trainable, frozen = split_qpeft(qp)
        opt_ft = AdamW(learning_rate=cosine_schedule(1e-3, 5, 60))
        st = init_qpeft_state(trainable, frozen, opt_ft)
        qstep = jax.jit(make_qpeft_step(
            cfg, opt_ft, StepConfig(compute_dtype=jnp.float32)))
        eval_b = host_batch(dcfg_ft, 9_999)
        l0 = float(lm_loss(Ctx(), merge_qpeft(st.trainable, st.frozen),
                           eval_b, cfg))
        for s in range(60):
            st, _ = qstep(st, host_batch(dcfg_ft, s))
        l1 = float(lm_loss(Ctx(), merge_qpeft(st.trainable, st.frozen),
                           eval_b, cfg))
        rows.append((label, l0, l1))
        print(f"   {label:20s}: eval loss {l0:.4f} → {l1:.4f}")

    shutil.rmtree(ckpt_dir, ignore_errors=True)
    better = rows[1][2] <= rows[0][2]
    print(f"[phase B] SRR init {'≤' if better else '>'} QER init after QPEFT")


if __name__ == "__main__":
    main()
