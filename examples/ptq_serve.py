"""PTQ → serve: quantize a whole model with SRR and serve it continuously.

    PYTHONPATH=src python examples/ptq_serve.py [--arch minitron-4b]

The paper's deployment scenario: calibrate on a handful of batches,
decompose every projection into Q + LR (per-matrix k*), then serve
requests through the continuous-batching engine — int8 KV cache on,
requests streamed in via ``submit()``/``step()`` so late arrivals join
mid-flight — and compare against the w-only and QER baselines.

A final act serves the production traffic shape: many requests sharing
one system prompt through the **paged** engine (``--arch`` permitting —
paged needs a pure-attention stack, so this step runs on phi3-mini),
where the radix-tree prefix cache maps the shared blocks into each new
request's block table and the printed prefix-hit rate shows how much
prefill the cache deleted. The paged act runs with telemetry enabled,
so it also prints the step-phase p50 breakdown (budget / admission /
prefill / decode / transfer) straight from the engine's metrics
registry. The act also arms the token-budget step scheduler
(``max_step_tokens``), bounding per-step prefill + decode work.

The last act turns on **self-speculative decoding**: the quantized base
`Q` alone drafts tokens (the low-rank sliver is skipped — a free draft
model living inside the serving weights) and the full `Q + LR` model
verifies k at a time in one chunked dispatch. It prints the measured
acceptance rate and tok/s next to the plain per-token engine. For a
real Q+LR model both numbers hinge on how closely the quantized base
tracks the corrected model — the act reports that trade-off honestly
rather than a synthetic best case (``benchmarks/serve_spec.py``
measures the mechanism at its acceptance ceiling).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.api import PTQConfig
from repro.data import capture_calibration, data_config_for
from repro.models import Ctx, init_lm, lm_loss
from repro.models.quantize import quantize_model_params
from repro.quant.base import QuantizerConfig
from repro.serve import Engine, Request, SamplingParams, ServeConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="minitron-4b")
    p.add_argument("--rank", type=int, default=16)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    dcfg = data_config_for(cfg, seq_len=32, global_batch=4)

    print("[1/5] calibrating …")
    stats = capture_calibration(
        params, cfg, dcfg, lambda c, pp, b, cc: lm_loss(c, pp, b, cc),
        n_batches=2)

    print("[2/5] quantizing (3-bit MXINT + SRR rank allocation) …")
    results = {}
    for method in ("w-only", "qer", "srr"):
        ptq = PTQConfig(method=method,
                        scaling="identity" if method == "w-only"
                        else "qera-exact",
                        rank=args.rank,
                        quantizer=QuantizerConfig("mxint", 3, 32))
        t0 = time.perf_counter()
        qp, reports = quantize_model_params(params, stats, ptq)
        dt = time.perf_counter() - t0
        from repro.data import host_batch
        loss = float(lm_loss(Ctx(), qp, host_batch(dcfg, 999), cfg))
        kbar = sum(r.k_star for r in reports) / max(len(reports), 1)
        results[method] = qp
        print(f"   {method:7s}: eval loss {loss:.4f}  mean k*={kbar:4.1f}  "
              f"({dt:.1f}s)")

    print("[3/5] serving the SRR model (continuous batching, int8 KV) …")
    eng = Engine(results["srr"], cfg,
                 ServeConfig(max_len=96, decode_batch=4, max_new_tokens=12,
                             kv_dtype="int8", scheduler="continuous",
                             prefill_len=16 + (cfg.n_vision_tokens or 0)))
    rng = np.random.default_rng(0)
    # stream requests in: 4 up front, 4 more arriving mid-decode — and
    # mix per-request sampling in the same batch (greedy lanes decode
    # next to temperature/top-p lanes, each with its own PRNG stream)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, size=int(rng.integers(6, 14))).astype(np.int32),
        params=SamplingParams(
            max_new_tokens=int(rng.integers(6, 13)),
            temperature=0.0 if i % 2 == 0 else 0.8,
            top_p=1.0 if i % 2 == 0 else 0.9,
            seed=i)) for i in range(8)]
    out = []
    for r in reqs[:4]:
        eng.submit(r)
    for _ in range(4):                       # a few steps before the rest
        out.extend(eng.step())
    for r in reqs[4:]:                       # late arrivals join mid-flight
        eng.submit(r)
    out.extend(eng.drain())
    out.sort(key=lambda r: r.uid)
    for r in out[:3]:
        kind = "greedy" if r.uid % 2 == 0 else "sampled"
        print(f"   req {r.uid} ({kind}, {r.finish_reason}): "
              f"{r.tokens.tolist()}")
    toks = sum(len(r.tokens) for r in out)
    st = eng.stats()
    print(f"   {len(out)} requests, {toks} new tokens, "
          f"lane occupancy {st['occupancy']:.2f}")

    print("[4/5] paged serving: one system prompt, many users "
          "(prefix-cache reuse) …")
    # paged needs a pure-attention stack; run this act on phi3-mini if
    # the requested arch doesn't qualify
    pcfg, pparams = cfg, results["srr"]
    if set(pcfg.block_pattern) != {"attn"} or pcfg.attn_kind == "mla" \
            or pcfg.is_encoder_decoder or pcfg.n_vision_tokens:
        pcfg = get_config("phi3-mini-3.8b").reduced()
        pparams = init_lm(jax.random.PRNGKey(0), pcfg)
        print(f"   ({args.arch} has non-attention mixers; paged act runs "
              f"on phi3-mini-3.8b instead)")
    # max_step_tokens arms the token-budget step scheduler: per step,
    # chunked-prefill dispatches + decode lanes stay under the cap, so
    # the burst of 10 admissions cannot stall lanes already decoding
    peng = Engine(pparams, pcfg, ServeConfig(
        max_len=96, decode_batch=4, max_new_tokens=8, kv_dtype="int8",
        prefill_len=16, paged=True, page_size=8, telemetry=True,
        max_step_tokens=16 + 4))
    system_prompt = rng.integers(0, pcfg.vocab, size=24).astype(np.int32)
    shared_reqs = [Request(
        uid=i, prompt=np.concatenate(
            [system_prompt,
             rng.integers(0, pcfg.vocab, size=6).astype(np.int32)]),
        max_new_tokens=8) for i in range(10)]
    sout = peng.generate(shared_reqs)
    pst = peng.stats()
    print(f"   {len(sout)} requests over a shared 24-token system prompt: "
          f"prefix hit rate {pst['prefix_hit_rate']:.2f}, "
          f"{pst['prefill_tokens_computed']}/{pst['prompt_tokens_total']} "
          f"prompt tokens computed, {pst['prefill_chunks']} chunks, "
          f"{pst['evictions']} evictions, "
          f"{pst['budget_deferred_admissions']:.0f} admissions deferred "
          f"by the step budget")
    phases = " ".join(
        f"{ph} {pst[f'step_{ph}_seconds']['p50'] * 1e3:.2f}ms"
        for ph in ("budget", "admission", "prefill", "decode", "transfer"))
    print(f"   step-phase p50: {phases}  "
          f"(ttft p50 {pst['ttft_seconds']['p50'] * 1e3:.0f}ms, "
          f"{pst['compiled_shapes_decode']} decode shape(s) compiled)")

    print("[5/5] self-speculative decoding: Q-only draft, Q+LR verify …")
    spec_prompts = [rng.integers(0, pcfg.vocab, size=8 + i % 4)
                    .astype(np.int32) for i in range(4)]
    mk_reqs = lambda: [Request(uid=i, prompt=pr.copy(),   # noqa: E731
                               max_new_tokens=24)
                       for i, pr in enumerate(spec_prompts)]
    lanes = {}
    for label, spec in (("plain", False), ("speculative", True)):
        seng = Engine(pparams, pcfg, ServeConfig(
            max_len=96, decode_batch=1, max_new_tokens=24,
            kv_dtype="int8", prefill_len=16, paged=True, page_size=8,
            speculative=spec, spec_k=6))
        seng.warmup()
        t0 = time.perf_counter()
        sres = seng.generate(mk_reqs())
        wall = time.perf_counter() - t0
        lanes[label] = (sum(len(r.tokens) for r in sres) / wall,
                        seng.stats(), sres)
    tps_p, _, res_p = lanes["plain"]
    tps_s, sstat, res_s = lanes["speculative"]
    for a, b in zip(res_p, sorted(res_s, key=lambda r: r.uid)):
        assert np.array_equal(a.tokens, b.tokens), \
            "speculation must not change greedy output"
    print(f"   plain {tps_p:6.1f} tok/s | speculative {tps_s:6.1f} tok/s "
          f"({tps_s / tps_p:.2f}x) — {sstat['spec_rounds']} rounds, "
          f"acceptance rate {sstat['spec_acceptance_rate']:.3f} "
          f"({sstat['spec_accepted_tokens']}/{sstat['spec_draft_tokens']} "
          f"drafts), tokens identical")
    print("   (the SRR draft skips the LR correction, so acceptance — "
          "and the payoff — tracks how well Q alone matches Q+LR; "
          "benchmarks/serve_spec.py isolates the mechanism's ceiling)")


if __name__ == "__main__":
    main()
