"""PTQ → serve: quantize a whole model with SRR and serve it batched.

    PYTHONPATH=src python examples/ptq_serve.py [--arch minitron-4b]

The paper's deployment scenario: calibrate on a handful of batches,
decompose every projection into Q + LR (per-matrix k*), then serve
requests through the prefill/decode engine — optionally with the int8 KV
cache and comparing against the w-only and QER baselines.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.api import PTQConfig
from repro.data import capture_calibration, data_config_for
from repro.models import Ctx, init_lm, lm_loss
from repro.models.quantize import quantize_model_params
from repro.quant.base import QuantizerConfig
from repro.serve import Engine, Request, ServeConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="minitron-4b")
    p.add_argument("--rank", type=int, default=16)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    dcfg = data_config_for(cfg, seq_len=32, global_batch=4)

    print("[1/3] calibrating …")
    stats = capture_calibration(
        params, cfg, dcfg, lambda c, pp, b, cc: lm_loss(c, pp, b, cc),
        n_batches=2)

    print("[2/3] quantizing (3-bit MXINT + SRR rank allocation) …")
    results = {}
    for method in ("w-only", "qer", "srr"):
        ptq = PTQConfig(method=method,
                        scaling="identity" if method == "w-only"
                        else "qera-exact",
                        rank=args.rank,
                        quantizer=QuantizerConfig("mxint", 3, 32))
        t0 = time.perf_counter()
        qp, reports = quantize_model_params(params, stats, ptq)
        dt = time.perf_counter() - t0
        from repro.data import host_batch
        loss = float(lm_loss(Ctx(), qp, host_batch(dcfg, 999), cfg))
        kbar = sum(r.k_star for r in reports) / max(len(reports), 1)
        results[method] = qp
        print(f"   {method:7s}: eval loss {loss:.4f}  mean k*={kbar:4.1f}  "
              f"({dt:.1f}s)")

    print("[3/3] serving the SRR model (int8 KV cache) …")
    eng = Engine(results["srr"], cfg,
                 ServeConfig(max_len=96, decode_batch=4, max_new_tokens=12,
                             kv_dtype="int8"))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, size=8).astype(np.int32)) for i in range(8)]
    out = eng.generate(reqs)
    for r in out[:3]:
        print(f"   req {r.uid}: {r.tokens.tolist()}")
    toks = sum(len(r.tokens) for r in out)
    dt = sum(r.decode_s for r in out[:1]) or 1.0
    print(f"   {len(out)} requests, {toks} new tokens")


if __name__ == "__main__":
    main()
