"""Pallas TPU kernel: fused MXINT dequant-matmul with low-rank correction.

The QER/SRR serving hot spot is ``y = x·dequant(Q) + (x·L)·R``. A naive
XLA lowering materializes the dequantized f32/bf16 weight in HBM (2–4×
the quantized bytes) and runs the rank-r correction as a separate GEMM
with its own HBM round trip of the (M, N) output. This kernel instead:

  * streams int8 codes + per-32-block scales HBM→VMEM tile by tile and
    dequantizes *in VMEM* into an MXU-aligned (bk, bn) tile — the weight
    never exists in HBM at full precision, so the matmul's memory traffic
    is ~bits/16 of the bf16 baseline;
  * accumulates x @ W_tile in an f32 VMEM accumulator across the K grid;
  * fuses the low-rank correction on the **last K step**: ``xl = x·L``
    (an (M, r) sliver computed once outside — r ≤ 64 ≪ K so it is
    negligible) is multiplied by the (r, bn) slice of R straight into the
    same accumulator, saving a full (M, N) HBM round trip.

Tiling: bm×bn×bk = 128×128×512 by default — multiples of the 128×128 MXU;
bk a multiple of the MXINT block (32) so scale tiles align. VMEM per
step ≈ x(128·512·4) + codes(512·128) + scale(16·128·4) + out(128·128·4)
+ xl/r slivers ≈ 390 KiB ≪ 16 MiB v5e VMEM, leaving headroom for
double-buffering the HBM streams.

TPU adaptation note (DESIGN.md §3): the CUDA equivalents (e.g. LQER's
fused dequant GEMM) pivot on warp-level shuffles; here the same insight —
"dequantize in fast memory, fuse the correction" — maps to VMEM tiling +
MXU-aligned blocks instead.

Three entry points, all sharing the same tile geometry:

  * :func:`mxint_lowrank_matmul_2d`       — xl = x·L precomputed outside
    (one big fused GEMM for the sliver; best when N ≫ bn so xl is reused
    across many N blocks);
  * :func:`mxint_lowrank_matmul_fused_2d` — takes L itself and accumulates
    the (bm, r) sliver in a VMEM scratch across the K grid, applying ·R on
    the last K step: x never leaves VMEM between the backbone and the
    correction (single-pass decode shapes);
  * :func:`mxint_lowrank_matmul_batched_2d` — leading grid axis over a
    stack of G independent weights (scan groups / MoE expert dispatch):
    x (G, M, K) · codes (G, K, N), one pallas_call for the whole stack.

The 2d/fused entries also accept the **packed4** container (uint8, two
4-bit codes per byte, ``packed=True``): nibbles are unpacked in the
kernel body (:func:`_unpack_tile`), so the codes' HBM stream halves
again vs int8 — the container is never pre-expanded in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.constraints import MXINT_BLOCK


def _check_tiles(m: int, k: int, n: int, bm: int, bk: int, bn: int,
                 mx_block: int) -> None:
    """The grid floor-divides every problem dim by its block; a ragged
    dim would silently drop the tail tile, so enforce the documented
    caller contract (ops.py pads before calling) with a loud error."""
    if m % bm or k % bk or n % bn:
        raise ValueError(
            f"mxint matmul tiles must divide the problem: (M={m}, K={k}, "
            f"N={n}) vs (bm={bm}, bk={bk}, bn={bn}) — pad to tile "
            f"multiples first (see kernels.ops._pad_to)")
    if bk % mx_block:
        raise ValueError(
            f"bk={bk} must be a multiple of the scale block {mx_block} "
            f"(canonically {MXINT_BLOCK}) so scale tiles align with "
            f"code tiles")


def _unpack_tile(packed: jax.Array) -> jax.Array:
    """packed4 (bk/2, bn) uint8 tile → int8 (bk, bn) codes, in VMEM.

    Row pairs interleave as [lo0, hi0, lo1, hi1, ...] — the layout
    :func:`repro.quant.mxint.pack_codes_4bit` writes — via a stack +
    reshape on the sublane axis (lane dim untouched, so Mosaic keeps the
    tile resident). Sign extension is shift-based (shl + arithmetic shr
    in the i32 working type, 2 ops/nibble) instead of a compare-select
    pair — this runs per (K, N) tile of every fused matmul and per
    (bs, hd) K/V tile of every int4 flash-decode step. Reading the
    packed container instead of pre-expanded int8 halves the codes' HBM
    stream."""
    u = packed.astype(jnp.int32)
    lo = ((u << 28) >> 28).astype(jnp.int8)  # sign-extend 4-bit 2's comp
    hi = ((u << 24) >> 28).astype(jnp.int8)
    m2, bn = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(m2 * 2, bn)


def _dequant_tile(codes: jax.Array, scale: jax.Array,
                  mx_block: int, packed: bool = False) -> jax.Array:
    """Codes tile (int8, or packed4 uint8) + per-block scales → f32
    (bk, bn) weight tile."""
    if packed:
        codes = _unpack_tile(codes)
    codes = codes.astype(jnp.float32)
    bk, bn = codes.shape
    return (codes.reshape(bk // mx_block, mx_block, bn)
            * scale[:, None, :]).reshape(bk, bn)


def _kernel(x_ref, codes_ref, scale_ref, xl_ref, r_ref, o_ref, *,
            n_k: int, mx_block: int, packed: bool):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ dequant(codes[k,j])."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(codes_ref[...], scale_ref[...], mx_block, packed)
    x = x_ref[...].astype(jnp.float32)                # (bm, bk)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _lowrank():
        xl = xl_ref[...].astype(jnp.float32)          # (bm, r)
        rr = r_ref[...].astype(jnp.float32)           # (r, bn)
        o_ref[...] += jnp.dot(xl, rr, preferred_element_type=jnp.float32)


def mxint_lowrank_matmul_2d(
    x: jax.Array,        # (M, K)
    codes: jax.Array,    # (K, N) int8, or packed4 (K/2, N) uint8
    scale: jax.Array,    # (K/32, N) f32
    xl: jax.Array,       # (M, r) — precomputed x @ L
    r: jax.Array,        # (r, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    packed: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Core pallas_call; caller guarantees M % bm == K % bk == N % bn == 0
    and bk % mx_block == 0. ``packed`` reads the two-codes-per-byte
    container and unpacks nibbles in the kernel body."""
    m, k = x.shape
    n = codes.shape[1]
    mx_block = k // scale.shape[0]
    _check_tiles(m, k, n, bm, bk, bn, mx_block)
    rr = max(r.shape[0], 1)
    if r.shape[0] == 0:  # rank-0: keep the kernel uniform with a zero sliver
        xl = jnp.zeros((m, 1), x.dtype)
        r = jnp.zeros((1, n), x.dtype)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    cdiv = 2 if packed else 1    # packed rows hold two codes each

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, mx_block=mx_block,
                          packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // cdiv, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // mx_block, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, rr), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((rr, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scale, xl, r)


def _fused_kernel(x_ref, codes_ref, scale_ref, l_ref, r_ref, o_ref, xl_ref,
                  *, n_k: int, mx_block: int, packed: bool):
    """Like ``_kernel`` but builds the xl = x·L sliver *inside* the pass:
    each K step accumulates the (bm, r) partial into a VMEM scratch, and
    the last K step multiplies it with the (r, bn) slice of R. The sliver
    is recomputed per N block — r ≤ 64 keeps that rounding-error cheap
    relative to saving the separate (M, r) HBM round trip at decode."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        xl_ref[...] = jnp.zeros_like(xl_ref)

    x = x_ref[...].astype(jnp.float32)                # (bm, bk)
    w = _dequant_tile(codes_ref[...], scale_ref[...], mx_block, packed)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    xl_ref[...] += jnp.dot(x, l_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _lowrank():
        rr = r_ref[...].astype(jnp.float32)           # (r, bn)
        o_ref[...] += jnp.dot(xl_ref[...], rr,
                              preferred_element_type=jnp.float32)


def mxint_lowrank_matmul_fused_2d(
    x: jax.Array,        # (M, K)
    codes: jax.Array,    # (K, N) int8, or packed4 (K/2, N) uint8
    scale: jax.Array,    # (K/32, N) f32
    l: jax.Array,        # (K, r)
    r: jax.Array,        # (r, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    packed: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Single-pass y = x·dequant(Q) + (x·L)·R with the sliver accumulated
    in-kernel. Caller guarantees the same divisibility as the 2d entry;
    ``packed`` unpacks the two-codes-per-byte container in-kernel."""
    m, k = x.shape
    n = codes.shape[1]
    mx_block = k // scale.shape[0]
    _check_tiles(m, k, n, bm, bk, bn, mx_block)
    rr = max(r.shape[0], 1)
    if r.shape[0] == 0:
        l = jnp.zeros((k, 1), x.dtype)
        r = jnp.zeros((1, n), x.dtype)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    cdiv = 2 if packed else 1

    return pl.pallas_call(
        functools.partial(_fused_kernel, n_k=n_k, mx_block=mx_block,
                          packed=packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // cdiv, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // mx_block, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, rr), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((rr, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, rr), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale, l, r)


def _batched_kernel(x_ref, codes_ref, scale_ref, xl_ref, r_ref, o_ref, *,
                    n_k: int, mx_block: int):
    """One (g, i, j, k) grid step over a stack of G independent weights.
    Blocks carry a leading singleton G dim; ``ref[0]`` strips it."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(codes_ref[0], scale_ref[0], mx_block)
    x = x_ref[0].astype(jnp.float32)                  # (bm, bk)
    o_ref[0] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _lowrank():
        xl = xl_ref[0].astype(jnp.float32)            # (bm, r)
        rr = r_ref[0].astype(jnp.float32)             # (r, bn)
        o_ref[0] += jnp.dot(xl, rr, preferred_element_type=jnp.float32)


def mxint_lowrank_matmul_batched_2d(
    x: jax.Array,        # (G, M, K)
    codes: jax.Array,    # (G, K, N) int8
    scale: jax.Array,    # (G, K/32, N) f32
    xl: jax.Array,       # (G, M, r) — precomputed x @ L per stack entry
    r: jax.Array,        # (G, r, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Stacked variant: grid leads with the G axis, so one pallas_call
    serves every expert / scanned layer in the stack (MoE dispatch)."""
    g, m, k = x.shape
    _, _, n = codes.shape
    mx_block = k // scale.shape[1]
    _check_tiles(m, k, n, bm, bk, bn, mx_block)
    rr = max(r.shape[1], 1)
    if r.shape[1] == 0:
        xl = jnp.zeros((g, m, 1), x.dtype)
        r = jnp.zeros((g, 1, n), x.dtype)
    n_k = k // bk
    grid = (g, m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_batched_kernel, n_k=n_k, mx_block=mx_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
            pl.BlockSpec((1, bk // mx_block, bn),
                         lambda gg, i, j, kk: (gg, kk, j)),
            pl.BlockSpec((1, bm, rr), lambda gg, i, j, kk: (gg, i, 0)),
            pl.BlockSpec((1, rr, bn), lambda gg, i, j, kk: (gg, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scale, xl, r)
