"""Pallas TPU kernel: fused MXINT dequant-matmul with low-rank correction.

The QER/SRR serving hot spot is ``y = x·dequant(Q) + (x·L)·R``. A naive
XLA lowering materializes the dequantized f32/bf16 weight in HBM (2–4×
the quantized bytes) and runs the rank-r correction as a separate GEMM
with its own HBM round trip of the (M, N) output. This kernel instead:

  * streams int8 codes + per-32-block scales HBM→VMEM tile by tile and
    dequantizes *in VMEM* into an MXU-aligned (bk, bn) tile — the weight
    never exists in HBM at full precision, so the matmul's memory traffic
    is ~bits/16 of the bf16 baseline;
  * accumulates x @ W_tile in an f32 VMEM accumulator across the K grid;
  * fuses the low-rank correction on the **last K step**: ``xl = x·L``
    (an (M, r) sliver computed once outside — r ≤ 64 ≪ K so it is
    negligible) is multiplied by the (r, bn) slice of R straight into the
    same accumulator, saving a full (M, N) HBM round trip.

Tiling: bm×bn×bk = 128×128×512 by default — multiples of the 128×128 MXU;
bk a multiple of the MXINT block (32) so scale tiles align. VMEM per
step ≈ x(128·512·4) + codes(512·128) + scale(16·128·4) + out(128·128·4)
+ xl/r slivers ≈ 390 KiB ≪ 16 MiB v5e VMEM, leaving headroom for
double-buffering the HBM streams.

TPU adaptation note (DESIGN.md §3): the CUDA equivalents (e.g. LQER's
fused dequant GEMM) pivot on warp-level shuffles; here the same insight —
"dequantize in fast memory, fuse the correction" — maps to VMEM tiling +
MXU-aligned blocks instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, codes_ref, scale_ref, xl_ref, r_ref, o_ref, *,
            n_k: int, mx_block: int):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ dequant(codes[k,j])."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[...].astype(jnp.float32)        # (bk, bn)
    scale = scale_ref[...]                            # (bk/32, bn)
    bk, bn = codes.shape
    w = (codes.reshape(bk // mx_block, mx_block, bn)
         * scale[:, None, :]).reshape(bk, bn)
    x = x_ref[...].astype(jnp.float32)                # (bm, bk)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _lowrank():
        xl = xl_ref[...].astype(jnp.float32)          # (bm, r)
        rr = r_ref[...].astype(jnp.float32)           # (r, bn)
        o_ref[...] += jnp.dot(xl, rr, preferred_element_type=jnp.float32)


def mxint_lowrank_matmul_2d(
    x: jax.Array,        # (M, K)
    codes: jax.Array,    # (K, N) int8
    scale: jax.Array,    # (K/32, N) f32
    xl: jax.Array,       # (M, r) — precomputed x @ L
    r: jax.Array,        # (r, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Core pallas_call; caller guarantees M % bm == K % bk == N % bn == 0
    and bk % mx_block == 0."""
    m, k = x.shape
    _, n = codes.shape
    mx_block = k // scale.shape[0]
    assert bk % mx_block == 0, (bk, mx_block)
    rr = max(r.shape[0], 1)
    if r.shape[0] == 0:  # rank-0: keep the kernel uniform with a zero sliver
        xl = jnp.zeros((m, 1), x.dtype)
        r = jnp.zeros((1, n), x.dtype)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, mx_block=mx_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // mx_block, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, rr), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((rr, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scale, xl, r)
