"""Shared layout/tiling constants for the Pallas kernels — ONE home.

Before this module the minimum-tile numbers lived in three places at
once: the flash-decode docstrings ("32 rows for int8/f32 pages, 64
logical slots for packed4"), the page-pool evenness check, and the
ROADMAP's hardware-validation notes. The static analyzer
(``tools/analysis``) and the kernels now import the same constants, so
a drifting copy is a lint failure instead of a first-dispatch Mosaic
error on hardware.

Everything here is a plain int / pure function — importable by the
dependency-free analyzer without pulling in jax.
"""
from __future__ import annotations

# MXINT shared-exponent block: one scale per 32 codes along K. The
# quantizer (repro.quant.mxint) and the fused matmul's scale BlockSpecs
# both assume this granularity.
MXINT_BLOCK = 32

# int4 packed4 container: two 4-bit codes per byte along the slot axis,
# so every slot count that touches a packed page must be even.
PACKED4_SLOT_ALIGN = 2

# Mosaic sublane tiling on real TPU hardware: a kernel block's
# second-to-last dim must cover the sublane tile. int8/f32 pages need
# 32 rows; a packed4 page stores two logical slots per sublane row, so
# it needs 64 *logical* slots to fill the same 32 physical rows.
MIN_SUBLANE_TILE = 32
MIN_SUBLANE_TILE_PACKED4 = 64

# Static per-grid-step VMEM budget the analyzer warns over (sum of
# BlockSpec block shapes + VMEM scratch, double-buffering headroom
# left implicit). v5e has 16 MiB; 4 MiB keeps generous room for the
# compiler's own double-buffering of the HBM streams.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024


def min_page_size(packed: bool, strict: bool) -> int:
    """Smallest legal page/block size in logical slots. ``strict`` is
    the real-hardware regime (Mosaic sublane tiling enforced);
    interpret mode only needs nibble-pair alignment."""
    if strict:
        return MIN_SUBLANE_TILE_PACKED4 if packed else MIN_SUBLANE_TILE
    return PACKED4_SLOT_ALIGN


def validate_page_size(page_size: int, *, packed: bool = False,
                       strict: bool = False, what: str = "page_size"
                       ) -> None:
    """Raise ``ValueError`` when ``page_size`` logical slots cannot back
    a kernel block: odd sizes break the packed4 nibble-pair container
    everywhere; under ``strict`` (compiled TPU) the size must also meet
    the Mosaic sublane tile — 32 slots for int8/f32 pages,
    64 for packed4."""
    if page_size % PACKED4_SLOT_ALIGN:
        raise ValueError(
            f"{what}={page_size} must be even (a multiple of "
            f"{PACKED4_SLOT_ALIGN}): int4 packs two slots per byte and a "
            f"nibble pair must not straddle a page")
    floor = min_page_size(packed, strict)
    if page_size < floor:
        raise ValueError(
            f"{what}={page_size} is below the Mosaic sublane tile on "
            f"compiled TPU: {'packed4' if packed else 'int8/f32'} pages "
            f"need >= {floor} logical slots per block "
            f"(interpret mode accepts any even size)")
