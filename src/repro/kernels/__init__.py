"""Pallas TPU kernels for the QER/SRR serving + quantization hot spots.

Validated on CPU with interpret=True against the pure-jnp oracles in
ref.py; compiled for TPU in deployment (ops.py auto-selects).
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (
    decode_attention_op,
    mxint_lowrank_matmul,
    mxint_lowrank_matmul_batched,
    mxint_quantize,
    qlr_matmul,
    qlr_matmul_batched,
)

__all__ = ["ops", "ref", "decode_attention_op",
           "mxint_lowrank_matmul",
           "mxint_lowrank_matmul_batched", "mxint_quantize",
           "qlr_matmul", "qlr_matmul_batched"]
