"""Pallas TPU kernel: forward flash attention (prefill / serving path).

Why: the dry-run roofline shows the XLA-lowered blockwise attention's
memory term is ≈ one full pass over the (B, H, Sq, Sk) score tensor even
after fusion (phi3 prefill_32k: ~6.7 TB/device ≈ 8.2 s at HBM bw — the
dominant term). A flash kernel keeps score tiles in VMEM end to end, so
HBM attention traffic drops to the q/k/v/out tensors themselves
(≈ B·S·H·hd·(3+1) bytes — three orders of magnitude less at 32k).

Design (TPU-native): grid = (B·KV·G, Sq/bq, Sk/bk) with the K dimension
innermost; each program owns one (bq, hd) query tile, and the online-
softmax running stats (m, l, acc) persist across the K steps in VMEM
scratch. Tiles are MXU-aligned (bq = bk = 256 by default; hd rides the
lane dim). VMEM per program ≈ q/k/v tiles (3·256·128·4 B) + score tile
(256·256·4) + acc (256·128·4) ≈ 780 KiB ≪ 16 MiB. The causal / sliding-
window / validity mask comes from explicit position vectors, exactly
matching ``repro.models.attention.blockwise_attention`` semantics
(oracle: ``ref.flash_attention_ref``).

Forward-only by design: serving (prefill/decode) needs no VJP, and the
training path keeps the XLA lowering + remat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref,
            *, n_k: int, causal: bool, window: int, scale: float):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                 # (bk, hd)
    qp = qp_ref[0]                                   # (bq,)
    kp = kp_ref[0]                                   # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = jnp.broadcast_to(kp[None, :] >= 0, s.shape)
    if causal:
        mask = mask & (qp[:, None] >= kp[None, :])
    if window > 0:
        mask = mask & (qp[:, None] - kp[None, :] < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_prev * corr + pv

    @pl.when(kk == n_k - 1)
    def _final():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_hsd(
    q: jax.Array,        # (H, Sq, hd) — flattened batch·heads
    k: jax.Array,        # (H, Sk, hd)
    v: jax.Array,        # (H, Sk, hd)
    q_pos: jax.Array,    # (Sq,) int32
    k_pos: jax.Array,    # (Sk,) int32, -1 ⇒ invalid slot
    *,
    causal: bool = True,
    window: int = 0,     # 0 ⇒ no sliding window
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Core pallas_call; caller guarantees Sq % bq == Sk % bk == 0."""
    h, sq, hd = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    n_q, n_k = sq // bq, sk // bk
    grid = (h, n_q, n_k)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, n_k=n_k, causal=causal,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda hh, i, j: (0, i)),   # q_pos
            pl.BlockSpec((1, bk), lambda hh, i, j: (0, j)),   # k_pos
            pl.BlockSpec((1, bq, hd), lambda hh, i, j: (hh, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda hh, i, j: (hh, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda hh, i, j: (hh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda hh, i, j: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),   # running accumulator
        ],
        interpret=interpret,
    )(q_pos[None], k_pos[None], q, k, v)
