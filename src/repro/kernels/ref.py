"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its semantics pinned by one of these
reference functions; tests sweep shapes/dtypes and assert allclose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mxint_dequant_ref(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """codes (K, N) int8 × per-block scale (K/B, N) → f32 weight."""
    k, n = codes.shape
    nb = scale.shape[0]
    block = k // nb
    w = codes.astype(jnp.float32).reshape(nb, block, n) * scale[:, None, :]
    return w.reshape(k, n)


def mxint_lowrank_matmul_ref(
    x: jax.Array,       # (M, K) or (..., K)
    codes: jax.Array,   # (K, N) int8
    scale: jax.Array,   # (K/B, N) f32
    l: jax.Array,       # (K, r)
    r: jax.Array,       # (r, N)
) -> jax.Array:
    """y = x · dequant(codes, scale) + (x · L) · R — the QER serving op."""
    w = mxint_dequant_ref(codes, scale)
    xf = x.astype(jnp.float32)
    y = xf @ w
    if l.shape[-1] > 0:
        y = y + (xf @ l.astype(jnp.float32)) @ r.astype(jnp.float32)
    return y


def mxint_quantize_ref(w: jax.Array, bits: int = 3,
                       block: int = 32) -> tuple[jax.Array, jax.Array]:
    """(M, N) f32 → (codes int8 (M, N), exponents int8 (M/B, N)).

    Mirrors repro.quant.mxint.MXIntQuantizer.quantize for row counts that
    are multiples of ``block`` (kernel path never pads)."""
    m, n = w.shape
    assert m % block == 0
    qmax = 2 ** (bits - 1) - 1
    blocks = w.astype(jnp.float32).reshape(m // block, block, n)
    amax = jnp.max(jnp.abs(blocks), axis=1)
    safe = jnp.where(amax > 0, amax, 1.0)
    exp = jnp.clip(jnp.ceil(jnp.log2(safe / qmax)), -127, 127)
    scale = jnp.exp2(exp)[:, None, :]
    codes = jnp.clip(jnp.round(blocks / scale), -qmax - 1, qmax)
    codes = jnp.where(amax[:, None, :] > 0, codes, 0.0)
    return (codes.reshape(m, n).astype(jnp.int8), exp.astype(jnp.int8))


def decode_attention_ref(
    q: jax.Array,       # (B, KV, G, hd)
    k: jax.Array,       # (B, KV, S, hd) head-major; f32/bf16, int8 codes,
                        # or packed4 uint8 (B, KV, S/2, hd)
    v: jax.Array,
    q_pos: jax.Array,   # (B,) per-row positions
    k_pos: jax.Array,   # (B, S) per-(row, slot) positions; -1 empty
    k_scale: jax.Array | None = None,   # (B, KV, S) — int8/int4 KV only
    v_scale: jax.Array | None = None,
    window: int = 0,
    scale: float | None = None,
    block_table: jax.Array | None = None,  # (B, nb): k/v/scales are then
                                           # (P, KV, ps[, hd]) page pools
) -> jax.Array:
    """Dense-softmax oracle for the flash-decode kernel: dequantize the
    whole cache, one masked softmax per row. A row with no valid slot
    (all masked) emits zeros, not a uniform V-mean. With ``block_table``
    the paged pools are first materialized to each row's logical view
    (page j of the table holds logical slots [j·ps, (j+1)·ps)). Returns
    (B, KV, G, hd)."""
    hd = q.shape[-1]
    if block_table is not None:
        def flat(pool):  # (P, KV, ps, ...) → (B, KV, nb·ps, ...)
            g = jnp.moveaxis(pool[block_table], 2, 1)
            return g.reshape(g.shape[:2] + (g.shape[2] * g.shape[3],)
                             + g.shape[4:])
        k, v = flat(k), flat(v)
        if k_scale is not None:
            k_scale, v_scale = flat(k_scale), flat(v_scale)
    if k.dtype == jnp.uint8:    # packed4: two slots per byte on axis -2
        from repro.quant.mxint import unpack_codes_4bit
        k, v = unpack_codes_4bit(k), unpack_codes_4bit(v)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)[..., None]
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32), kf) * scale
    mask = (k_pos >= 0) & (k_pos <= q_pos[:, None])        # (B, S)
    if window > 0:
        mask = mask & (q_pos[:, None] - k_pos < window)
    neg = -0.7 * float(jnp.finfo(jnp.float32).max)
    s = jnp.where(mask[:, None, None, :], s, neg)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[:, None, None, None], p, 0.0)
    return jnp.einsum("bkgs,bksd->bkgd", p, vf).astype(q.dtype)


def flash_attention_ref(
    q: jax.Array,       # (H, Sq, hd)
    k: jax.Array,       # (H, Sk, hd)
    v: jax.Array,       # (H, Sk, hd)
    q_pos: jax.Array,   # (Sq,)
    k_pos: jax.Array,   # (Sk,), -1 invalid
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Dense-softmax oracle for the flash attention kernel."""
    hd = q.shape[-1]
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    mask = (k_pos[None, :] >= 0)
    mask = jnp.broadcast_to(mask, s.shape[1:])
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    if window > 0:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    neg = -0.7 * float(jnp.finfo(jnp.float32).max)
    s = jnp.where(mask[None], s, neg)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
