"""Jit'd public wrappers around the Pallas kernels.

Handles everything the raw kernels don't: batch/sequence flattening,
padding to tile multiples, the (x · L) sliver, dtype plumbing, and
interpret-mode fallback so the same call sites run on CPU (validation)
and TPU (deployment). ``repro.models.linear`` routes here for the fused
Q + LR matmul path (``ctx.fused`` / ``ctx.use_pallas``).

``qlr_matmul`` / ``qlr_matmul_batched`` / ``decode_attention_op`` are
the *deployment* entry points: on TPU (or with ``kernel=True``) they run
the Pallas kernel; on other backends they lower to an XLA formulation
that keeps the low-rank correction as an activation sliver and never
materializes the dense ``L·R`` product (matmuls), or feeds the int8 KV
codes straight into the score/value GEMMs with the scales folded into
the score planes and never materializes the dequantized cache (decode
attention) — the best non-Pallas lowering of the same math, so the
``fused="auto"`` serving path is fast everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mxint_matmul import (
    mxint_lowrank_matmul_2d,
    mxint_lowrank_matmul_batched_2d,
    mxint_lowrank_matmul_fused_2d,
)
from repro.kernels.mxint_quantize import mxint_quantize_2d


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "fuse_sliver"))
def mxint_lowrank_matmul(
    x: jax.Array,        # (..., K)
    codes: jax.Array,    # (K, N) int8, or packed4 (K/2, N) uint8
    scale: jax.Array,    # (K/B, N) f32
    l: jax.Array,        # (K, r)
    r: jax.Array,        # (r, N)
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    fuse_sliver: bool = False,
) -> jax.Array:
    """y = x · dequant(codes, scale) + (x · L) · R, any leading dims.

    ``fuse_sliver`` selects the single-pass kernel that accumulates
    ``x · L`` in VMEM scratch instead of precomputing it as a separate
    GEMM — the decode-shape variant (activations fit one M block).

    A uint8 ``codes`` array is the packed4 container (two codes per
    byte); the nibbles are unpacked *inside* the kernel, so the packed
    path streams half the code bytes from HBM."""
    packed = codes.dtype == jnp.uint8
    k = codes.shape[0] * (2 if packed else 1)
    n = codes.shape[1]
    lead = x.shape[:-1]
    xf = x.reshape(-1, k)
    m = xf.shape[0]

    bk = min(bk, k)
    while k % bk:
        bk //= 2
    bmm = min(bm, max(8, m))
    xp = _pad_to(xf, bmm, 0)
    cp = _pad_to(codes, bn, 1)
    sp = _pad_to(scale, bn, 1)
    rp = _pad_to(r, bn, 1)
    bnn = min(bn, cp.shape[1])

    if fuse_sliver:
        y = mxint_lowrank_matmul_fused_2d(
            xp, cp, sp, l, rp, bm=bmm, bn=bnn, bk=bk,
            packed=packed, interpret=_interpret())
    else:
        # the (M, r) sliver: r ≤ 64 ≪ K, negligible FLOPs, one fused GEMM
        xl = xf.astype(jnp.float32) @ l.astype(jnp.float32) \
            if l.shape[-1] > 0 else jnp.zeros((m, 0), jnp.float32)
        xlp = _pad_to(xl, bmm, 0)
        y = mxint_lowrank_matmul_2d(
            xp, cp, sp, xlp, rp, bm=bmm, bn=bnn, bk=bk,
            packed=packed, interpret=_interpret())
    y = y[:m, :n]
    return y.reshape(*lead, n).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def mxint_lowrank_matmul_batched(
    x: jax.Array,        # (G, M, K)
    codes: jax.Array,    # (G, K, N) int8
    scale: jax.Array,    # (G, K/B, N) f32
    l: jax.Array,        # (G, K, r)
    r: jax.Array,        # (G, r, N)
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
) -> jax.Array:
    """Stacked y[g] = x[g] · dequant(codes[g]) + (x[g] · L[g]) · R[g] —
    one pallas_call over the whole stack (MoE experts, scan groups)."""
    g, k, n = codes.shape
    m = x.shape[1]

    xl = jnp.einsum("gmk,gkr->gmr", x.astype(jnp.float32),
                    l.astype(jnp.float32)) \
        if l.shape[-1] > 0 else jnp.zeros((g, m, 0), jnp.float32)

    bk = min(bk, k)
    while k % bk:
        bk //= 2
    bmm = min(bm, max(8, m))
    xp = _pad_to(x, bmm, 1)
    xlp = _pad_to(xl, bmm, 1)
    cp = _pad_to(codes, bn, 2)
    sp = _pad_to(scale, bn, 2)
    rp = _pad_to(r, bn, 2)

    y = mxint_lowrank_matmul_batched_2d(
        xp, cp, sp, xlp, rp, bm=bmm, bn=min(bn, cp.shape[2]), bk=bk,
        interpret=_interpret())
    return y[:, :m, :n].astype(x.dtype)


# ---------------------------------------------------------------------------
# Deployment dispatch: Pallas kernel on TPU, fused-XLA formulation elsewhere
# ---------------------------------------------------------------------------
def dequant_blockwise(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Blockwise dequant via reshape-multiply (no ``jnp.repeat`` copy of
    the scale plane); leading stack dims pass through. The single XLA
    lowering of ``dequant`` — ``models.linear.dequant_weight`` and the
    fused-XLA matmuls below all route here."""
    lead, (k, n) = codes.shape[:-2], codes.shape[-2:]
    nb = scale.shape[-2]
    return (codes.astype(dtype).reshape(lead + (nb, k // nb, n))
            * scale.astype(dtype)[..., :, None, :]).reshape(lead + (k, n))


@jax.jit
def _qlr_matmul_xla(x, codes, scale, l, r):
    """XLA lowering of the fused op: backbone matmul against the
    blockwise-dequantized weight + the rank-r correction as an activation
    sliver (never the dense (K, N) ``L·R`` product)."""
    dt = x.dtype
    y = x @ dequant_blockwise(codes, scale, dt)
    if l.shape[-1] > 0:
        y = y + (x @ l.astype(dt)) @ r.astype(dt)
    return y


@jax.jit
def _qlr_matmul_batched_xla(x, codes, scale, l, r):
    dt = x.dtype
    y = jnp.einsum("gmk,gkn->gmn", x, dequant_blockwise(codes, scale, dt))
    if l.shape[-1] > 0:
        xl = jnp.einsum("gmk,gkr->gmr", x, l.astype(dt))
        y = y + jnp.einsum("gmr,grn->gmn", xl, r.astype(dt))
    return y


def qlr_matmul(x, codes, scale, l, r, *, kernel=None) -> jax.Array:
    """y = x · dequant(codes, scale) + (x · L) · R — deployment entry.

    ``kernel=None`` auto-selects: Pallas on TPU, fused-XLA elsewhere.
    ``kernel=True`` forces the Pallas kernel (interpret mode off-TPU —
    numerics validation); ``kernel=False`` forces the XLA path.

    uint8 ``codes`` = the packed4 container: the kernel unpacks nibbles
    in VMEM (half the HBM code traffic); the XLA path unpacks up front
    (XLA has no sub-byte dot, so int8 expansion is its best lowering)."""
    if kernel is None:
        kernel = jax.default_backend() == "tpu"
    if not kernel and codes.dtype == jnp.uint8:
        from repro.quant.mxint import unpack_codes_4bit
        codes = unpack_codes_4bit(codes)
    if kernel:
        # Decode regime (activations fit one M block): accumulate the
        # x·L sliver inside the kernel pass — x is already VMEM-resident
        # per K step, so the correction adds zero HBM traffic, vs. a
        # separate sliver GEMM re-reading x from HBM. At prefill M the
        # per-N-block sliver recompute would cost real FLOPs, so large M
        # keeps the precomputed-xl kernel.
        rows = x.size // x.shape[-1]
        return mxint_lowrank_matmul(x, codes, scale, l, r,
                                    fuse_sliver=rows <= 128)
    return _qlr_matmul_xla(x, codes, scale, l, r)


def qlr_matmul_batched(x, codes, scale, l, r, *, kernel=None) -> jax.Array:
    """Stacked-weight variant of :func:`qlr_matmul` (MoE experts)."""
    if kernel is None:
        kernel = jax.default_backend() == "tpu"
    if kernel:
        return mxint_lowrank_matmul_batched(x, codes, scale, l, r)
    return _qlr_matmul_batched_xla(x, codes, scale, l, r)


@functools.partial(jax.jit, static_argnames=("bits", "mx_block", "bm", "bn"))
def mxint_quantize(
    w: jax.Array,        # (M, N), M % mx_block == 0
    bits: int = 3,
    mx_block: int = 32,
    bm: int = 256,
    bn: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """(codes, exponents) = MXINT(w); pads N (and M to a block multiple)."""
    m, n = w.shape
    assert m % mx_block == 0, "pad rows to the MXINT block before calling"
    bmm = min(bm, m)
    while m % bmm:
        bmm -= mx_block
    wp = _pad_to(w, bn, 1)
    codes, exps = mxint_quantize_2d(
        wp, bits=bits, mx_block=mx_block, bm=bmm,
        bn=min(bn, wp.shape[1]), interpret=_interpret())
    return codes[:, :n], exps[:, :n]


# ---------------------------------------------------------------------------
# Decode attention: Pallas flash-decode on TPU, fused-XLA lowering elsewhere
# ---------------------------------------------------------------------------
def gather_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize the logical head-major view of a paged pool for one
    batch of block tables: pool ``(P, KV, ps, ...)`` + table ``(B, nb)``
    → ``(B, KV, nb·ps, ...)``. Works for K/V pages (trailing hd axis,
    including the packed4 uint8 container — page rows concatenate along
    the packed slot axis because pages hold whole byte pairs) and for
    the (P, KV, ps) scale planes. This is the XLA lowering's one gather
    per step; the Pallas paged kernel never materializes it (the block
    table steers the DMA instead)."""
    g = pool[block_table]                      # (B, nb, KV, ps, ...)
    g = jnp.moveaxis(g, 2, 1)                  # (B, KV, nb, ps, ...)
    return g.reshape(g.shape[:2] + (g.shape[2] * g.shape[3],) + g.shape[4:])


@functools.partial(jax.jit, static_argnames=("window", "scale"))
def _decode_attention_xla_paged(q, k, v, q_pos, k_pos, block_table,
                                k_scale, v_scale, window=0, scale=None):
    """Paged fused-XLA lowering: one gather maps each row's block table
    over the pools (codes stay in their storage container — packed4
    stays packed through the gather), then the regular fused-XLA
    single-query attention runs on the logical view."""
    k = gather_pages(k, block_table)
    v = gather_pages(v, block_table)
    if k_scale is not None:
        k_scale = gather_pages(k_scale, block_table)
        v_scale = gather_pages(v_scale, block_table)
    return _decode_attention_xla(q, k, v, q_pos, k_pos, k_scale, v_scale,
                                 window=window, scale=scale)


@functools.partial(jax.jit, static_argnames=("window", "scale"))
def _decode_attention_xla(q, k, v, q_pos, k_pos, k_scale, v_scale,
                          window=0, scale=None):
    """Fused-XLA lowering of single-query attention over the head-major
    ``(B, KV, S, hd)`` cache. int8 codes feed the score/value matmuls
    directly and the per-(slot, head) scales are applied to the (B, KV,
    G, S) score / probability planes — the dense f32 cache is never
    materialized, and the head-major layout means the batched GEMMs run
    without transposing the cache (the old sequence-major einsum
    relayouted the whole cache every step). packed4 (uint8) pages are
    expanded to int8 codes first — XLA has no sub-byte dot, so the 1
    byte/elt code plane is its best lowering; the scales still fold into
    the score/probability planes. A row with no valid slot emits zeros
    (matching the kernel and the oracle), not a uniform V-mean."""
    hd = q.shape[-1]
    if k.dtype == jnp.uint8:    # packed4: two slots per byte on axis -2
        from repro.quant.mxint import unpack_codes_4bit
        k, v = unpack_codes_4bit(k), unpack_codes_4bit(v)
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    if k_scale is not None:
        s = s * k_scale.astype(jnp.float32)[:, :, None, :]
    s = s * scale
    mask = (k_pos >= 0) & (k_pos <= q_pos[:, None])        # (B, S)
    if window > 0:
        mask = mask & (q_pos[:, None] - k_pos < window)
    neg = -0.7 * float(jnp.finfo(jnp.float32).max)
    s = jnp.where(mask[:, None, None, :], s, neg)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[:, None, None, None], p, 0.0)
    if v_scale is not None:
        p = p * v_scale.astype(jnp.float32)[:, :, None, :]
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "bs", "interpret"))
def _decode_attention_pallas(q, k, v, q_pos, k_pos, k_scale, v_scale,
                             window=0, scale=None, bs=256, interpret=False):
    """Pad the slot axis to the kernel block and run the flash-decode
    kernel (pad slots carry k_pos = -1, so they mask out). The block is
    rounded up to the 32-row sublane tile (the int8 minimum; also
    satisfies f32's 8) — 64 for packed4 pages so the byte tile (bs/2
    sublanes) still meets the uint8 minimum — interpret mode accepts any
    block shape, Mosaic on real TPU does not."""
    from repro.kernels.decode_attention import flash_decode_bkgd
    packed = k.dtype == jnp.uint8
    s_len = k.shape[2] * (2 if packed else 1)
    bs = min(bs, max(s_len, 1))
    tile = 64 if packed else 32
    bs = -(-bs // tile) * tile
    pad = (-s_len) % bs          # even when packed: s_len and bs both are
    if pad:
        widths4 = ((0, 0), (0, 0), (0, pad // (2 if packed else 1)), (0, 0))
        k = jnp.pad(k, widths4)
        v = jnp.pad(v, widths4)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad)))
    return flash_decode_bkgd(q, k, v, q_pos, k_pos, k_scale, v_scale,
                             window=window, scale=scale, bs=bs,
                             interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "interpret"))
def _decode_attention_pallas_paged(q, k, v, q_pos, k_pos, block_table,
                                   k_scale, v_scale, window=0, scale=None,
                                   interpret=False):
    """The paged kernel needs no slot padding: the logical length is
    nb·ps by construction, and the kernel block is the page."""
    from repro.kernels.decode_attention import flash_decode_paged
    return flash_decode_paged(q, k, v, q_pos, k_pos, block_table,
                              k_scale, v_scale, window=window, scale=scale,
                              interpret=interpret)


def decode_attention_op(
    q: jax.Array,              # (B, KV, G, hd)
    k: jax.Array,              # (B, KV, S, hd) — f32/bf16, int8 codes, or
                               # packed4 uint8 (B, KV, S/2, hd); with a
                               # block_table: the page pool (P, KV, ps, hd)
                               # / (P, KV, ps/2, hd)
    v: jax.Array,
    q_pos: jax.Array,          # (B,) per-row positions
    k_pos: jax.Array,          # (B, S) per-(row, slot) map; -1 ⇒ empty
    *,
    k_scale: jax.Array = None,  # (B, KV, S) f32 — int8/int4 KV only
    v_scale: jax.Array = None,  # (with block_table: (P, KV, ps) pools)
    window: int = 0,
    scale: float = None,
    kernel: bool = None,
    block_table: jax.Array = None,  # (B, nb) page ids — paged cache only
) -> jax.Array:
    """Single-query attention over the slot cache — deployment entry.

    ``kernel=None`` auto-selects: the Pallas flash-decode kernel on TPU,
    the fused-XLA lowering elsewhere. ``kernel=True`` forces the kernel
    (interpret mode off-TPU — numerics validation); ``kernel=False``
    forces the XLA path. Both read int8 KV codes directly and fold the
    scales into the score/probability planes; neither materializes the
    dequantized cache. uint8 ``k``/``v`` is the **packed4 int4 cache**
    (two slots per byte along the slot axis, scales still (B, KV, S)):
    the kernel unpacks nibbles in VMEM, so codes stream HBM at 0.5
    byte/elt; the XLA lowering expands to int8 codes first (no sub-byte
    dot in XLA) and still never builds the dense float cache.

    ``block_table`` switches to the **paged** cache: ``k``/``v`` (and
    the scales) are physical page *pools* and each row reads through its
    (B, nb) table of page ids. The kernel follows the indirection per
    sequence grid step (scalar-prefetched table steers the page DMA —
    nothing is gathered); the XLA lowering pays one gather to the
    logical view first. ``k_pos`` then covers the logical nb·ps slots.

    ``scale`` overrides the 1/√hd score scale (the MLA latent path
    scores in the latent dim but scales by the head dim). Returns
    (B, KV, G, hd) in q.dtype."""
    if kernel is None:
        kernel = jax.default_backend() == "tpu"
    if block_table is not None:
        fn = _decode_attention_pallas_paged if kernel \
            else _decode_attention_xla_paged
        kw = {"interpret": _interpret()} if kernel else {}
        return fn(q, k, v, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32),
                  block_table.astype(jnp.int32), k_scale, v_scale,
                  window=window, scale=scale, **kw)
    fn = _decode_attention_pallas if kernel else _decode_attention_xla
    kw = {"interpret": _interpret()} if kernel else {}
    return fn(q, k, v, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32),
              k_scale, v_scale, window=window, scale=scale, **kw)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(
    q: jax.Array,        # (B, Sq, KV, G, hd)
    k: jax.Array,        # (B, Sk, KV, hd)
    v: jax.Array,        # (B, Sk, KV, hd)
    q_pos: jax.Array,    # (Sq,)
    k_pos: jax.Array,    # (Sk,)
    causal: bool = True,
    window: int = 0,
    bq: int = 256,
    bk: int = 256,
) -> jax.Array:
    """Model-layout wrapper over the flash kernel: handles GQA group
    broadcast, (B·KV·G) flattening and Sq/Sk padding. Returns
    (B, Sq, KV, G, hd) like blockwise_attention."""
    from repro.kernels.flash_attention import flash_attention_hsd
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    kb = jnp.broadcast_to(k[:, :, :, None, :], (b, sk, kvh, g, hd))
    vb = jnp.broadcast_to(v[:, :, :, None, :], (b, sk, kvh, g, hd))
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kvh * g, sq, hd)
    kf = kb.transpose(0, 2, 3, 1, 4).reshape(b * kvh * g, sk, hd)
    vf = vb.transpose(0, 2, 3, 1, 4).reshape(b * kvh * g, sk, hd)

    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    pq = (-sq) % bq_
    pk = (-sk) % bk_
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pk), constant_values=-1)
    out = flash_attention_hsd(
        qf, kf, vf, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32),
        causal=causal, window=window, bq=bq_, bk=bk_,
        interpret=_interpret())
    out = out[:, :sq].reshape(b, kvh, g, sq, hd).transpose(0, 3, 1, 2, 4)
    return out.astype(q.dtype)
