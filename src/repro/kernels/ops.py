"""Jit'd public wrappers around the Pallas kernels.

Handles everything the raw kernels don't: batch/sequence flattening,
padding to tile multiples, the (x · L) sliver, dtype plumbing, and
interpret-mode fallback so the same call sites run on CPU (validation)
and TPU (deployment). ``repro.models.linear`` routes here when
``ctx.use_pallas`` is set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mxint_matmul import mxint_lowrank_matmul_2d
from repro.kernels.mxint_quantize import mxint_quantize_2d


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def mxint_lowrank_matmul(
    x: jax.Array,        # (..., K)
    codes: jax.Array,    # (K, N) int8
    scale: jax.Array,    # (K/B, N) f32
    l: jax.Array,        # (K, r)
    r: jax.Array,        # (r, N)
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
) -> jax.Array:
    """y = x · dequant(codes, scale) + (x · L) · R, any leading dims."""
    k, n = codes.shape
    lead = x.shape[:-1]
    xf = x.reshape(-1, k)
    m = xf.shape[0]

    # the (M, r) sliver: r ≤ 64 ≪ K, negligible FLOPs, one fused GEMM
    xl = xf.astype(jnp.float32) @ l.astype(jnp.float32) \
        if l.shape[-1] > 0 else jnp.zeros((m, 0), jnp.float32)

    bk = min(bk, k)
    while k % bk:
        bk //= 2
    bmm = min(bm, max(8, m))
    xp = _pad_to(xf, bmm, 0)
    xlp = _pad_to(xl, bmm, 0)
    cp = _pad_to(codes, bn, 1)
    sp = _pad_to(scale, bn, 1)
    rp = _pad_to(r, bn, 1)

    y = mxint_lowrank_matmul_2d(
        xp, cp, sp, xlp, rp, bm=bmm, bn=min(bn, cp.shape[1]), bk=bk,
        interpret=_interpret())
    y = y[:m, :n]
    return y.reshape(*lead, n).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "mx_block", "bm", "bn"))
def mxint_quantize(
    w: jax.Array,        # (M, N), M % mx_block == 0
    bits: int = 3,
    mx_block: int = 32,
    bm: int = 256,
    bn: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """(codes, exponents) = MXINT(w); pads N (and M to a block multiple)."""
    m, n = w.shape
    assert m % mx_block == 0, "pad rows to the MXINT block before calling"
    bmm = min(bm, m)
    while m % bmm:
        bmm -= mx_block
    wp = _pad_to(w, bn, 1)
    codes, exps = mxint_quantize_2d(
        wp, bits=bits, mx_block=mx_block, bm=bmm,
        bn=min(bn, wp.shape[1]), interpret=_interpret())
    return codes[:, :n], exps[:, :n]


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(
    q: jax.Array,        # (B, Sq, KV, G, hd)
    k: jax.Array,        # (B, Sk, KV, hd)
    v: jax.Array,        # (B, Sk, KV, hd)
    q_pos: jax.Array,    # (Sq,)
    k_pos: jax.Array,    # (Sk,)
    causal: bool = True,
    window: int = 0,
    bq: int = 256,
    bk: int = 256,
) -> jax.Array:
    """Model-layout wrapper over the flash kernel: handles GQA group
    broadcast, (B·KV·G) flattening and Sq/Sk padding. Returns
    (B, Sq, KV, G, hd) like blockwise_attention."""
    from repro.kernels.flash_attention import flash_attention_hsd
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    kb = jnp.broadcast_to(k[:, :, :, None, :], (b, sk, kvh, g, hd))
    vb = jnp.broadcast_to(v[:, :, :, None, :], (b, sk, kvh, g, hd))
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kvh * g, sq, hd)
    kf = kb.transpose(0, 2, 3, 1, 4).reshape(b * kvh * g, sk, hd)
    vf = vb.transpose(0, 2, 3, 1, 4).reshape(b * kvh * g, sk, hd)

    bq_ = min(bq, sq)
    bk_ = min(bk, sk)
    pq = (-sq) % bq_
    pk = (-sk) % bk_
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq))
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pk), constant_values=-1)
    out = flash_attention_hsd(
        qf, kf, vf, q_pos.astype(jnp.int32), k_pos.astype(jnp.int32),
        causal=causal, window=window, bq=bq_, bk=bk_,
        interpret=_interpret())
    out = out[:, :sq].reshape(b, kvh, g, sq, hd).transpose(0, 3, 1, 2, 4)
    return out.astype(q.dtype)
