"""Pallas TPU kernel: MXINT block-exponent extraction (quantization).

The PTQ pipeline quantizes every (possibly SRR-residual) weight matrix;
at 70B scale that is ~10^11 elements of "reduce 32 rows → exponent, then
round" — trivially parallel and memory-bound. The kernel tiles (bm, bn)
weight blocks into VMEM, computes per-32-block abs-max → power-of-2
exponent → rounded codes entirely on-chip, and writes int8 codes +
exponents back; one HBM read + ~0.53× HBM write per element, no f32
intermediates in HBM.

bm is a multiple of the MXINT block (32); tiles are (256, 256) by
default: 256·256·4 B ≈ 256 KiB of VMEM for the input tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, codes_ref, exp_ref, *, bits: int, mx_block: int):
    w = w_ref[...].astype(jnp.float32)                # (bm, bn)
    bm, bn = w.shape
    qmax = 2 ** (bits - 1) - 1
    blocks = w.reshape(bm // mx_block, mx_block, bn)
    amax = jnp.max(jnp.abs(blocks), axis=1)           # (bm/32, bn)
    safe = jnp.where(amax > 0, amax, 1.0)
    exp = jnp.clip(jnp.ceil(jnp.log2(safe / qmax)), -127, 127)
    scale = jnp.exp2(exp)[:, None, :]
    codes = jnp.clip(jnp.round(blocks / scale), -qmax - 1, qmax)
    codes = jnp.where(amax[:, None, :] > 0, codes, 0.0)
    codes_ref[...] = codes.reshape(bm, bn).astype(jnp.int8)
    exp_ref[...] = exp.astype(jnp.int8)


def mxint_quantize_2d(
    w: jax.Array,        # (M, N), M % mx_block == 0
    *,
    bits: int = 3,
    mx_block: int = 32,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (codes int8 (M, N), exponents int8 (M/32, N)); caller
    pads so M % bm == N % bn == 0 and bm % mx_block == 0."""
    m, n = w.shape
    assert m % mx_block == 0 and bm % mx_block == 0
    if m % bm or n % bn:
        raise ValueError(
            f"mxint_quantize_2d tiles must divide the problem: "
            f"(M={m}, N={n}) vs (bm={bm}, bn={bn}) — pad first, or the "
            f"grid would silently drop the tail")
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, mx_block=mx_block),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm // mx_block, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m // mx_block, n), jnp.int8),
        ],
        interpret=interpret,
    )(w)
