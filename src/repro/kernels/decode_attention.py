"""Pallas TPU kernel: flash-decode attention over the slot KV cache.

Why: after PR 2 every quantized projection runs fused, so serving decode
is dominated by the attention read over the cache. The XLA lowering
dequantized the whole int8 cache into f32 *and* (with the old
sequence-major layout) transposed it to bring the batch/head dims
adjacent before the score matmul — two full HBM round trips over the
largest live tensor, every token. This kernel reads the cache exactly
once, in its storage dtype:

  * single-query online-softmax attention, blocked along the sequence
    (slot) axis; running (m, l, acc) stats live in VMEM scratch across
    the S grid steps — the (G, S) score plane never touches HBM;
  * the cache is **head-major** ``(B, KV, S, hd)`` so each (batch, head)
    grid step streams a contiguous (bs, hd) tile — no transpose;
  * int8 KV dequantization is fused *inside*: codes stream HBM→VMEM as
    int8 (1 byte/elt) and the per-(slot, head) scale is applied to the
    (G, bs) score columns / probability columns instead of the (bs, hd)
    tile — the dense f32 cache never exists anywhere;
  * **int4 KV** reuses the packed4 nibble container: uint8 pages
    ``(B, KV, S/2, hd)`` hold two slots per byte (slot 2j = low nibble —
    the ``pack_codes_4bit`` layout, packed along the *slot* axis) and are
    unpacked in-kernel (:func:`~repro.kernels.mxint_matmul._unpack_tile`
    on the (bs/2, hd) tile), so codes stream HBM→VMEM at 0.5 byte/elt —
    the KV HBM footprint halves again vs int8;
  * per-row masking from explicit ``q_pos`` (B,) / ``k_pos`` (B, S)
    position maps — co-batched rows decode at unrelated positions
    (continuous batching) — plus an optional sliding window;
  * GQA via the (KV, G) head layout: one grid step scores all G query
    heads of a KV group against the group's single K/V stream.

Grid: (B, KV, S/bs) with the sequence axis innermost. VMEM per step ≈
k/v tiles (2·bs·hd·{1,4} B) + scores (G·bs·4) + acc (G·hd·4) ≪ 16 MiB
at bs = 256. Forward-only by design (serving needs no VJP).

Oracle: ``ref.decode_attention_ref``; dispatcher: ``ops.decode_attention_op``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.constraints import PACKED4_SLOT_ALIGN, validate_page_size
from repro.kernels.mxint_matmul import _unpack_tile

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, *rest,
                   n_s: int, window: int, scale: float, quantized: bool,
                   packed: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
    k = k_ref[0, 0]                                  # (bs, hd) / (bs/2, hd)
    if packed:
        # int4 KV: the (bs/2, hd) uint8 tile expands to (bs, hd) int8
        # codes in VMEM — slot pairs interleave on the sublane axis, the
        # layout pack_codes_4bit writes along the slot dim
        k = _unpack_tile(k)
    k = k.astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    if quantized:
        # dequant fused on the (G, bs) score columns — G·bs multiplies
        # instead of bs·hd, and the f32 K tile never materializes
        s = s * ks_ref[0, 0][None, :]
    s = s * scale

    qp = qp_ref[0, 0]                                # scalar position
    kp = kp_ref[0]                                   # (bs,) slot positions
    mask = (kp >= 0) & (kp <= qp)
    if window > 0:
        mask = mask & (qp - kp < window)
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # a lane whose running max is still NEG_INF has seen no valid slot
    # yet: exp(NEG_INF - NEG_INF) = 1 would credit every masked column
    # with unit probability, and a lane that stays empty through all S
    # blocks would emit an unweighted V-mean instead of zeros. Zero p
    # while m_new sits at the sentinel (real scores are bounded far
    # above NEG_INF/2); corr is then exp(0)·{l,acc}=0 — harmless.
    p = jnp.where(m_new > 0.5 * NEG_INF,
                  jnp.exp(s - m_new), 0.0)           # (G, bs)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    if quantized:
        p = p * vs_ref[0, 0][None, :]                # fold V scales into p
    v = v_ref[0, 0]                                  # (bs, hd) / (bs/2, hd)
    if packed:
        v = _unpack_tile(v)
    v = v.astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_prev * corr + pv

    @pl.when(si == n_s - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_decode_bkgd(
    q: jax.Array,              # (B, KV, G, hd)
    k: jax.Array,              # (B, KV, S, hd) — f32/bf16, int8 codes, or
                               # packed4 uint8 (B, KV, S/2, hd)
    v: jax.Array,              # same container as k
    q_pos: jax.Array,          # (B,) int32 per-row positions
    k_pos: jax.Array,          # (B, S) int32 per-(row, slot) map; -1 empty
    k_scale: jax.Array | None = None,   # (B, KV, S) f32 — int8/int4 KV only
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,           # 0 ⇒ no sliding window
    scale: float | None = None,
    bs: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Core pallas_call; S % bs == 0 is a hard contract (checked — a
    truncated tail would silently drop the newest cache slots). uint8
    ``k``/``v`` is the packed4 container: two slots per byte along the
    slot axis, unpacked in-kernel. Returns (B, KV, G, hd) in q.dtype."""
    b, kv, g, hd = q.shape
    packed = k.dtype == jnp.uint8
    s_len = k.shape[2] * (2 if packed else 1)
    if packed and k_scale is None:
        raise ValueError("packed4 (uint8) KV pages require k/v scales")
    bs = min(bs, s_len)
    if s_len % bs:
        raise ValueError(
            f"flash_decode_bkgd: S={s_len} is not a multiple of bs={bs} — "
            f"pad the slot axis (see ops._decode_attention_pallas) instead "
            f"of letting the grid drop the tail")
    if packed and bs % PACKED4_SLOT_ALIGN:
        raise ValueError(f"packed4 KV needs an even block, got bs={bs}")
    n_s = s_len // bs
    quantized = k_scale is not None
    if scale is None:
        scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _decode_kernel, n_s=n_s, window=window, scale=float(scale),
        quantized=quantized, packed=packed)
    cdiv = 2 if packed else 1    # packed slot rows hold two codes each
    in_specs = [
        pl.BlockSpec((1, 1), lambda bb, hh, ss: (bb, 0)),        # q_pos
        pl.BlockSpec((1, bs), lambda bb, hh, ss: (bb, ss)),      # k_pos
        pl.BlockSpec((1, 1, g, hd), lambda bb, hh, ss: (bb, hh, 0, 0)),
        pl.BlockSpec((1, 1, bs // cdiv, hd), lambda bb, hh, ss: (bb, hh, ss, 0)),
        pl.BlockSpec((1, 1, bs // cdiv, hd), lambda bb, hh, ss: (bb, hh, ss, 0)),
    ]
    args = [q_pos.reshape(b, 1).astype(jnp.int32),
            k_pos.astype(jnp.int32), q, k, v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, bs), lambda bb, hh, ss: (bb, hh, ss)),
            pl.BlockSpec((1, 1, bs), lambda bb, hh, ss: (bb, hh, ss)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    return pl.pallas_call(
        kernel,
        grid=(b, kv, n_s),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bb, hh, ss: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max m
            pltpu.VMEM((g, 1), jnp.float32),     # running sum l
            pltpu.VMEM((g, hd), jnp.float32),    # running accumulator
        ],
        interpret=interpret,
    )(*args)


def _paged_decode_kernel(bt_ref, *args, **kw):
    """Scalar-prefetch wrapper: the block table rode in as prefetch arg
    0 (it steered the index maps); the body is the shared flash-decode
    kernel, which never needs it."""
    del bt_ref
    _decode_kernel(*args, **kw)


def flash_decode_paged(
    q: jax.Array,              # (B, KV, G, hd)
    k: jax.Array,              # page pool (P, KV, ps, hd) — f32/bf16, int8
                               # codes, or packed4 uint8 (P, KV, ps/2, hd)
    v: jax.Array,              # same container as k
    q_pos: jax.Array,          # (B,) int32 per-row positions
    k_pos: jax.Array,          # (B, nb·ps) logical slot positions; -1 empty
    block_table: jax.Array,    # (B, nb) int32 physical page per block
    k_scale: jax.Array | None = None,   # (P, KV, ps) f32 — int8/int4 only
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Paged flash-decode: same online-softmax body as
    :func:`flash_decode_bkgd`, but the sequence grid axis walks each
    row's **block table** instead of a contiguous slot axis — the
    K/V/scale block specs are steered by a scalar-prefetched page-id
    table (``PrefetchScalarGridSpec``), so grid step (b, h, j) DMAs
    physical page ``block_table[b, j]`` and the pool never streams
    pages the row doesn't own. Every table entry must be a valid page id
    (the serving layer parks unused entries on a private page).

    The kernel block *is* the page: one page per sequence grid step. On
    real TPU hardware that means the page size must meet the Mosaic
    sublane tile (32 rows for int8/f32 pages, 64 logical slots for
    packed4); interpret mode — CPU validation — takes any even size.
    Returns (B, KV, G, hd) in q.dtype."""
    b, kv, g, hd = q.shape
    packed = k.dtype == jnp.uint8
    ps = k.shape[2] * (2 if packed else 1)
    nb = block_table.shape[1]
    if k_pos.shape[1] != nb * ps:
        raise ValueError(
            f"flash_decode_paged: k_pos covers {k_pos.shape[1]} slots but "
            f"the block table addresses {nb}×{ps}")
    if packed and k_scale is None:
        raise ValueError("packed4 (uint8) KV pages require k/v scales")
    # the kernel block IS the page: nibble pairs must land whole, and a
    # compiled (non-interpret) run must meet the Mosaic sublane tile —
    # fail at dispatch setup with the shared constraint error instead of
    # a Mosaic lowering crash
    validate_page_size(ps, packed=packed, strict=not interpret)
    quantized = k_scale is not None
    if scale is None:
        scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _paged_decode_kernel, n_s=nb, window=window, scale=float(scale),
        quantized=quantized, packed=packed)
    cdiv = 2 if packed else 1
    in_specs = [
        pl.BlockSpec((1, 1), lambda bb, hh, ss, bt: (bb, 0)),       # q_pos
        pl.BlockSpec((1, ps), lambda bb, hh, ss, bt: (bb, ss)),     # k_pos
        pl.BlockSpec((1, 1, g, hd), lambda bb, hh, ss, bt: (bb, hh, 0, 0)),
        pl.BlockSpec((1, 1, ps // cdiv, hd),
                     lambda bb, hh, ss, bt: (bt[bb, ss], hh, 0, 0)),
        pl.BlockSpec((1, 1, ps // cdiv, hd),
                     lambda bb, hh, ss, bt: (bt[bb, ss], hh, 0, 0)),
    ]
    args = [q_pos.reshape(b, 1).astype(jnp.int32),
            k_pos.astype(jnp.int32), q, k, v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, ps), lambda bb, hh, ss, bt: (bt[bb, ss], hh, 0)),
            pl.BlockSpec((1, 1, ps), lambda bb, hh, ss, bt: (bt[bb, ss], hh, 0)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bb, hh, ss, bt: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),     # running max m
            pltpu.VMEM((g, 1), jnp.float32),     # running sum l
            pltpu.VMEM((g, hd), jnp.float32),    # running accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), *args)
