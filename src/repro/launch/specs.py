"""Abstract inputs + sharded step builders for the multi-pod dry-run.

Everything here works on ShapeDtypeStructs — no array is ever allocated,
so lowering a 32B model × 32k context × 512 devices is pure compilation.

Three step kinds per (arch × shape) cell:

  train   : full-parameter LM training (AdamW state included), bf16
  prefill : prompt processing over the quantized Q + LR model
  decode  : one-token serve_step over the quantized model + KV cache

The quantized serving trees use the int8-codes container (3-bit codes in
an int8 carrier + f32 block scales; DESIGN.md §3 records the accounting)
with the paper's r = 64 adapters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import Ctx, decode_step, init_lm, lm_loss
from repro.models.transformer import init_cache, prefill
from repro.models.quantize import quantized_abstract
from repro.optim import AdamW, cosine_schedule
from repro.sharding import (
    batch_spec,
    tree_cache_shardings,
    tree_param_specs,
    tree_shardings,
)
from repro.train import StepConfig, TrainState, make_train_step


@dataclasses.dataclass(frozen=True)
class DryrunOptions:
    """Perf-iteration knobs (EXPERIMENTS.md §Perf records their effect)."""
    remat: str = "none"            # none | full
    microbatch: int = 0
    kv_dtype: str = "int8"         # decode cache: int8 | bf16 | int4
    rank: int = 64                 # adapter rank for serve paths
    compute_dtype: Any = jnp.bfloat16
    donate: bool = True
    q_chunk: int = 512             # blockwise attention tiling
    kv_chunk: int = 1024


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: init_lm(k, cfg, dtype=dtype), jax.random.PRNGKey(0))


def abstract_quant_params(cfg: ModelConfig, rank: int = 64):
    return quantized_abstract(abstract_params(cfg), rank=rank)


def batch_structs(cfg: ModelConfig, shape: ShapeConfig,
                  dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Train/prefill batch stand-ins."""
    b, s = shape.global_batch, shape.seq_len
    S = jax.ShapeDtypeStruct
    out = {"tokens": S((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = S((b, s), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = S((b, cfg.enc_seq, cfg.d_frontend), dtype)
    if cfg.n_vision_tokens:
        out["vision"] = S((b, cfg.n_vision_tokens,
                           cfg.d_frontend or cfg.d_model), dtype)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                opts: DryrunOptions = DryrunOptions()) -> Dict[str, Any]:
    """All abstract inputs for this cell's step (public dry-run surface)."""
    if shape.kind == "train":
        return {"batch": batch_structs(cfg, shape, opts.compute_dtype)}
    if shape.kind == "prefill":
        return {
            "batch": batch_structs(cfg, shape, opts.compute_dtype),
            "cache": abstract_cache(cfg, shape, opts),
        }
    # decode: one new token against a seq_len-deep cache
    return {
        "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "cache": abstract_cache(cfg, shape, opts),
    }


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig,
                   opts: DryrunOptions):
    # "int4" is the packed4 sentinel: the model layer allocates uint8
    # nibble pages (half the int8 cache bytes) for it
    dt = {"int8": jnp.int8, "int4": "int4"}.get(opts.kv_dtype,
                                                jnp.bfloat16)
    slots = shape.seq_len
    if shape.kind == "prefill" and cfg.n_vision_tokens:
        slots += cfg.n_vision_tokens  # vision tokens prepend to the prompt
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, slots, dtype=dt))


# ==========================================================================
# Step builders (abstract in, lowered out)
# ==========================================================================
def _shardings_of(tree: Any, mesh: Mesh, spec_fn) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(mesh, spec_fn(path, x.shape)), tree)


def build_train_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                         opts: DryrunOptions = DryrunOptions()):
    """jit(train_step).lower(...) for this cell."""
    opt = AdamW(learning_rate=cosine_schedule(3e-4, 100, 10_000),
                weight_decay=0.1)
    sc = StepConfig(remat=opts.remat, microbatch=opts.microbatch,
                    compute_dtype=opts.compute_dtype, mesh=mesh)
    step = make_train_step(cfg, opt, sc)

    params_abs = abstract_params(cfg, opts.compute_dtype)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    state_abs = TrainState(params=params_abs, opt=opt_abs,
                           step=jax.ShapeDtypeStruct((), jnp.int32))

    from repro.optim import AdamState
    pspecs = tree_shardings(params_abs, mesh)
    rep = NamedSharding(mesh, P())
    ospecs = TrainState(  # Adam moments share the param layout (FSDP)
        params=pspecs,
        opt=AdamState(step=rep, mu=tree_shardings(params_abs, mesh),
                      nu=tree_shardings(params_abs, mesh)),
        step=rep)
    batch_abs = batch_structs(cfg, shape, opts.compute_dtype)
    bspecs = {k: NamedSharding(
        mesh, batch_spec(mesh, shape.global_batch, len(v.shape) - 1))
        for k, v in batch_abs.items()}
    metric_specs = {"loss": NamedSharding(mesh, P()),
                    "grad_norm": NamedSharding(mesh, P()),
                    "step": NamedSharding(mesh, P())}

    jitted = jax.jit(
        step,
        in_shardings=(ospecs, bspecs),
        out_shardings=(ospecs, metric_specs),
        donate_argnums=(0,) if opts.donate else (),
    )
    return jitted.lower(state_abs, batch_abs)


def build_prefill_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                           opts: DryrunOptions = DryrunOptions()):
    ctx = Ctx(compute_dtype=opts.compute_dtype, mesh=mesh,
              attn_q_chunk=opts.q_chunk, attn_kv_chunk=opts.kv_chunk)

    def prefill_step(params, batch, cache):
        return prefill(ctx, params, batch, cfg, cache)

    qparams = abstract_quant_params(cfg, opts.rank)
    cache_abs = abstract_cache(cfg, shape, opts)
    batch_abs = batch_structs(cfg, shape, opts.compute_dtype)

    pspecs = tree_shardings(qparams, mesh)
    cspecs = tree_cache_shardings(cache_abs, mesh, shape.global_batch)
    bspecs = {k: NamedSharding(
        mesh, batch_spec(mesh, shape.global_batch, len(v.shape) - 1))
        for k, v in batch_abs.items()}

    jitted = jax.jit(
        prefill_step,
        in_shardings=(pspecs, bspecs, cspecs),
        out_shardings=(NamedSharding(
            mesh, batch_spec(mesh, shape.global_batch, 2)), cspecs),
        donate_argnums=(2,) if opts.donate else (),
    )
    return jitted.lower(qparams, batch_abs, cache_abs)


def build_decode_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                          opts: DryrunOptions = DryrunOptions()):
    ctx = Ctx(compute_dtype=opts.compute_dtype, mesh=mesh)

    def serve_step(params, token, cache):
        return decode_step(ctx, params, token, cache, cfg)

    qparams = abstract_quant_params(cfg, opts.rank)
    cache_abs = abstract_cache(cfg, shape, opts)
    token_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

    pspecs = tree_shardings(qparams, mesh)
    cspecs = tree_cache_shardings(cache_abs, mesh, shape.global_batch)
    tspec = NamedSharding(mesh, batch_spec(mesh, shape.global_batch, 1))

    jitted = jax.jit(
        serve_step,
        in_shardings=(pspecs, tspec, cspecs),
        out_shardings=(NamedSharding(
            mesh, batch_spec(mesh, shape.global_batch, 2)), cspecs),
        donate_argnums=(2,) if opts.donate else (),
    )
    return jitted.lower(qparams, token_abs, cache_abs)


BUILDERS: Dict[str, Callable] = {
    "train": build_train_lowering,
    "prefill": build_prefill_lowering,
    "decode": build_decode_lowering,
}


def build_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   opts: DryrunOptions = DryrunOptions()):
    return BUILDERS[shape.kind](cfg, shape, mesh, opts)
