"""While-loop-aware cost analysis of post-SPMD HLO text.

``compiled.cost_analysis()`` counts each ``while`` body **once**, which
under-reports any scan-over-layers / chunked-attention model by the trip
count (verified empirically: an 8-step lax.scan of a matmul reports 1/8
of the unrolled FLOPs). Since the production models here lean on scan for
O(period) compile times, the roofline needs loop-aware accounting.

This module parses ``compiled.as_text()`` — the per-device partitioned
module — into computations and ops, then walks the call graph:

  * ``while``  : (body + cond) × trip count (trip = the max integer
                 constant in the condition computation — exact for the
                 counted loops lax.scan/map emit);
  * ``fusion`` / ``call``: FLOPs recurse into the called computation;
                 bytes count the call-site operands + results only
                 (matching XLA's fusion accounting: internals stay in
                 registers/VMEM);
  * ``dot``    : 2 × |result| × |contracting dims|;
  * elementwise/reduce: 1 FLOP per output element (second-order);
  * collectives: result bytes, accumulated per kind, trip-multiplied.

Outputs per-chip totals: flops, bytes, collective bytes by kind — the
three roofline terms' numerators.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],{} ]+?)\s+"
    r"([\w\-]+)\(")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "negate", "abs", "rsqrt", "sqrt",
    "logistic", "floor", "ceil", "round-nearest-even", "cosine", "sine",
    "select", "compare", "and", "or", "xor", "not", "clamp",
    "exponential-minus-one", "log-plus-one", "sign", "atan2", "remainder",
}
FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "rng-bit-generator",
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(text: str) -> Tuple[int, int]:
    """(elements, bytes) of a shape or tuple-shape string."""
    elems = 0
    size = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        size += n * _DTYPE_BYTES[dt]
    return elems, size


@dataclasses.dataclass
class Op:
    name: str
    shape: str          # result shape text
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str]   # op name -> result shape text


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVES}

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
                # parameters declared in the header keep their own lines
            continue
        s = line.strip()
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, shape, opcode = m.group(1), m.group(2).strip(), m.group(3)
            cur.symbols[name] = shape
            cur.ops.append(Op(name, shape, opcode, s))
    return comps, entry


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _operand_shapes(op: Op, comp: Computation) -> List[str]:
    inner = op.line.split("(", 1)[1]
    inner = inner.split(")", 1)[0]
    names = _OPERAND_RE.findall(inner)
    return [comp.symbols.get(n, "") for n in names]


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_computations(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._param_memo: Dict[Tuple[str, int], Optional[float]] = {}

    def _dus_root_slice_bytes(self, callee: Optional["Computation"]
                              ) -> Optional[float]:
        """If the callee's ROOT is a dynamic-update-slice (possibly via
        bitcast), return the update-slice bytes, else None."""
        if callee is None:
            return None
        root = None
        for op in callee.ops:
            if "ROOT %" in op.line or op.line.startswith("ROOT"):
                root = op
        if root is None and callee.ops:
            root = callee.ops[-1]
        seen = 0
        while root is not None and root.opcode in ("bitcast", "copy",
                                                   "convert") and seen < 4:
            ops_ = _OPERAND_RE.findall(root.line.split("(", 1)[1])
            nxt = next((o for o in callee.ops if o.name in ops_), None)
            root = nxt
            seen += 1
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = _operand_shapes(root, callee)
            if len(upd) > 1:
                return float(_shape_info(upd[1])[1])
        return None

    def _param_effective_bytes(self, callee: "Computation",
                               index: int) -> Optional[float]:
        """If fusion parameter ``index`` is consumed only by dynamic-slice
        (read) or is the target of dynamic-update-slice (in-place write),
        return the slice bytes; None → count the full operand."""
        key = (callee.name, index)
        if key in self._param_memo:
            return self._param_memo[key]
        pname = None
        for op in callee.ops:
            if op.opcode == "parameter" and f"parameter({index})" in op.line:
                pname = op.name
                break
        result: Optional[float] = None
        if pname is not None:
            uses = [op for op in callee.ops
                    if op.opcode != "parameter"
                    and re.search(r"%" + re.escape(pname) + r"\b", op.line)]
            if uses and all(u.opcode in ("dynamic-slice",
                                         "dynamic-update-slice")
                            for u in uses):
                total = 0.0
                for u in uses:
                    if u.opcode == "dynamic-slice":
                        total += _shape_info(u.shape)[1]
                    else:  # DUS: find the update operand's size
                        shapes = _operand_shapes(u, callee)
                        upd = (_shape_info(shapes[1])[1]
                               if len(shapes) > 1 else 0)
                        total += 2.0 * upd  # read-modify-write of the slice
                result = total
        self._param_memo[key] = result
        return result

    def total(self) -> Cost:
        return self.comp_cost(self.entry)

    # ------------------------------------------------------------------
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        self._memo[name] = total  # break cycles defensively
        if comp is None:
            return total
        for op in comp.ops:
            total += self.op_cost(op, comp)
        return total

    def op_cost(self, op: Op, comp: Computation) -> Cost:
        oc = op.opcode
        if oc in FREE_OPS:
            return Cost()
        out_elems, out_bytes = _shape_info(op.shape)

        if oc == "while":
            body = _BODY_RE.search(op.line)
            cond = _COND_RE.search(op.line)
            trip = 1
            inner = Cost()
            if cond and cond.group(1) in self.comps:
                trip = _trip_count(self.comps[cond.group(1)])
                inner += self.comp_cost(cond.group(1))
            if body and body.group(1) in self.comps:
                inner += self.comp_cost(body.group(1))
            return inner.scaled(trip)

        if oc in ("fusion", "call", "custom-call"):
            c = Cost()
            m = _CALLS_RE.search(op.line)
            callee = self.comps.get(m.group(1)) if m else None
            if callee is not None:
                inner = self.comp_cost(callee.name)
                c += Cost(inner.flops, 0.0, inner.coll)
            # bytes at the call boundary: operands + result — EXCEPT
            # in-place slice updates. A fusion whose root is a dynamic-
            # update-slice aliases its big buffer operand with the output
            # and touches only the slice region (XLA in-place DUS); and a
            # parameter consumed only by dynamic-slice reads only the
            # slice. Counting full buffers would overstate scan-carried
            # accumulator traffic by the trip count.
            shapes = _operand_shapes(op, comp)
            opb_list = [float(_shape_info(s)[1]) for s in shapes]
            ob = float(out_bytes)
            if callee is not None:
                # params consumed only through dynamic-(update-)slice read/
                # write just the slice region
                for i in range(len(opb_list)):
                    eff = self._param_effective_bytes(callee, i)
                    if eff is not None:
                        opb_list[i] = min(opb_list[i], eff)
                # a DUS-rooted fusion writes only the updated slice (the
                # output buffer aliases the big input in place)
                dus_slice = self._dus_root_slice_bytes(callee)
                if dus_slice is not None:
                    ob = min(ob, dus_slice)
            c += Cost(0.0, sum(opb_list) + ob)
            return c

        if oc == "conditional":
            # branches: worst case (sum would double-count)
            branches = re.findall(r"%([\w.\-]+)", op.line)
            cs = [self.comp_cost(b) for b in branches if b in self.comps]
            best = max(cs, key=lambda c: c.flops, default=Cost())
            return best

        # leaf op: bytes = operands + result
        opb = sum(_shape_info(s)[1] for s in _operand_shapes(op, comp))
        c = Cost(0.0, opb + out_bytes)

        if oc.startswith(COLLECTIVES):
            for k in COLLECTIVES:
                if oc.startswith(k):
                    if not oc.endswith("-done"):
                        c.coll[k] += out_bytes
                    break
            return c

        if oc == "dot":
            m = _LHS_CDIMS.search(op.line)
            shapes = _operand_shapes(op, comp)
            contract = 1
            if m and shapes and shapes[0]:
                dims_txt = _SHAPE_RE.search(shapes[0])
                if dims_txt:
                    lhs_dims = [int(d) for d in dims_txt.group(2).split(",")
                                if d]
                    for ci in m.group(1).split(","):
                        if ci:
                            contract *= lhs_dims[int(ci)]
            c.flops += 2.0 * out_elems * contract
        elif oc == "convolution":
            # rough: 2 × out × (kernel elems) — unused by the model zoo
            c.flops += 2.0 * out_elems
        elif oc in ELEMENTWISE or oc in ("reduce", "reduce-window",
                                         "exponential", "map"):
            c.flops += float(out_elems)
        return c


def analyze_text(hlo_text: str) -> Dict[str, float]:
    hc = HloCost(hlo_text)
    t = hc.total()
    eff = sum(t.coll.values()) + t.coll["all-reduce"]  # AR ≈ RS + AG
    return {
        "flops": t.flops,
        "bytes": t.bytes,
        "collective_bytes": eff,
        "coll_by_kind": dict(t.coll),
    }
