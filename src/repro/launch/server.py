"""HTTP serving driver: ``python -m repro.launch.server [...]``.

Builds the paper's deployment artifact (init → calibrate → SRR-quantize,
same pipeline as ``repro.launch.serve``) and exposes it through the
OpenAI-compatible frontend (``repro.serve.http``): streaming
`/v1/completions` + `/v1/chat/completions`, `/v1/models`, `/health`,
`/metrics` (Prometheus) and `/metrics.json`.

``--smoke`` boots the server on an ephemeral port, streams one chat
completion through a real HTTP client, validates the SSE protocol and
the metrics snapshot against ``tools/metrics_schema.json``, and exits
0/1 — the CI tier-1 entry point for the serving stack.
"""
from __future__ import annotations

import argparse
import http.client
import importlib.util
import json
import sys
import threading
import time
from pathlib import Path

import numpy as np

from repro.launch.serve import add_model_args, build_quantized_model
from repro.serve import Engine, Request, ServeConfig, serve_http


def build_engine(args) -> Engine:
    params, cfg = build_quantized_model(args, tag="server")
    eng = Engine(params, cfg, ServeConfig(
        max_len=args.max_len, decode_batch=args.batch,
        max_new_tokens=args.new_tokens, eos_id=args.eos_id,
        kv_dtype=args.kv, temperature=args.temperature,
        prefill_len=args.prefill_len, seed=args.seed, fused=args.fused,
        paged=args.paged, page_size=args.page_size,
        max_step_tokens=args.max_step_tokens,
        speculative=args.spec_k > 0,
        spec_k=args.spec_k if args.spec_k > 0 else 4,
        max_pages_per_request=args.max_pages_per_request,
        free_watermark=args.free_watermark, telemetry=args.telemetry,
        sanitize=args.sanitize,
        drift_monitor=args.drift_monitor,
        drift_sample_rate=args.drift_sample_rate,
        drift_ref_fused=args.drift_ref_fused))
    print("[server] warming up (prefill + decode compiles)...")
    eng.warmup()
    return eng


def main(argv=None):
    p = argparse.ArgumentParser()
    add_model_args(p)
    p.add_argument("--kv", default="f32",
                   choices=["f32", "bf16", "int8", "int4"])
    p.add_argument("--batch", type=int, default=4,
                   help="decode lanes (concurrent requests)")
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--new-tokens", type=int, default=32,
                   help="default max_new_tokens when a request sends none")
    p.add_argument("--eos-id", type=int, default=-1)
    p.add_argument("--temperature", type=float, default=0.0,
                   help="default temperature when a request sends none")
    p.add_argument("--prefill-len", type=int, default=32)
    p.add_argument("--fused", default="auto", choices=["auto", "on", "off"])
    p.add_argument("--paged", action="store_true")
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--max-step-tokens", type=int, default=None,
                   help="token-budget step scheduler (see ServeConfig)")
    p.add_argument("--spec-k", type=int, default=0,
                   help="self-speculative decoding draft depth (0 = off; "
                        "greedy continuous-batching lanes only)")
    p.add_argument("--max-pages-per-request", type=int, default=None)
    p.add_argument("--free-watermark", type=float, default=0.0)
    p.add_argument("--telemetry", action="store_true")
    p.add_argument("--sanitize", action="store_true",
                   help="audit serve-state invariants after every step "
                        "(see repro.serve.sanitizer); token-identical "
                        "but host-syncing — smoke/debug use")
    p.add_argument("--drift-monitor", action="store_true",
                   help="sampled shadow comparison of serving vs "
                        "reference-lowering logits; drift histograms + "
                        "NaN/inf guard counters land in /metrics.json")
    p.add_argument("--drift-sample-rate", type=float, default=0.05)
    p.add_argument("--drift-ref-fused", default="off",
                   choices=["auto", "on", "off"])
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8000)
    p.add_argument("--model-id", default="repro-qlr")
    p.add_argument("--smoke", action="store_true",
                   help="boot on an ephemeral port, stream one chat "
                        "completion over real HTTP, validate SSE + "
                        "metrics schema, exit 0/1")
    args = p.parse_args(argv)
    if args.smoke:
        # the smoke validates the full metrics schema, which includes
        # the per-phase step histograms only telemetry records
        args.telemetry = True

    eng = build_engine(args)
    httpd, srv = serve_http(eng, host=args.host,
                            port=0 if args.smoke else args.port,
                            model_id=args.model_id)
    host, port = httpd.server_address[:2]
    if args.smoke:
        t = threading.Thread(target=httpd.serve_forever, daemon=True)
        t.start()
        try:
            ok = run_smoke(host, port, args.model_id,
                           spec=args.spec_k > 0)
        finally:
            httpd.shutdown()
            srv.close()
        return 0 if ok else 1
    print(f"[server] serving {args.model_id} on http://{host}:{port} "
          f"(/v1/completions, /v1/chat/completions, /metrics)")
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        httpd.shutdown()
        srv.close()
    return 0


# ==========================================================================
# --smoke: end-to-end protocol check over a real socket
# ==========================================================================
def _fail(msg: str) -> bool:
    print(f"[smoke] FAIL: {msg}")
    return False


def _get_json(host, port, path):
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", path)
    r = conn.getresponse()
    body = r.read()
    conn.close()
    return r.status, json.loads(body)


def run_smoke(host: str, port: int, model_id: str,
              spec: bool = False) -> bool:
    # -- health + models ------------------------------------------------
    status, health = _get_json(host, port, "/health")
    if status != 200 or health.get("status") != "ok":
        return _fail(f"/health: {status} {health}")
    status, models = _get_json(host, port, "/v1/models")
    if status != 200 or models["data"][0]["id"] != model_id:
        return _fail(f"/v1/models: {status} {models}")

    # -- streamed chat completion --------------------------------------
    conn = http.client.HTTPConnection(host, port, timeout=120)
    body = json.dumps({
        "model": model_id, "stream": True, "max_tokens": 8,
        "messages": [{"role": "user", "content": "smoke test prompt"}]})
    conn.request("POST", "/v1/chat/completions", body,
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    if resp.status != 200:
        return _fail(f"chat stream: HTTP {resp.status} {resp.read()!r}")
    # http.client decodes the chunked transfer encoding transparently
    frames = []
    for raw in resp.read().decode().split("\n\n"):
        raw = raw.strip()
        if raw.startswith("data: "):
            frames.append(raw[len("data: "):])
    conn.close()
    if not frames or frames[-1] != "[DONE]":
        return _fail(f"SSE must end with [DONE] (got {frames[-2:]})")
    events = [json.loads(f) for f in frames[:-1]]
    if not events:
        return _fail("no SSE data events before [DONE]")
    if events[0]["choices"][0]["delta"].get("role") != "assistant":
        return _fail(f"first delta must carry the role: {events[0]}")
    for ev in events:
        if ev.get("object") != "chat.completion.chunk":
            return _fail(f"bad object type: {ev.get('object')}")
        if not ev.get("id", "").startswith("chatcmpl-"):
            return _fail(f"bad id: {ev.get('id')}")
    finishes = [ev["choices"][0].get("finish_reason") for ev in events]
    if finishes[-1] not in ("stop", "length"):
        return _fail(f"last chunk finish_reason: {finishes[-1]}")
    if any(f is not None for f in finishes[:-1]):
        return _fail("finish_reason must be null until the final chunk")
    n_tokens = sum(1 for ev in events
                   if ev["choices"][0].get("delta", {}).get("content"))
    if n_tokens < 1:
        return _fail("no content deltas streamed")
    print(f"[smoke] chat stream OK: {n_tokens} content deltas, "
          f"finish_reason={finishes[-1]}")

    # -- non-stream completion + usage ---------------------------------
    conn = http.client.HTTPConnection(host, port, timeout=120)
    conn.request("POST", "/v1/completions",
                 json.dumps({"model": model_id, "prompt": "hello smoke",
                             "max_tokens": 4}),
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    out = json.loads(resp.read())
    conn.close()
    if resp.status != 200:
        return _fail(f"completions: HTTP {resp.status} {out}")
    usage = out.get("usage", {})
    if usage.get("completion_tokens") != 4:
        return _fail(f"usage: {usage}")
    if out["choices"][0].get("finish_reason") != "length":
        return _fail(f"finish_reason: {out['choices'][0]}")

    # -- metrics: Prometheus text + JSON schema ------------------------
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", "/metrics")
    resp = conn.getresponse()
    prom = resp.read().decode()
    conn.close()
    if resp.status != 200 or "# TYPE" not in prom:
        return _fail("/metrics has no Prometheus TYPE lines")
    status, snap = _get_json(host, port, "/metrics.json")
    if status != 200:
        return _fail(f"/metrics.json: {status}")
    if snap.get("retired", 0) < 2:
        return _fail(f"metrics.json retired={snap.get('retired')}")
    if spec:
        # the greedy smoke requests must actually take the speculative
        # path: rounds recorded + draft/accept counters consistent
        if snap.get("spec_rounds", 0) < 1:
            return _fail(f"spec_rounds={snap.get('spec_rounds')} with "
                         "speculation enabled")
        if snap.get("spec_accepted_tokens", 0) > \
                snap.get("spec_draft_tokens", 0):
            return _fail("spec_accepted_tokens > spec_draft_tokens")
        print(f"[smoke] speculative: {snap['spec_rounds']} rounds, "
              f"acceptance rate {snap.get('spec_acceptance_rate')}")
    root = Path(__file__).resolve().parents[3]
    schema_path = root / "tools" / "metrics_schema.json"
    validator = root / "tools" / "validate_metrics.py"
    if schema_path.exists() and validator.exists():
        spec = importlib.util.spec_from_file_location("validate_metrics",
                                                      validator)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        schema = json.loads(schema_path.read_text())
        errors = mod.validate(snap, schema, schema)
        if errors:
            return _fail("metrics schema: " + "; ".join(errors[:5]))
        print("[smoke] /metrics.json validates against "
              "tools/metrics_schema.json")
    else:
        print("[smoke] metrics schema tooling not found; skipped")

    # -- error envelope -------------------------------------------------
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("POST", "/v1/completions", "{not json",
                 {"Content-Type": "application/json"})
    resp = conn.getresponse()
    err = json.loads(resp.read())
    conn.close()
    if resp.status != 400 or "error" not in err:
        return _fail(f"bad-JSON envelope: {resp.status} {err}")

    print("[smoke] PASS")
    return True


if __name__ == "__main__":
    raise SystemExit(main())
