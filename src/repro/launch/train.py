"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs on whatever devices exist (laptop CPU → host mesh; a TPU slice →
the same code with a bigger mesh). ``--reduced`` (default) trains the
family-preserving tiny config; full-size configs are for real hardware.

Modes:
  full   — ordinary LM pretraining (bf16/f32, AdamW, cosine)
  qpeft  — the paper's §4.4 flow: calibrate → SRR-quantize → freeze the
           backbone → train rank-r adapters with γ-scaled gradients
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.api import PTQConfig
from repro.data import batches, capture_calibration, data_config_for
from repro.launch.mesh import make_host_mesh
from repro.models import init_lm, lm_loss
from repro.models.quantize import quantize_model_params, split_qpeft
from repro.optim import AdamW, cosine_schedule
from repro.quant.base import QuantizerConfig
from repro.train import (
    CheckpointManager,
    StepConfig,
    Trainer,
    init_qpeft_state,
    init_train_state,
    make_qpeft_step,
    make_train_step,
)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="phi3-mini-3.8b")
    p.add_argument("--mode", default="full", choices=["full", "qpeft"])
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--rank", type=int, default=16)
    p.add_argument("--bits", type=int, default=3)
    p.add_argument("--gamma", type=float, default=0.1)
    p.add_argument("--microbatch", type=int, default=0)
    p.add_argument("--remat", default="none", choices=["none", "full"])
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--full-size", action="store_true",
                   help="train the full config (needs real hardware)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    print(f"[train] arch={args.arch} mode={args.mode} "
          f"devices={jax.device_count()} params≈{cfg.n_params() / 1e6:.1f}M")

    dcfg = data_config_for(cfg, seq_len=args.seq, global_batch=args.batch,
                           seed=args.seed)
    opt = AdamW(learning_rate=cosine_schedule(args.lr, 10, args.steps),
                weight_decay=0.01)
    dtype = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16
    sc = StepConfig(remat=args.remat, microbatch=args.microbatch,
                    compute_dtype=dtype,
                    mesh=mesh if jax.device_count() > 1 else None)

    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    if args.mode == "qpeft":
        print("[train] calibrating + quantizing (SRR)…")
        stats = capture_calibration(
            params, cfg, dcfg, lambda c, pp, b, cc: lm_loss(c, pp, b, cc),
            n_batches=2)
        ptq = PTQConfig(method="srr", scaling="qera-exact", rank=args.rank,
                        quantizer=QuantizerConfig(kind="mxint",
                                                  bits=args.bits,
                                                  block_size=32),
                        seed=args.seed)
        qparams, reports = quantize_model_params(params, stats, ptq)
        mean_k = sum(r.k_star for r in reports) / max(len(reports), 1)
        print(f"[train] quantized {len(reports)} matrices, mean k*={mean_k:.1f}")
        trainable, frozen = split_qpeft(qparams)
        state = init_qpeft_state(trainable, frozen, opt)
        step = jax.jit(make_qpeft_step(cfg, opt, sc))
    else:
        state = init_train_state(params, opt)
        step = jax.jit(make_train_step(cfg, opt, sc))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    trainer = Trainer(step, lambda s: batches(dcfg, s), ckpt=ckpt,
                      ckpt_every=args.ckpt_every, log_every=10,
                      meta={"arch": args.arch, "mode": args.mode})
    state, history = trainer.run(state, args.steps)
    if history:
        print(f"[train] final loss {history[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
