"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Full paper pipeline on a reduced model: init → calibrate → SRR-quantize
(W ≈ Q + LR) → serve requests through the continuous-batching engine.
``--method qer`` / ``--method w-only`` serve the baselines instead;
``--kv int8`` exercises the quantized KV cache (``--kv int4`` the
packed4 nibble cache — half the int8 HBM again); ``--scheduler
bucketed`` falls back to the prompt-length-bucketed baseline scheduler.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.api import PTQConfig
from repro.data import capture_calibration, data_config_for
from repro.models import init_lm, lm_loss
from repro.models.quantize import quantize_model_params
from repro.quant.base import QuantizerConfig
from repro.serve import Engine, Request, SamplingParams, ServeConfig, \
    percentile


def add_model_args(p: argparse.ArgumentParser) -> None:
    """Model/quantization flags shared by the batch driver here and the
    HTTP server (``repro.launch.server``)."""
    p.add_argument("--arch", default="phi3-mini-3.8b")
    p.add_argument("--method", default="srr",
                   choices=["srr", "qer", "w-only", "none"])
    p.add_argument("--rank", type=int, default=16)
    p.add_argument("--bits", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--quant-report", metavar="PATH", default=None,
                   help="write the per-layer quantization-quality report "
                        "(singular-spectrum head, preserved/exposed "
                        "energy, residual norms, container bytes) as "
                        "JSON to PATH, plus a Chrome trace of the "
                        "quantizer passes to PATH with a .trace.json "
                        "extension; render with python -m "
                        "tools.quant_report PATH")


def build_quantized_model(args, tag: str = "serve"):
    """Init the reduced model and run the paper pipeline (calibrate →
    quantize) per the shared model flags; returns ``(params, cfg)``.

    ``--quant-report PATH`` threads a :class:`repro.obs.QuantRecorder`
    through the pass and writes its schema-pinned JSON report (always —
    ``--method none`` yields an empty-layer report, so CI artifact steps
    never conditionally skip)."""
    cfg = get_config(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    recorder = None
    report_path = getattr(args, "quant_report", None)
    if report_path:
        from repro.obs import QuantRecorder
        recorder = QuantRecorder()
    if args.method != "none":
        dcfg = data_config_for(cfg, seq_len=32, global_batch=4,
                               seed=args.seed)
        stats = capture_calibration(
            params, cfg, dcfg, lambda c, pp, b, cc: lm_loss(c, pp, b, cc),
            n_batches=2)
        ptq = PTQConfig(method=args.method, scaling="qera-exact",
                        rank=args.rank,
                        quantizer=QuantizerConfig(kind="mxint",
                                                  bits=args.bits,
                                                  block_size=32),
                        seed=args.seed)
        t0 = time.perf_counter()
        params, reports = quantize_model_params(params, stats, ptq,
                                                recorder=recorder)
        print(f"[{tag}] {args.method} quantized {len(reports)} matrices "
              f"in {time.perf_counter() - t0:.1f}s")
    if recorder is not None:
        recorder.write(report_path)
        print(f"[{tag}] quant report -> {report_path}")
    return params, cfg


def main(argv=None):
    p = argparse.ArgumentParser()
    add_model_args(p)
    p.add_argument("--kv", default="f32",
                   choices=["f32", "bf16", "int8", "int4"])
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--scheduler", default="continuous",
                   choices=["continuous", "bucketed"])
    p.add_argument("--prefill-len", type=int, default=32,
                   help="compiled prompt pad length (continuous)")
    p.add_argument("--temperature", type=float, default=0.0,
                   help="per-request sampling temperature (0 = greedy); "
                        "applied through SamplingParams on every request")
    p.add_argument("--top-p", type=float, default=1.0,
                   help="nucleus sampling mass (1.0 = off)")
    p.add_argument("--top-k", type=int, default=0,
                   help="top-k logit filter (0 = off)")
    p.add_argument("--spec-k", type=int, default=0,
                   help="self-speculative decoding: draft up to K tokens "
                        "per round through the Q-only base (the low-rank "
                        "sliver skipped), verify them in one chunked Q+LR "
                        "dispatch, rewind any rejected tail (0 = off; "
                        "continuous scheduler, greedy lanes only — "
                        "sampled lanes fall back to per-token decode)")
    p.add_argument("--max-step-tokens", type=int, default=None,
                   help="token-budget step scheduler: per-step cap on "
                        "prefill dispatch width + decode lanes "
                        "(continuous scheduler only; bounds p95 ITL "
                        "under long-prompt bursts)")
    p.add_argument("--fused", default="auto", choices=["auto", "on", "off"],
                   help="fused serving path — Q+LR matmuls AND decode "
                        "attention over the slot cache: auto (Pallas "
                        "kernels on TPU, fused-XLA elsewhere), on (force "
                        "kernels; interpret off-TPU), off (dequant-then-"
                        "matmul / dequantize-the-cache baselines). With "
                        "--kv int8/int4 the flash-decode path reads the "
                        "codes directly (int4: packed two-per-byte, "
                        "unpacked in VMEM); the dense f32 cache never "
                        "materializes")
    p.add_argument("--paged", action="store_true",
                   help="paged KV cache: block-granular page pool + "
                        "per-slot block tables, radix-tree prefix reuse "
                        "(identical prompt prefixes map cached pages in "
                        "and skip their prefill), and chunked prefill "
                        "(prompts longer than --prefill-len stream in "
                        "prefill_len-sized chunks interleaved with decode)")
    p.add_argument("--page-size", type=int, default=16,
                   help="logical KV slots per page (even; = flash-decode "
                        "kernel block in the paged path)")
    p.add_argument("--n-pages", type=int, default=None,
                   help="physical page-pool size (paged only; default "
                        "sized so every slot can hold a full row plus "
                        "prefix-cache headroom)")
    p.add_argument("--compute-dtype", default="f32",
                   choices=["f32", "bf16"],
                   help="activation dtype for prefill/decode matmuls")
    p.add_argument("--sanitize", action="store_true",
                   help="audit serve-state invariants after every engine "
                        "step (page refcount conservation, block-table "
                        "validity, pos monotonicity, int4 nibble "
                        "alignment); token-identical but host-syncing — "
                        "a CI/debug mode, not a production default")
    p.add_argument("--no-prefix-cache", action="store_true",
                   help="disable radix-tree prefix reuse (paged only)")
    p.add_argument("--drift-monitor", action="store_true",
                   help="sampled shadow comparison of the serving logits "
                        "against a reference lowering of the same "
                        "quantized params (KL / top-1 agreement / "
                        "max-|Δlogit| histograms + NaN/inf guard "
                        "counters in the metrics snapshot); "
                        "token-identical, costs one extra decode "
                        "dispatch per sampled step")
    p.add_argument("--drift-sample-rate", type=float, default=0.05,
                   help="fraction of decode steps the drift monitor "
                        "shadow-compares (deterministic in the step "
                        "counter; 1.0 = every step)")
    p.add_argument("--drift-ref-fused", default="off",
                   choices=["auto", "on", "off"],
                   help="fused mode of the drift monitor's reference "
                        "lowering; the default 'off' is the dequant-"
                        "then-matmul ground-truth path")
    p.add_argument("--telemetry", action="store_true",
                   help="enable serve telemetry: request-lifecycle + "
                        "step-phase tracing, latency histograms, compile "
                        "tracking (implied by --trace/--profile-dir)")
    p.add_argument("--metrics-json", metavar="PATH", default=None,
                   help="write the final metrics snapshot as JSON to "
                        "PATH, plus the Prometheus text exposition to "
                        "PATH with a .prom extension")
    p.add_argument("--tokens-json", metavar="PATH", default=None,
                   help="write {uid: generated tokens} as JSON to PATH "
                        "(CI token-parity assertions, e.g. --sanitize "
                        "on/off must generate identical streams)")
    p.add_argument("--trace", metavar="PATH", default=None,
                   help="write the Chrome trace-event JSON (Perfetto-"
                        "loadable) to PATH, plus the JSONL event stream "
                        "to PATH with a .jsonl extension")
    p.add_argument("--trace-sync", action="store_true",
                   help="fence device dispatches (block_until_ready) so "
                        "traced phase timings show device time where it "
                        "was launched, not in the next host transfer")
    p.add_argument("--profile-dir", metavar="DIR", default=None,
                   help="capture a jax.profiler trace of the first "
                        "--profile-steps engine steps into DIR (view in "
                        "TensorBoard/Perfetto; works on CPU and TPU)")
    p.add_argument("--profile-steps", type=int, default=20,
                   help="engine steps to capture under --profile-dir")
    args = p.parse_args(argv)

    params, cfg = build_quantized_model(args)

    telemetry = bool(args.telemetry or args.trace or args.profile_dir)
    eng = Engine(params, cfg, ServeConfig(
        max_len=128, decode_batch=args.batch,
        max_new_tokens=args.new_tokens, kv_dtype=args.kv,
        scheduler=args.scheduler, prefill_len=args.prefill_len,
        temperature=args.temperature, seed=args.seed,
        max_step_tokens=args.max_step_tokens,
        speculative=args.spec_k > 0,
        spec_k=args.spec_k if args.spec_k > 0 else 4,
        fused=args.fused, paged=args.paged, page_size=args.page_size,
        n_pages=args.n_pages, compute_dtype=args.compute_dtype,
        sanitize=args.sanitize,
        drift_monitor=args.drift_monitor,
        drift_sample_rate=args.drift_sample_rate,
        drift_ref_fused=args.drift_ref_fused,
        prefix_cache=not args.no_prefix_cache,
        telemetry=telemetry, trace_sync=args.trace_sync,
        profile_dir=args.profile_dir, profile_steps=args.profile_steps))
    rng = np.random.default_rng(args.seed)
    sp = SamplingParams(temperature=args.temperature, top_p=args.top_p,
                        top_k=args.top_k)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=8 + 4 * (i % 3))
                    .astype(np.int32), params=sp)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    results = eng.generate(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s incl. compile, "
          f"scheduler={args.scheduler})")
    # latency_s is None-able (a max_new_tokens=0 request has no decode
    # span); the shared interpolating percentile replaces the old index
    # shortcut, which overshot p95 for small n and mis-picked even-n
    # medians
    lats = [r.latency_s for r in results if r.latency_s is not None]
    if args.scheduler == "continuous" and lats:
        p50 = percentile(lats, 0.50)
        p95 = percentile(lats, 0.95)
        st = eng.stats()
        print(f"[serve] latency p50 {p50 * 1e3:.0f}ms p95 {p95 * 1e3:.0f}ms "
              f"occupancy {st['occupancy']:.2f} "
              f"eos_retired {st['eos_retired']}")
        if args.spec_k > 0:
            print(f"[serve] speculative: {st['spec_rounds']} rounds, "
                  f"{st['spec_accepted_tokens']}/{st['spec_draft_tokens']} "
                  f"drafts accepted "
                  f"(rate {st['spec_acceptance_rate']:.3f})")
        if args.drift_monitor:
            print(f"[serve] drift: {st['drift_checks']} checks, "
                  f"top-1 agreement {st['drift_top1_agreement_rate']:.3f}, "
                  f"{st['drift_nonfinite']} non-finite, "
                  f"{st['guard_token_oob']} OOB tokens")
        if args.paged:
            print(f"[serve] paged: {st['prefill_chunks']} prefill chunks, "
                  f"{st['prefill_tokens_computed']}/"
                  f"{st['prompt_tokens_total']} prompt tokens computed "
                  f"(prefix hit rate {st['prefix_hit_rate']:.2f}), "
                  f"{st['evictions']} evictions, "
                  f"{st['pages_hot']}/{st['pages_total']} pages hot")
    for r in results[:3]:
        print(f"  req {r.uid} [{r.finish_reason}]: "
              f"{r.tokens[:10].tolist()}")
    if args.tokens_json:
        with open(args.tokens_json, "w") as f:
            json.dump({int(r.uid): [int(t) for t in r.tokens]
                       for r in results}, f, sort_keys=True)
            f.write("\n")
        print(f"[serve] tokens -> {args.tokens_json}")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(eng.stats(), f, indent=2, sort_keys=True)
            f.write("\n")
        prom = os.path.splitext(args.metrics_json)[0] + ".prom"
        with open(prom, "w") as f:
            f.write(eng.prometheus())
        print(f"[serve] metrics -> {args.metrics_json} (+ {prom})")
    if args.trace:
        jsonl = os.path.splitext(args.trace)[0] + ".jsonl"
        eng.write_trace(args.trace, jsonl_path=jsonl)
        print(f"[serve] trace -> {args.trace} (+ {jsonl})")
    if args.profile_dir:
        eng.tel.stop_profiler()
        print(f"[serve] jax.profiler trace -> {args.profile_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
