"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, three terms in seconds:

    compute    = HLO_FLOPs          / peak_FLOP/s          (per chip)
    memory     = HLO_bytes_accessed / HBM_bw               (per chip)
    collective = collective_bytes   / link_bw              (per chip)

``compiled.cost_analysis()`` is per-device (the SPMD-partitioned module),
so no further division by chip count is needed. Collective bytes come
from a textual parse of the post-partitioning HLO: the summed result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (all-reduce counted 2× — ring = reduce-scatter +
all-gather). Hardware model: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, Optional

# --- TPU v5e hardware model -----------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~ per-chip collective bw)
HBM_PER_CHIP = 16e9          # bytes

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# an HLO op line: "%name = <shape-or-tuple> opcode(...)"
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}/#\s]*?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes per collective kind from post-SPMD HLO text."""
    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_text, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_text)
        by_kind[kind] += b
        counts[kind] += 1
    # '-done' ops repeat the '-start' shape; halve pairs
    for kind in _COLLECTIVES:
        starts = len(re.findall(kind + r"-start\(", hlo_text))
        if starts:
            by_kind[kind] = by_kind[kind] * starts // max(counts[kind], 1)
            counts[kind] = starts
    total = sum(by_kind.values()) + by_kind["all-reduce"]  # AR counts 2×
    return {"bytes_by_kind": by_kind, "counts": counts,
            "effective_bytes": total}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    kind: str
    chips: int
    flops: float               # per-chip HLO FLOPs
    hbm_bytes: float           # per-chip bytes accessed
    coll_bytes: float          # per-chip effective collective bytes
    coll_detail: Dict[str, Any]
    model_flops: float         # 6·N·D (train) or 2·N_active·tokens (decode)
    peak_mem_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (chips × HLO_FLOPs) — remat/redundancy waste."""
        denom = self.chips * self.flops
        return self.model_flops / denom if denom else 0.0

    @property
    def roofline_frac(self) -> float:
        """Useful-FLOPs time over the bound step time (≈ achievable MFU)."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        if bound <= 0:
            return 0.0
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / bound

    def to_dict(self) -> Dict[str, Any]:
        return {
            **{f.name: getattr(self, f.name)
               for f in dataclasses.fields(self) if f.name != "coll_detail"},
            "coll_detail": self.coll_detail,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops_for(cfg, shape) -> float:
    """Useful-work FLOPs for one step of this cell."""
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention reads over the cache
    return 2.0 * n_active * shape.global_batch


def analyze(compiled, cfg, shape, mesh_name: str, chips: int,
            arch: str) -> Roofline:
    from repro.launch.hlo_cost import analyze_text
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    text = compiled.as_text()
    # while-aware accounting (XLA's cost_analysis counts loop bodies once;
    # see launch.hlo_cost) — the XLA numbers ride along for reference
    hc = analyze_text(text)
    flops = float(hc["flops"])
    hbm = float(hc["bytes"])
    coll = {"bytes_by_kind": hc["coll_by_kind"],
            "effective_bytes": hc["collective_bytes"],
            "xla_flops_once": float(cost.get("flops", 0.0)),
            "xla_bytes_once": float(cost.get("bytes accessed", 0.0))}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, kind=shape.kind,
        chips=chips, flops=flops, hbm_bytes=hbm,
        coll_bytes=float(coll["effective_bytes"]), coll_detail=coll,
        model_flops=model_flops_for(cfg, shape), peak_mem_bytes=mem)


def save(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=1)
