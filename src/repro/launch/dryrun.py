import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including jax and
# repro.*): jax locks the device count at first initialization, and the
# multi-pod dry-run needs 512 placeholder host devices to build the
# production mesh. Everything below is ordinary code.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds abstract inputs (ShapeDtypeStruct — nothing is allocated),
  2. jit's the step with explicit in/out shardings over the production
     mesh ((16,16) single-pod / (2,16,16) multi-pod),
  3. ``.lower().compile()`` — any sharding mismatch, OOM-at-compile or
     unsupported collective fails the cell (a bug in our system),
  4. prints ``memory_analysis()`` / ``cost_analysis()`` and writes the
     roofline record (repro.launch.roofline) to JSON.

Usage:
    python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, save
from repro.launch.specs import DryrunOptions, build_lowering


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: DryrunOptions, out_dir: str, verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if not ok:
        if verbose:
            print(f"[dryrun] SKIP {tag}: {why}")
        return {"cell": tag, "status": "skip", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.perf_counter()
    try:
        with mesh:
            lowered = build_lowering(cfg, shape, mesh, opts)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
    except Exception as e:
        traceback.print_exc()
        rec = {"cell": tag, "status": "fail", "error": f"{type(e).__name__}: {e}"}
        if out_dir:
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:
        pass
    if verbose:
        print(f"[dryrun] {tag}: lower {t1 - t0:.1f}s compile {t2 - t1:.1f}s")
        print(f"  memory_analysis: {mem}")
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        keep = {k: v for k, v in cost.items()
                if k in ("flops", "bytes accessed", "transcendentals")}
        print(f"  cost_analysis:   {keep}")

    r = analyze(compiled, cfg, shape, mesh_name, chips, arch)
    if verbose:
        print(f"  roofline: compute {r.t_compute * 1e3:.2f} ms | "
              f"memory {r.t_memory * 1e3:.2f} ms | "
              f"collective {r.t_collective * 1e3:.2f} ms "
              f"→ {r.bottleneck}-bound; useful-FLOPs "
              f"{100 * r.useful_flops_frac:.1f}%, roofline frac "
              f"{100 * r.roofline_frac:.1f}%")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        save(r, os.path.join(out_dir, tag + ".json"))
    rec = r.to_dict()
    rec.update(cell=tag, status="ok",
               lower_s=t1 - t0, compile_s=t2 - t1)
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="architecture id (or --all)")
    p.add_argument("--shape", default=None,
                   help="shape name (default: all applicable)")
    p.add_argument("--mesh", default="single",
                   choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true", help="every arch")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--remat", default="none", choices=["none", "full"])
    p.add_argument("--microbatch", type=int, default=0)
    p.add_argument("--kv", default="int8",
                   choices=["int8", "bf16", "int4"])
    p.add_argument("--rank", type=int, default=64)
    p.add_argument("--no-donate", action="store_true")
    p.add_argument("--qchunk", type=int, default=512)
    p.add_argument("--kvchunk", type=int, default=1024)
    args = p.parse_args(argv)

    opts = DryrunOptions(remat=args.remat, microbatch=args.microbatch,
                         kv_dtype=args.kv, rank=args.rank,
                         donate=not args.no_donate,
                         q_chunk=args.qchunk, kv_chunk=args.kvchunk)
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                results.append(run_cell(arch, shape, multi, opts, args.out))
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skip")
    n_fail = sum(1 for r in results if r["status"] == "fail")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} FAIL")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
