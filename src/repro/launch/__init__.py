"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only as
``python -m repro.launch.dryrun``. This package init deliberately does
not re-export it.
"""
from repro.launch.mesh import make_host_mesh, make_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_mesh", "make_production_mesh"]
