"""Mesh construction. Functions only — importing this module never
touches jax device state (device count locks on first jax init)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax ≥ 0.5 distinguishes Auto/Explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: Auto is the only behaviour
    AxisType = None


def _mk(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The target deployment mesh.

      single-pod : (data=16, model=16)         — 256 chips (one v5e pod)
      multi-pod  : (pod=2, data=16, model=16)  — 512 chips over DCN

    'pod' is pure data parallelism (gradient all-reduce over DCN),
    'data' is FSDP, 'model' is tensor/expert parallelism (ICI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    return _mk(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / laptop runs)."""
    n = jax.device_count()
    model = max(1, min(model, n))
    data = n // model
    return make_mesh((data, model), ("data", "model"))
