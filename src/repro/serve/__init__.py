"""Serving: continuous-batching prefill/decode engine over Q + LR models."""
from repro.serve.engine import Engine, Request, Result, ServeConfig
from repro.serve.http import (EngineServer, encode_text, render_chat,
                              serve_http)
from repro.serve.pages import PagedKVCache, PagePool, set_block_table_row
from repro.serve.prefix import RadixPrefixCache
from repro.serve.sampling import SamplingParams, lane_seed, sample_tokens
from repro.serve.sanitizer import Sanitizer, SanitizerError
from repro.serve.scheduler import (ContinuousScheduler, SchedulerStats,
                                   StepBudget)
from repro.serve.slots import SlotKVCache, SlotState, SlotTable, write_slot
from repro.serve.telemetry import (NULL_TELEMETRY, MetricsRegistry,
                                   NullTelemetry, Telemetry, Tracer,
                                   latency_summary, percentile)

__all__ = [
    "ContinuousScheduler", "Engine", "EngineServer", "MetricsRegistry",
    "NULL_TELEMETRY", "NullTelemetry", "PagePool", "PagedKVCache",
    "RadixPrefixCache", "Request", "Result", "SamplingParams",
    "Sanitizer", "SanitizerError",
    "SchedulerStats", "ServeConfig", "SlotKVCache", "SlotState",
    "SlotTable", "StepBudget", "Telemetry", "Tracer", "encode_text",
    "lane_seed", "latency_summary", "percentile", "render_chat",
    "sample_tokens", "serve_http", "set_block_table_row", "write_slot",
]
