"""Serving: continuous-batching prefill/decode engine over Q + LR models."""
from repro.serve.engine import Engine, Request, Result, ServeConfig
from repro.serve.pages import PagedKVCache, PagePool, set_block_table_row
from repro.serve.prefix import RadixPrefixCache
from repro.serve.scheduler import ContinuousScheduler, SchedulerStats
from repro.serve.slots import SlotKVCache, SlotState, SlotTable, write_slot
from repro.serve.telemetry import (NULL_TELEMETRY, MetricsRegistry,
                                   NullTelemetry, Telemetry, Tracer,
                                   latency_summary, percentile)

__all__ = [
    "ContinuousScheduler", "Engine", "MetricsRegistry", "NULL_TELEMETRY",
    "NullTelemetry", "PagePool", "PagedKVCache", "RadixPrefixCache",
    "Request", "Result", "SchedulerStats", "ServeConfig", "SlotKVCache",
    "SlotState", "SlotTable", "Telemetry", "Tracer", "latency_summary",
    "percentile", "set_block_table_row", "write_slot",
]
