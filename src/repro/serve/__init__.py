"""Serving: continuous-batching prefill/decode engine over Q + LR models."""
from repro.serve.engine import Engine, Request, Result, ServeConfig
from repro.serve.scheduler import ContinuousScheduler, SchedulerStats
from repro.serve.slots import SlotKVCache, SlotState, SlotTable, write_slot

__all__ = [
    "ContinuousScheduler", "Engine", "Request", "Result", "SchedulerStats",
    "ServeConfig", "SlotKVCache", "SlotState", "SlotTable", "write_slot",
]
