"""Serving: batched prefill/decode engine over quantized (Q + LR) models."""
from repro.serve.engine import Engine, Request, Result, ServeConfig

__all__ = ["Engine", "Request", "Result", "ServeConfig"]
