"""Automatic prefix caching: a radix tree over token-block hashes.

Production serving traffic is dominated by shared prefixes — the same
system prompt in front of every user turn, few-shot preambles, agent
scaffolding. With the paged KV cache (``serve.pages``) a prefix that was
prefilled once is just a run of physical pages, so a new request whose
prompt starts with the same tokens can *map those pages into its block
table* (refcount bump) and skip the prefill compute for them entirely.

The index is a radix tree at **block granularity**: each edge consumes
exactly ``page_size`` tokens (hashed to bytes for the child key) and
each node owns one physical page. Only *full* prompt blocks enter the
tree — a partial tail block also holds the request's decode tokens, so
it is never shareable — and matching is capped by the caller so at
least one prompt token is always recomputed (the engine needs the
last-token logits to sample the first output token).

Invariants (property-tested in ``tests/test_paged_pool.py``):

  * a node's page outlives the node: pages enter via ``insert`` (owner
    still holds a ref), go *cold* in the pool when the owner retires,
    are revived by ``match`` (incref), and leave the tree only through
    pool eviction (LRU) or ``reset``;
  * a matched path is ref'd root-to-leaf, so a hot node's ancestors are
    hot — eviction of a cold node can therefore drop the whole subtree
    (descendants are cold too) without stranding a live request;
  * ``match`` never returns a page the pool could evict mid-request:
    the incref happens inside the match walk.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.serve.pages import PagePool


class _Node:
    __slots__ = ("children", "parent", "key", "page")

    def __init__(self, parent: Optional["_Node"], key: Optional[bytes],
                 page: Optional[int]):
        self.children: Dict[bytes, _Node] = {}
        self.parent = parent
        self.key = key
        self.page = page


def _block_key(tokens: np.ndarray) -> bytes:
    return np.ascontiguousarray(tokens, np.int32).tobytes()


class RadixPrefixCache:
    """Block-granular prefix index over a :class:`PagePool`."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self.root = _Node(None, None, None)
        self._by_page: Dict[int, _Node] = {}
        pool.evict_hook = self._on_evict
        # counters (engine surfaces these via stats())
        self.queries = 0
        self.hit_blocks = 0
        self.miss_blocks = 0
        self.inserted_blocks = 0

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return len(self._by_page)

    def match(self, tokens: np.ndarray, max_blocks: int) -> List[int]:
        """Longest cached block-prefix of ``tokens``, at most
        ``max_blocks`` blocks. Returns the physical pages root-to-leaf,
        **already incref'd** — the caller owns one reference per page
        and releases them all at retirement."""
        self.queries += 1
        ps = self.page_size
        node = self.root
        pages: List[int] = []
        n_full = min(max_blocks, len(tokens) // ps)
        for i in range(n_full):
            child = node.children.get(_block_key(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            pages.append(child.page)
            node = child
        self.pool.incref(pages)
        self.hit_blocks += len(pages)
        self.miss_blocks += n_full - len(pages)
        return pages

    def release_match(self, pages: List[int], n_queried: int) -> None:
        """Undo a :meth:`match` whose admission was deferred (pool
        pressure): drop the references *and* the query counters, so a
        request retried N times doesn't inflate the hit stats N-fold.
        ``n_queried`` is the full-block count the match walked (the
        engine's ``min(max_blocks, len(prompt) // page_size)``)."""
        self.pool.decref(pages)
        self.queries -= 1
        self.hit_blocks -= len(pages)
        self.miss_blocks -= n_queried - len(pages)

    def insert(self, tokens: np.ndarray, pages: List[int]) -> int:
        """Register a prefilled prompt's full blocks: ``pages[i]`` holds
        the KV of tokens ``[i*ps, (i+1)*ps)``. Blocks already in the
        tree keep their incumbent page (the duplicate page stays private
        to its request and frees on retirement); new blocks take tree
        ownership of the page (``pool.mark_cached``). Returns the number
        of newly registered blocks."""
        ps = self.page_size
        node = self.root
        added = 0
        for i, page in enumerate(pages):
            key = _block_key(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(node, key, page)
                node.children[key] = child
                self._by_page[page] = child
                self.pool.mark_cached(page)
                added += 1
            node = child
        self.inserted_blocks += added
        return added

    # ------------------------------------------------------------------
    def _on_evict(self, page: int) -> None:
        """Pool reclaimed a cold page: drop its node and the whole
        subtree (all cold — see module invariants), releasing the
        subtree's pages back to the pool."""
        node = self._by_page.get(page)
        if node is None:
            return
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        stack = [node]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            n.children.clear()
            if n.page is not None:
                self._by_page.pop(n.page, None)
                self.pool.release_cached(n.page)

    def reset(self) -> None:
        """Drop every cached prefix (pages return to the free list as
        their refcounts allow)."""
        for page in list(self._by_page):
            node = self._by_page.pop(page)
            node.children.clear()
            self.pool.release_cached(page)
        self.root = _Node(None, None, None)

    def stats(self) -> Dict[str, int]:
        return {"prefix_queries": self.queries,
                "prefix_hit_blocks": self.hit_blocks,
                "prefix_miss_blocks": self.miss_blocks,
                "prefix_cached_blocks": self.n_blocks,
                "prefix_inserted_blocks": self.inserted_blocks}

    def publish(self, reg) -> None:
        """Publish the prefix-cache series into a telemetry registry
        (names match the legacy ``stats()`` keys exactly)."""
        reg.counter("prefix_queries", "prefix-cache match walks"
                    ).set(self.queries)
        reg.counter("prefix_hit_blocks", "blocks served from the tree"
                    ).set(self.hit_blocks)
        reg.counter("prefix_miss_blocks", "full blocks walked but absent"
                    ).set(self.miss_blocks)
        reg.gauge("prefix_cached_blocks", "blocks currently in the tree"
                  ).set(self.n_blocks)
        reg.counter("prefix_inserted_blocks", "blocks registered"
                    ).set(self.inserted_blocks)

    def reset_stats(self) -> None:
        self.queries = self.hit_blocks = 0
        self.miss_blocks = self.inserted_blocks = 0
