"""Serve-side telemetry: metrics registry, lifecycle tracing, JAX hooks.

One dependency-free observability layer for the serving stack, replacing
the ad-hoc ``time.perf_counter()`` calls and per-scheduler ``stats()``
dicts that grew with PRs 1–5. Three pieces:

  * **Metrics registry** (:class:`MetricsRegistry`): named counters,
    gauges, and fixed log-spaced-bucket histograms. ``snapshot()``
    returns one flat JSON-serializable dict (legacy ``Engine.stats()``
    keys preserved verbatim — the engine, scheduler, page pool, and
    prefix cache all *publish* into the registry at collection time, so
    the snapshot is uniform across bucketed/continuous/paged modes);
    ``prometheus()`` renders the standard text exposition format.
  * **Request-lifecycle + step tracing** (:class:`Tracer`,
    :class:`Telemetry`): every request emits spans (queued → admitted →
    prefill-chunk[i] → first-token → decode → retired) on its own
    Chrome-trace thread lane, and every engine ``step()`` emits a phase
    breakdown (admission, chunk prefill, decode dispatch, host
    transfer). Exported as Chrome trace-event JSON (loadable in
    Perfetto / ``chrome://tracing``) and as a JSONL event stream for
    programmatic analysis. An opt-in ``sync`` fence
    (``block_until_ready`` after device dispatch) attributes device
    time to the phase that launched it instead of hiding it in the
    next host transfer.
  * **JAX-level hooks**: per-entry-point compile tracking — distinct
    dispatched shapes plus real backend-compile seconds via
    ``jax.monitoring`` duration events (the shape-churn recompile
    detector chunked prefill was built to avoid), and
    ``jax.profiler.TraceAnnotation`` labels around prefill/decode with
    optional ``jax.profiler`` trace capture for the first N engine
    steps (``profile_dir``).

Telemetry is near-zero-cost when disabled: the engine holds a
:data:`NULL_TELEMETRY` recorder whose methods are no-ops and whose
context managers are a shared null object — one attribute dispatch per
call site, no timestamps taken, no events stored.

Also here: the shared **interpolating percentile** helper (numpy
"linear" method). The previous hand-rolled index math
(``lats[int(0.95 * len(lats))]``) overshoots p95 for small n and
``lats[n // 2]`` is not the median for even n; every consumer
(``launch/serve.py``, the serve benchmarks) now goes through
:func:`percentile`.
"""
from __future__ import annotations

import bisect
import contextlib
import json
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


# ==========================================================================
# Percentiles (shared helper — the single implementation in the repo)
# ==========================================================================
def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolating percentile of ``values`` at quantile ``q``
    in [0, 1] — numpy's default ("linear") method, so
    ``percentile(v, q) == np.percentile(v, 100 * q)`` exactly.

    Unlike the index-truncation shortcut ``v[int(q * len(v))]`` this
    neither overshoots small-n upper percentiles (p95 of 10 samples is
    between the 9th and 10th order statistic, not the maximum) nor
    mis-picks the even-n median (mean of the two middle samples)."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    vals = sorted(float(v) for v in values)
    if not vals:
        raise ValueError("percentile of empty sequence")
    pos = q * (len(vals) - 1)
    lo = math.floor(pos)
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


def latency_summary(values: Sequence[float], scale: float = 1.0
                    ) -> Dict[str, float]:
    """p50/p95/p99 + mean/max of ``values`` (× ``scale``, e.g. 1e3 for
    ms) — the common TTFT/ITL reporting shape. Empty input → zeros."""
    vals = [float(v) for v in values]
    if not vals:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    return {"p50": percentile(vals, 0.50) * scale,
            "p95": percentile(vals, 0.95) * scale,
            "p99": percentile(vals, 0.99) * scale,
            "mean": sum(vals) / len(vals) * scale,
            "max": max(vals) * scale}


# ==========================================================================
# Metrics registry
# ==========================================================================
def log_buckets(lo: float = 1e-5, hi: float = 100.0,
                per_decade: int = 4) -> List[float]:
    """Geometric bucket upper bounds: ``per_decade`` boundaries per
    decade from ``lo`` to ``hi`` inclusive. The default (1e-5 s … 100 s)
    spans microsecond host phases to multi-second cold compiles."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    n = int(round(math.log10(hi / lo) * per_decade))
    bounds = [lo * 10 ** (i / per_decade) for i in range(n + 1)]
    # snap the last boundary onto hi exactly (float round-off)
    bounds[-1] = hi
    return bounds


class Counter:
    """Monotonic counter. ``inc()`` for event-driven use; ``set()`` for
    publish-at-collection-time use (absolute value from an existing
    tally — how the scheduler/pool/prefix legacy counters flow in)."""
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return int(self.value) if self.value == int(self.value) \
            else self.value


class Gauge:
    """Point-in-time value (occupancy, pool residency, hit rate)."""
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self):
        return int(self.value) if self.value == int(self.value) \
            else self.value


class Histogram:
    """Fixed-bucket histogram over log-spaced boundaries.

    ``counts[i]`` tallies observations ``<= bounds[i]``; the final
    slot is the +Inf overflow. Quantiles are estimated by geometric
    interpolation within the containing bucket (log-spaced buckets →
    log-linear interpolation), clamped to the observed min/max so
    single-bucket distributions don't report a bucket edge."""
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help
        self.bounds = list(buckets) if buckets is not None else log_buckets()
        if sorted(self.bounds) != self.bounds or len(set(self.bounds)) \
                != len(self.bounds):
            raise ValueError(f"{name}: bucket bounds must be strictly "
                             f"increasing")
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-interpolated quantile estimate (None when empty)."""
        if self.count == 0:
            return None
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else min(self.min,
                                                          self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if lo <= 0 or hi <= lo:
                    return hi
                frac = (target - cum) / c
                return lo * (hi / lo) ** frac
            cum += c
        return self.max

    def reset(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def snapshot(self) -> Dict[str, Optional[float]]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "p50": None, "p95": None, "p99": None}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


def _fmt(v: float) -> str:
    return f"{v:.9g}"


class MetricsRegistry:
    """Name → metric map with typed get-or-create accessors.

    ``snapshot()`` flattens to one JSON dict (histograms nest their
    summary under their name); ``prometheus()`` renders the text
    exposition format. Re-requesting a name with a different metric
    type is a programming error and raises."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def snapshot(self) -> Dict[str, Any]:
        return {name: m.snapshot() for name, m in self._metrics.items()}

    def prometheus(self) -> str:
        """Prometheus text exposition (histograms in the standard
        cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count`` form)."""
        lines: List[str] = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for bound, c in zip(m.bounds, m.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{_fmt(bound)}"}} {cum}')
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{name}_sum {_fmt(m.sum)}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"

    def reset_histograms(self) -> None:
        """Clear histogram samples (counters/gauges are publish-time
        absolutes and need no reset) — a fresh ``generate()`` run must
        not inherit the warmup dummy's latencies."""
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                m.reset()


# ==========================================================================
# Chrome trace-event tracer
# ==========================================================================
PID_REQUESTS = 1      # request-lifecycle lanes (tid = request uid)
PID_ENGINE = 2        # engine step/phase timeline (tid 0)


class Tracer:
    """Chrome trace-event buffer (JSON array format).

    Events carry microsecond timestamps relative to the tracer's birth
    (one shared ``time.perf_counter`` origin, so engine-side
    ``perf_counter`` readings convert via :meth:`us`). ``chrome()``
    wraps the buffer for Perfetto / ``chrome://tracing``;
    ``write_jsonl`` streams the same records one-per-line for
    programmatic analysis."""

    def __init__(self):
        self.t0 = time.perf_counter()
        self.events: List[Dict[str, Any]] = []
        self._metadata()

    def _metadata(self) -> None:
        for pid, name in ((PID_REQUESTS, "requests"), (PID_ENGINE, "engine")):
            self.events.append({"ph": "M", "pid": pid, "tid": 0, "ts": 0,
                                "name": "process_name",
                                "args": {"name": name}})

    # ------------------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self.t0) * 1e6

    def us(self, t_perf: float) -> float:
        """Convert an absolute ``time.perf_counter()`` reading."""
        return (t_perf - self.t0) * 1e6

    def complete(self, name: str, ts_us: float, dur_us: float, pid: int,
                 tid: int, args: Optional[Dict] = None) -> None:
        ev = {"ph": "X", "name": name, "ts": round(ts_us, 3),
              "dur": round(max(dur_us, 0.0), 3), "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, ts_us: float, pid: int, tid: int,
                args: Optional[Dict] = None) -> None:
        ev = {"ph": "i", "name": name, "ts": round(ts_us, 3), "pid": pid,
              "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ------------------------------------------------------------------
    def chrome(self) -> Dict[str, Any]:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome(), f)
            f.write("\n")
        return path

    def write_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return path

    def reset(self) -> None:
        """Drop buffered events; the time origin is kept so timestamps
        stay monotonic across engine runs."""
        self.events = []
        self._metadata()


# ==========================================================================
# JAX compile-duration listener (module-level: jax.monitoring listeners
# cannot be unregistered individually, so one forwarding hook is
# installed lazily and routes to whichever Telemetry is mid-dispatch)
# ==========================================================================
_listener_state = {"installed": False}
_current_telemetry: Optional["Telemetry"] = None


def _install_compile_listener() -> None:
    if _listener_state["installed"]:
        return
    _listener_state["installed"] = True     # even on failure: don't retry
    try:
        from jax import monitoring

        def _on_duration(event: str, duration: float, **kw) -> None:
            tel = _current_telemetry
            if tel is not None and "backend_compile" in event:
                tel._note_compile_seconds(duration)

        monitoring.register_event_duration_secs_listener(_on_duration)
    except Exception:
        pass        # older/newer jax without the hook: first-call timing
        # (tracked per entry regardless) remains the fallback signal


# ==========================================================================
# Telemetry facade
# ==========================================================================
STEP_PHASES = ("budget", "admission", "prefill", "decode", "verify",
               "transfer")


class Telemetry:
    """Live recorder the engine drives; owns the tracer and publishes
    request/step histograms plus compile stats into the (shared)
    registry. Construct with ``sync=True`` to fence device dispatches
    (``block_until_ready``) so device time lands in the phase that
    launched it. ``profile_dir`` arms ``jax.profiler`` capture for the
    first ``profile_steps`` engine steps."""

    enabled = True

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 sync: bool = False, profile_dir: Optional[str] = None,
                 profile_steps: int = 20):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = Tracer()
        self.sync = sync
        self.profile_dir = profile_dir
        self.profile_steps = profile_steps
        self._profiling = False
        self._profile_done = False
        self._step_idx = 0
        self._step_t0: Optional[float] = None
        self._requests: Dict[int, Dict[str, float]] = {}
        # entry point → dispatch/compile accounting
        self.compiles: Dict[str, Dict[str, Any]] = {}
        self._entry_name: Optional[str] = None
        _install_compile_listener()
        reg = self.registry
        self._h_step = reg.histogram("step_seconds", "engine step wall time")
        self._h_phase = {p: reg.histogram(f"step_{p}_seconds",
                                          f"step {p} phase wall time")
                         for p in STEP_PHASES}
        self._h_ttft = reg.histogram("ttft_seconds",
                                     "submit to first token")
        self._h_latency = reg.histogram("request_latency_seconds",
                                        "submit to retirement")
        self._h_itl = reg.histogram("itl_seconds",
                                    "inter-token latency (decode span / "
                                    "(tokens - 1))")
        self._h_chunk = reg.histogram("prefill_chunk_seconds",
                                      "one chunked-prefill dispatch")

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def request_queued(self, uid: int) -> None:
        self._requests[uid] = {"queued": self.tracer.now_us()}

    def request_admitted(self, uid: int) -> None:
        now = self.tracer.now_us()
        r = self._requests.setdefault(uid, {})
        q = r.get("queued", now)
        r["admitted"] = now
        self.tracer.complete("queued", q, now - q, PID_REQUESTS, uid)

    def request_prefill(self, uid: int, index: int, t0: float,
                        t1: float) -> None:
        """One prefill dispatch for ``uid`` (chunk ``index``; unpaged
        prefill-on-admit is chunk 0). ``t0``/``t1`` are perf_counter."""
        self._h_chunk.observe(t1 - t0)
        self.tracer.complete(f"prefill_chunk[{index}]", self.tracer.us(t0),
                             (t1 - t0) * 1e6, PID_REQUESTS, uid)

    def request_first_token(self, uid: int) -> None:
        now = self.tracer.now_us()
        r = self._requests.setdefault(uid, {})
        a = r.get("admitted", now)
        r["first_token"] = now
        self.tracer.complete("prefill", a, now - a, PID_REQUESTS, uid)
        self.tracer.instant("first_token", now, PID_REQUESTS, uid)

    def request_retired(self, uid: int, n_tokens: int,
                        ttft_s: Optional[float],
                        latency_s: Optional[float],
                        decode_s: Optional[float]) -> None:
        now = self.tracer.now_us()
        r = self._requests.pop(uid, {})
        ft = r.get("first_token")
        if ft is not None:
            self.tracer.complete("decode", ft, now - ft, PID_REQUESTS, uid,
                                 args={"tokens": n_tokens})
        elif "admitted" in r:
            # retired without ever sampling (max_new_tokens=0): close the
            # prefill span so the lane still covers queued → retired
            self.tracer.complete("prefill", r["admitted"],
                                 now - r["admitted"], PID_REQUESTS, uid)
        self.tracer.instant("retired", now, PID_REQUESTS, uid,
                            args={"tokens": n_tokens})
        if ttft_s is not None:
            self._h_ttft.observe(ttft_s)
        if latency_s is not None:
            self._h_latency.observe(latency_s)
        if decode_s is not None and n_tokens > 1:
            self._h_itl.observe(decode_s / (n_tokens - 1))

    # ------------------------------------------------------------------
    # Engine step phases
    # ------------------------------------------------------------------
    def step_begin(self) -> None:
        self._step_t0 = time.perf_counter()
        if self.profile_dir and not self._profile_done and not self._profiling:
            try:
                import jax
                jax.profiler.start_trace(self.profile_dir)
                self._profiling = True
            except Exception:
                self._profile_done = True       # don't retry every step

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self._h_phase[name].observe(t1 - t0)
            self.tracer.complete(name, self.tracer.us(t0), (t1 - t0) * 1e6,
                                 PID_ENGINE, 0)

    def step_end(self, n_decoding: int) -> None:
        t0, self._step_t0 = self._step_t0, None
        if t0 is not None:
            t1 = time.perf_counter()
            self._h_step.observe(t1 - t0)
            self.tracer.complete("step", self.tracer.us(t0),
                                 (t1 - t0) * 1e6, PID_ENGINE, 0,
                                 args={"step": self._step_idx,
                                       "decoding": n_decoding})
        self._step_idx += 1
        if self._profiling and self._step_idx >= self.profile_steps:
            self.stop_profiler()

    # ------------------------------------------------------------------
    # JAX hooks: compile tracking + profiler annotations
    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def entry(self, name: str, shape_key: Tuple):
        """Wrap one jitted-entry-point dispatch. Tracks distinct
        ``shape_key`` signatures per entry (a growing set = the shape
        churn chunked prefill exists to avoid), attributes
        ``jax.monitoring`` backend-compile seconds to this entry while
        the dispatch is live, times first-seen-signature calls as the
        fallback compile signal, and labels the region for the JAX
        profiler timeline."""
        global _current_telemetry
        info = self.compiles.setdefault(
            name, {"shapes": set(), "compiles": 0, "calls": 0,
                   "compile_seconds": 0.0, "first_call_seconds": 0.0})
        info["calls"] += 1
        first = shape_key not in info["shapes"]
        prev, _current_telemetry = _current_telemetry, self
        self._entry_name = name
        t0 = time.perf_counter()
        try:
            import jax
            with jax.profiler.TraceAnnotation(f"serve/{name}"):
                yield
        finally:
            _current_telemetry = prev
            if first:
                dt = time.perf_counter() - t0
                info["shapes"].add(shape_key)
                info["compiles"] += 1
                info["first_call_seconds"] += dt
                self.tracer.instant(f"compile:{name}", self.tracer.now_us(),
                                    PID_ENGINE, 0,
                                    args={"shape": str(shape_key),
                                          "first_call_s": round(dt, 6)})

    def _note_compile_seconds(self, seconds: float) -> None:
        info = self.compiles.get(getattr(self, "_entry_name", None))
        if info is not None:
            info["compile_seconds"] += seconds

    def stop_profiler(self) -> None:
        if self._profiling:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass
            self._profiling = False
            self._profile_done = True

    # ------------------------------------------------------------------
    def publish(self) -> None:
        """Push compile accounting into the registry (histograms are
        registry-resident already)."""
        reg = self.registry
        for name, info in self.compiles.items():
            reg.gauge(f"compiled_shapes_{name}",
                      f"distinct dispatched shapes for {name}"
                      ).set(len(info["shapes"]))
            reg.counter(f"dispatches_{name}",
                        f"total {name} dispatches").set(info["calls"])
            reg.gauge(f"compile_seconds_{name}",
                      f"jax backend-compile seconds attributed to {name}"
                      ).set(round(info["compile_seconds"], 6))
            reg.gauge(f"first_call_seconds_{name}",
                      f"wall seconds of first-seen-shape {name} calls "
                      f"(compile fallback signal)"
                      ).set(round(info["first_call_seconds"], 6))

    def reset_run(self) -> None:
        """Start a fresh measured run: drop trace events, open request
        spans, and histogram samples. Compile accounting survives — it
        describes the engine session, not one run."""
        self.tracer.reset()
        self._requests.clear()
        self._step_idx = 0
        self._step_t0 = None
        self.registry.reset_histograms()

    def close(self) -> None:
        self.stop_profiler()


# ==========================================================================
# Disabled recorder: every engine call site dispatches through one of
# these no-ops — a single attribute lookup + call, no timestamps, no
# allocation. Shared singletons.
# ==========================================================================
class _NullContext:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullContext()


class NullTelemetry:
    """No-op recorder; ``Engine`` holds this when telemetry is off."""

    enabled = False
    sync = False
    registry = None
    tracer = None

    def request_queued(self, uid):
        pass

    def request_admitted(self, uid):
        pass

    def request_prefill(self, uid, index, t0, t1):
        pass

    def request_first_token(self, uid):
        pass

    def request_retired(self, uid, n_tokens, ttft_s, latency_s, decode_s):
        pass

    def step_begin(self):
        pass

    def phase(self, name):
        return _NULL_CTX

    def entry(self, name, shape_key):
        return _NULL_CTX

    def step_end(self, n_decoding):
        pass

    def publish(self):
        pass

    def reset_run(self):
        pass

    def stop_profiler(self):
        pass

    def close(self):
        pass


NULL_TELEMETRY = NullTelemetry()
