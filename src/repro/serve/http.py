"""OpenAI-compatible HTTP frontend over :class:`repro.serve.Engine`.

Dependency-free (stdlib ``http.server`` only): a ``ThreadingHTTPServer``
accepts connections, and a single background **pump thread** drives
``Engine.step()`` — handler threads never touch the device. The pump
fans generated tokens out to per-connection queues through the engine's
``on_token`` hook, so `/v1/completions` and `/v1/chat/completions` can
stream Server-Sent Events token-by-token with the exact latency the
continuous scheduler delivers.

Endpoints:

  * ``POST /v1/completions``       — prompt as a string (byte-level
    tokenizer below) or a raw token-id list; ``stream: true`` for SSE.
  * ``POST /v1/chat/completions``  — ``messages`` rendered through a
    deterministic chat template (stable rendering keeps the radix
    prefix cache hot across turns of the same conversation).
  * ``GET /v1/models`` / ``/health`` / ``/metrics`` (Prometheus text) /
    ``/metrics.json`` (the ``Engine.stats()`` snapshot).

Per-request sampling maps straight onto
:class:`~repro.serve.sampling.SamplingParams`: ``temperature``,
``top_p``, ``top_k``, ``seed``, ``stop_token_ids``, ``max_tokens``.
String ``stop`` sequences are rejected with a 400 — the repro tokenizer
is byte-level, so stop *token ids* are the faithful surface.

Client disconnect mid-stream calls ``Engine.abort(uid)``: the slot
frees and its pages decref on the next pump iteration, so an abandoned
long generation cannot pin pool pages or a decode lane.

The token text codec is the repro stand-in pair ``encode_text`` /
``detok`` (bytes mod vocab in, ``<id>`` pieces out) — deterministic,
reversible enough for tests, and trivially replaced by a real
tokenizer at integration time.
"""
from __future__ import annotations

import itertools
import json
import queue
import threading
import time
import uuid
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.engine import Engine, Request, Result
from repro.serve.sampling import SamplingParams


# ==========================================================================
# Token <-> text stand-in codec
# ==========================================================================
def encode_text(text: str, vocab: int) -> np.ndarray:
    """Byte-level stand-in tokenizer: UTF-8 bytes folded into the model
    vocab. Deterministic, so identical prompts hit the prefix cache."""
    data = text.encode("utf-8")
    if not data:
        data = b"\x00"
    return np.asarray([b % vocab for b in data], np.int32)


def detok(token: int) -> str:
    """Stand-in detokenizer piece for one generated id."""
    return f"<{int(token)}>"


def render_chat(messages: List[Dict[str, str]], vocab: int) -> np.ndarray:
    """Deterministic chat template: ``<|role|>content<|end|>`` per
    message plus the assistant cue. Stable token rendering across turns
    keeps shared conversation prefixes radix-cache hot."""
    parts = []
    for m in messages:
        role = m.get("role", "user")
        content = m.get("content") or ""
        if not isinstance(content, str):
            raise ValueError("message content must be a string")
        parts.append(f"<|{role}|>{content}<|end|>")
    parts.append("<|assistant|>")
    return encode_text("".join(parts), vocab)


# ==========================================================================
# Engine pump: one thread steps the engine, fans tokens to streams
# ==========================================================================

# The Engine methods that mutate engine/scheduler state (or publish into
# the shared metrics registry) and therefore may only be called while
# holding ``EngineServer.cv``. This registry is the thread-safety
# contract: the lock-discipline pass in tools/analysis proves every
# ``.engine.<name>`` call in this module for a name listed here happens
# under ``with self.cv:`` (or in ``__init__``, before the pump thread
# exists). Adding an engine call to a handler without the lock is a CI
# failure, not a code-review hope.
ENGINE_MUTATORS = frozenset({
    "submit", "abort", "step", "drain", "generate", "warmup",
    "stats", "prometheus", "write_trace",
})


class EngineServer:
    """Thread-safe bridge between HTTP handler threads and one Engine.

    All engine access happens under ``self.cv`` (handlers submit/abort,
    the pump steps); generated tokens and final results flow to the
    owning connection through a per-uid ``queue.Queue`` of
    ``("token", id) | ("done", Result) | ("error", message)`` events.
    """

    def __init__(self, engine: Engine, model_id: str = "repro-qlr"):
        if engine.sc.scheduler != "continuous":
            raise ValueError("EngineServer needs ServeConfig("
                             "scheduler='continuous')")
        self.engine = engine
        self.model_id = model_id
        self.cv = threading.Condition()
        self._streams: Dict[int, "queue.Queue"] = {}
        self._uids = itertools.count(1)
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self.t_start = time.time()
        engine.on_token = self._on_token

    # -- pump side (holds cv) ------------------------------------------
    def _on_token(self, uid: int, token: int, info=None) -> None:
        """``info`` is the engine's logprob record (or None) — it rides
        the token event so streaming and collected responses can both
        render OpenAI ``logprobs`` without a second engine query."""
        q = self._streams.get(uid)
        if q is not None:
            q.put(("token", (token, info)))

    def _pump(self) -> None:
        eng = self.engine
        while True:
            with self.cv:
                while not self._stop and not eng.sched.has_work:
                    self.cv.wait()
                if self._stop:
                    return
                try:
                    finished = eng.step()
                except Exception as e:          # noqa: BLE001 — any step
                    # failure must fail every open stream, not hang them
                    for q in self._streams.values():
                        q.put(("error", f"{type(e).__name__}: {e}"))
                    self._streams.clear()
                    continue
                for res in finished:
                    q = self._streams.pop(res.uid, None)
                    if q is not None:
                        q.put(("done", res))

    def start(self) -> "EngineServer":
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name="engine-pump")
        self._thread.start()
        return self

    def close(self) -> None:
        with self.cv:
            self._stop = True
            self.cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5)

    # -- handler side --------------------------------------------------
    def submit(self, prompt: np.ndarray,
               params: SamplingParams) -> Tuple[int, "queue.Queue"]:
        """Register a stream and queue the request; raises ValueError
        straight through (handler turns it into a 400)."""
        with self.cv:
            uid = next(self._uids)
            q: "queue.Queue" = queue.Queue()
            self._streams[uid] = q
            try:
                self.engine.submit(Request(uid=uid, prompt=prompt,
                                           params=params))
            except Exception:
                del self._streams[uid]
                raise
            self.cv.notify_all()
            return uid, q

    def abort(self, uid: int) -> None:
        with self.cv:
            self._streams.pop(uid, None)
            self.engine.abort(uid)

    def stats(self) -> Dict:
        with self.cv:
            return self.engine.stats()

    def prometheus(self) -> str:
        with self.cv:
            return self.engine.prometheus()


# ==========================================================================
# HTTP layer
# ==========================================================================
def _parse_params(body: Dict, chat: bool) -> SamplingParams:
    if body.get("stop") not in (None, [], ()):
        raise ValueError("string 'stop' sequences are not supported by "
                         "the byte-level repro tokenizer; pass "
                         "'stop_token_ids' (a list of token ids) instead")
    stop_ids = body.get("stop_token_ids") or []
    if not isinstance(stop_ids, list) \
            or not all(isinstance(t, int) for t in stop_ids):
        raise ValueError("stop_token_ids must be a list of token ids")
    mnt = body.get("max_tokens")
    if chat and mnt is None:
        mnt = body.get("max_completion_tokens")
    temp = body.get("temperature")
    # OpenAI surfaces: completions takes `logprobs: <int>`; chat takes
    # `logprobs: true` + `top_logprobs: <int>`. Both land on
    # SamplingParams.logprobs (validated 0..5 at submit)
    lp = body.get("logprobs")
    if chat:
        n_lp = int(body.get("top_logprobs", 0)) if lp else None
    else:
        n_lp = None if lp is None else int(lp)
    return SamplingParams(
        temperature=None if temp is None else float(temp),
        top_p=float(body.get("top_p", 1.0)),
        top_k=int(body.get("top_k", 0)),
        seed=body.get("seed"),
        stop=tuple(stop_ids),
        max_new_tokens=None if mnt is None else int(mnt),
        logprobs=n_lp)


class OpenAIHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-serve"
    srv: EngineServer = None          # installed by serve_http()

    def log_message(self, fmt, *args):   # noqa: A003 — quiet by default
        pass

    # -- plumbing ------------------------------------------------------
    def _json(self, code: int, obj: Dict) -> None:
        data = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _text(self, code: int, text: str,
              ctype: str = "text/plain; charset=utf-8") -> None:
        data = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _error(self, code: int, message: str,
               etype: str = "invalid_request_error") -> None:
        self._json(code, {"error": {"message": message, "type": etype,
                                    "code": code}})

    def _begin_sse(self) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()

    def _chunk(self, data: bytes) -> None:
        """One HTTP/1.1 chunked-transfer frame."""
        self.wfile.write(f"{len(data):X}\r\n".encode() + data + b"\r\n")
        self.wfile.flush()

    def _sse(self, obj) -> None:
        payload = obj if isinstance(obj, str) else json.dumps(obj)
        self._chunk(f"data: {payload}\n\n".encode())

    def _end_chunks(self) -> None:
        self.wfile.write(b"0\r\n\r\n")
        self.wfile.flush()

    # -- GET -----------------------------------------------------------
    def do_GET(self):   # noqa: N802 — http.server API
        srv = self.srv
        if self.path == "/health":
            self._json(200, {"status": "ok",
                             "uptime_s": round(time.time() - srv.t_start, 3)})
        elif self.path == "/metrics":
            self._text(200, srv.prometheus(),
                       "text/plain; version=0.0.4; charset=utf-8")
        elif self.path == "/metrics.json":
            self._json(200, srv.stats())
        elif self.path == "/v1/models":
            self._json(200, {"object": "list", "data": [
                {"id": srv.model_id, "object": "model",
                 "created": int(srv.t_start), "owned_by": "repro"}]})
        else:
            self._error(404, f"unknown route {self.path}", "not_found_error")

    # -- POST ----------------------------------------------------------
    def do_POST(self):  # noqa: N802 — http.server API
        if self.path == "/v1/completions":
            self._completions(chat=False)
        elif self.path == "/v1/chat/completions":
            self._completions(chat=True)
        else:
            self._error(404, f"unknown route {self.path}", "not_found_error")

    def _read_body(self) -> Optional[Dict]:
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            if not isinstance(body, dict):
                raise ValueError("body must be a JSON object")
            return body
        except (ValueError, json.JSONDecodeError) as e:
            self._error(400, f"invalid JSON body: {e}")
            return None

    def _completions(self, chat: bool) -> None:
        srv = self.srv
        body = self._read_body()
        if body is None:
            return
        model = body.get("model", srv.model_id)
        if model != srv.model_id:
            self._error(404, f"model {model!r} not found (serving "
                        f"{srv.model_id!r})", "not_found_error")
            return
        vocab = srv.engine.cfg.vocab
        try:
            if chat:
                messages = body.get("messages")
                if not isinstance(messages, list) or not messages:
                    raise ValueError("'messages' must be a non-empty list")
                prompt = render_chat(messages, vocab)
            else:
                raw = body.get("prompt")
                if isinstance(raw, str):
                    prompt = encode_text(raw, vocab)
                elif isinstance(raw, list) \
                        and all(isinstance(t, int) for t in raw):
                    prompt = np.asarray(raw, np.int32)
                else:
                    raise ValueError("'prompt' must be a string or a "
                                     "list of token ids")
            params = _parse_params(body, chat)
            uid, q = srv.submit(prompt, params)
        except ValueError as e:
            self._error(400, str(e))
            return

        rid = (("chatcmpl-" if chat else "cmpl-") + uuid.uuid4().hex[:24])
        created = int(time.time())
        obj = "chat.completion.chunk" if chat else "text_completion"
        if body.get("stream"):
            self._stream(uid, q, rid, created, obj, chat, len(prompt))
        else:
            self._collect(uid, q, rid, created, chat, len(prompt))

    # -- response shapes -----------------------------------------------
    def _envelope(self, rid: str, created: int, obj: str) -> Dict:
        return {"id": rid, "object": obj, "created": created,
                "model": self.srv.model_id}

    def _stream(self, uid: int, q: "queue.Queue", rid: str, created: int,
                obj: str, chat: bool, n_prompt: int) -> None:
        srv = self.srv
        try:
            self._begin_sse()
            if chat:
                first = self._envelope(rid, created, obj)
                first["choices"] = [{"index": 0, "finish_reason": None,
                                     "delta": {"role": "assistant"}}]
                self._sse(first)
            while True:
                kind, val = q.get()
                if kind == "token":
                    tok, info = val
                    ev = self._envelope(rid, created, obj)
                    piece = detok(tok)
                    choice = {"index": 0, "finish_reason": None,
                              "token_ids": [int(tok)]}
                    if chat:
                        choice["delta"] = {"content": piece}
                        if info is not None:
                            choice["logprobs"] = self._lp_chat(
                                [tok], [info])
                    else:
                        choice["text"] = piece
                        if info is not None:
                            choice["logprobs"] = self._lp_completions(
                                [tok], [info])
                    ev["choices"] = [choice]
                    self._sse(ev)
                elif kind == "done":
                    res: Result = val
                    ev = self._envelope(rid, created, obj)
                    choice = {"index": 0,
                              "finish_reason": res.finish_reason or "stop"}
                    if chat:
                        choice["delta"] = {}
                    else:
                        choice["text"] = ""
                    ev["choices"] = [choice]
                    ev["usage"] = self._usage(n_prompt, len(res.tokens))
                    self._sse(ev)
                    self._sse("[DONE]")
                    self._end_chunks()
                    return
                else:    # ("error", message)
                    self._sse({"error": {"message": val,
                                         "type": "server_error"}})
                    self._end_chunks()
                    return
        except (BrokenPipeError, ConnectionResetError):
            # client went away mid-stream: cancel the request so its
            # slot and pages free instead of decoding to the budget
            srv.abort(uid)

    def _collect(self, uid: int, q: "queue.Queue", rid: str, created: int,
                 chat: bool, n_prompt: int) -> None:
        infos: List = []
        while True:
            kind, val = q.get()
            if kind == "token":
                infos.append(val[1])
                continue
            if kind == "done":
                res: Result = val
                break
            if kind == "error":
                self._error(500, val, "server_error")
                return
        text = "".join(detok(t) for t in res.tokens)
        out = self._envelope(rid, created,
                             "chat.completion" if chat else "text_completion")
        choice = {"index": 0, "finish_reason": res.finish_reason or "stop",
                  "token_ids": [int(t) for t in res.tokens]}
        if chat:
            choice["message"] = {"role": "assistant", "content": text}
        else:
            choice["text"] = text
        toks = [int(t) for t in res.tokens]
        if infos and len(infos) == len(toks) \
                and all(i is not None for i in infos):
            choice["logprobs"] = (self._lp_chat(toks, infos) if chat
                                  else self._lp_completions(toks, infos))
        out["choices"] = [choice]
        out["usage"] = self._usage(n_prompt, len(res.tokens))
        self._json(200, out)

    # -- OpenAI logprob shapes -----------------------------------------
    @staticmethod
    def _lp_completions(tokens: List[int], infos: List[Dict]) -> Dict:
        """Completions-style block: parallel arrays over positions."""
        return {"tokens": [detok(t) for t in tokens],
                "token_logprobs": [i["logprob"] for i in infos],
                "top_logprobs": [
                    {detok(t): lp for t, lp in i["top_logprobs"]}
                    for i in infos]}

    @staticmethod
    def _lp_chat(tokens: List[int], infos: List[Dict]) -> Dict:
        """Chat-style block: one content entry per position."""
        return {"content": [
            {"token": detok(t), "logprob": i["logprob"],
             "top_logprobs": [{"token": detok(tt), "logprob": ll}
                              for tt, ll in i["top_logprobs"]]}
            for t, i in zip(tokens, infos)]}

    @staticmethod
    def _usage(n_prompt: int, n_out: int) -> Dict:
        return {"prompt_tokens": n_prompt, "completion_tokens": n_out,
                "total_tokens": n_prompt + n_out}


def serve_http(engine: Engine, host: str = "127.0.0.1", port: int = 8000,
               model_id: str = "repro-qlr"
               ) -> Tuple[ThreadingHTTPServer, EngineServer]:
    """Build the pump + HTTP server (not yet serving: call
    ``serve_forever()`` or drive it from a thread; ``port=0`` binds an
    ephemeral port, ``httpd.server_address[1]`` tells you which)."""
    srv = EngineServer(engine, model_id=model_id).start()
    handler = type("BoundHandler", (OpenAIHandler,), {"srv": srv})
    httpd = ThreadingHTTPServer((host, port), handler)
    httpd.daemon_threads = True
    return httpd, srv
