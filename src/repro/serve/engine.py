"""Serving engine: continuous batching over a (quantized) Q + LR model.

The engine serves the paper's deployment artifact — a ``W ≈ Q + LR``
model — through the same forward code paths the dry-run lowers at pod
scale, under two schedulers:

  * ``continuous`` (default, production): a **slot-based KV cache**
    (``serve.slots``) gives every batch row its own write position and
    valid-length mask, so requests are admitted into free slots
    *mid-flight*: prefill-on-admit scatters a freshly prefilled row into
    the live cache while the other slots keep decoding. Per-request
    ``max_new_tokens`` / EOS retire a slot the moment its request
    finishes, and the next queued request takes the lane on the same
    step. Exactly **two compiled shapes** total — one (1, prefill_len)
    prefill, one (slots, 1) decode — regardless of the prompt-length mix
    (prompts are right-padded and masked, never re-bucketed).
  * ``bucketed`` (baseline): the old dry-run-grade scheduler — requests
    grouped by identical prompt length, each bucket padded to
    ``decode_batch`` and decoded to its slowest member. Kept for A/B
    benchmarking (``benchmarks/serve_throughput.py``).

Also here: **int8 KV** (``kv_dtype="int8"``) halves cache HBM — the
quantization-native option that makes 32k-context MHA models fit — and
**int4 KV** (``kv_dtype="int4"``) halves it again via the packed4
nibble container (two slots per byte, unpacked inside the flash-decode
kernel), doubling the servable slots or context at fixed memory; plus
per-request latency metrics (TTFT, end-to-end latency) and scheduler
occupancy counters. The ``fused`` switch routes every quantized
projection in prefill *and* per-step decode through the fused Q + LR
matmul (``repro.kernels.ops.qlr_matmul``) **and** per-step decode
attention through the flash-decode path
(``repro.kernels.ops.decode_attention_op``: Pallas kernel on TPU,
fused-XLA elsewhere — int8 KV codes are read straight from the
head-major cache pages and dequantized in VMEM / on the score planes),
so neither the dequantized weight nor the dequantized cache ever
round-trips HBM. MLA models additionally get their absorbed decode
projections (W_uk / W_uv) materialized once per engine session instead
of once per token (see ``absorbed_params`` below).

Request-path API (one surface across Python, CLI, and HTTP —
``serve.http`` speaks OpenAI over exactly these calls):

  * per-request :class:`~repro.serve.sampling.SamplingParams` on
    ``Request.params`` — temperature / top-p / top-k / seed / stop ids /
    max_new_tokens; mixed greedy+sampled batches decode together, each
    lane drawing from its own counter-based PRNG stream so output is
    independent of scheduling. ``ServeConfig.temperature`` / ``eos_id``
    are *defaults* only.
  * ``Result.finish_reason`` ∈ ``"stop" | "length" | "abort"``.
  * ``abort(uid)`` cancels a request anywhere in its lifecycle — queued,
    mid-chunked-prefill (pages decref'd, prefix match released), or
    decoding — and frees its slot immediately.
  * ``ServeConfig.max_step_tokens`` arms the token-budget step
    scheduler: per step, prefill tokens (chunk dispatches at compiled
    width) + decode lanes stay ≤ the budget, so a burst of long-prompt
    admissions cannot stall live decode lanes (bounded p95 ITL);
    ``max_pages_per_request`` and ``free_watermark`` add per-request
    page quotas and ahead-of-demand cold-set eviction under the paged
    cache.

API: ``submit()`` / ``step()`` / ``drain()`` for streaming use;
``generate()`` runs a whole batch of requests through either scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.constraints import validate_page_size
from repro.models import (Ctx, decode_step, init_cache, prefill,
                          prefill_chunk, verify_chunk)
from repro.models.attention import absorb_mla_weights
from repro.serve.pages import PagedKVCache, PagePool
from repro.serve.sanitizer import Sanitizer
from repro.serve.prefix import RadixPrefixCache
from repro.serve.sampling import (TOP_LOGPROBS, SamplingParams, lane_seed,
                                  sample_tokens)
from repro.serve.scheduler import (ContinuousScheduler, SchedulerStats,
                                   StepBudget)
from repro.serve.slots import KV_DTYPES, SlotKVCache
from repro.serve.telemetry import (NULL_TELEMETRY, MetricsRegistry,
                                   Telemetry, log_buckets)


# --------------------------------------------------------------------------
# MLA absorbed-weight cache: ``mla_step`` folds q / the attention output
# through W_uk / W_uv each token; materializing those dense projections
# from the quantized Q+LR params *inside* the compiled decode step would
# re-run dequant + the L·R product per token. Absorb once per params
# tree instead, keyed on identity (repeat Engine constructions over the
# same quantized model — A/B benchmark sweeps — reuse the absorption).
# --------------------------------------------------------------------------
# single entry: consecutive engines over the same params (mode/kv-dtype
# A/B sweeps) share the absorption; a new params tree evicts the old one
# immediately, so at most one model's absorbed weights stay resident.
# Deliberate trade-off: the entry outlives its engines (that is what
# makes A/B sweeps hit), retaining at most one model until the next
# absorption or a non-MLA engine construction; call
# release_absorbed_params() to free it eagerly.
_absorb_cache: Optional[tuple] = None  # (params, absorbed)


def _params_have_lowrank(tree) -> bool:
    """True when any quantized matrix in the tree carries a non-empty
    low-rank correction (an ``l`` leaf with rank > 0). Decides the
    speculative verify's storage mode: without LR slivers the Q-only
    draft IS the full model, so the drafts' step-graph KV writes are
    already exact and verify can stay read-only."""
    if isinstance(tree, dict):
        ll = tree.get("l")
        if hasattr(ll, "shape") and ll.shape and ll.shape[-1] > 0:
            return True
        return any(_params_have_lowrank(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return any(_params_have_lowrank(v) for v in tree)
    return False


def _absorb_mla_tree(p):
    """Copy of the params tree with every MLA mixer (any dict carrying
    ``w_uk``/``w_uv``) augmented with its dense absorbed projections.
    Scan-stacked group mixers pass through with their leading dim."""
    if isinstance(p, dict):
        if "w_uk" in p and "w_uv" in p:
            return absorb_mla_weights(p)
        return {k: _absorb_mla_tree(v) for k, v in p.items()}
    if isinstance(p, list):
        return [_absorb_mla_tree(v) for v in p]
    if isinstance(p, tuple):
        return tuple(_absorb_mla_tree(v) for v in p)
    return p


def absorbed_params(params):
    """Identity-cached :func:`_absorb_mla_tree` (single entry)."""
    global _absorb_cache
    if _absorb_cache is not None and _absorb_cache[0] is params:
        return _absorb_cache[1]
    out = _absorb_mla_tree(params)
    _absorb_cache = (params, out)
    return out


def release_absorbed_params() -> None:
    """Drop the cached absorption so the old model's params + dense
    W_uk/W_uv become collectable. Called when an engine is built over a
    non-MLA model (the cache can only be stale then); live MLA engines
    keep their own reference to the absorbed tree."""
    global _absorb_cache
    _absorb_cache = None


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512               # cache slots (prompt + generation)
    decode_batch: int = 8            # decode lanes (= slots, continuous)
    max_new_tokens: int = 64
    eos_id: int = -1                 # -1: never stop early. DEFAULT only:
    # per-request SamplingParams.stop ids extend it
    kv_dtype: str = "bf16"           # bf16 | f32 | int8 | int4
    temperature: float = 0.0         # 0 = greedy. DEFAULT only: a
    # request's SamplingParams.temperature overrides per lane (the old
    # engine-global knob is deprecated as anything but a fallback)
    compute_dtype: str = "f32"
    scheduler: str = "continuous"    # continuous | bucketed
    prefill_len: Optional[int] = None  # compiled prompt pad length; under
    # --paged this is the *chunk* width, no longer a prompt-length cap
    seed: int = 0                    # sampling stream for submit()/step()
    fused: str = "auto"              # Q+LR matmul path: auto | on | off
    # --- paged KV cache (serve.pages / serve.prefix) ---
    paged: bool = False              # block-granular pages + block tables
    page_size: int = 16              # logical slots per page (even; on real
    # TPU must meet the Mosaic sublane tile: ≥32, ≥64 for int4)
    n_pages: Optional[int] = None    # pool size; default sizes for full
    # residency of every lane + one request of prefix-retention headroom
    prefix_cache: bool = True        # radix-tree automatic prefix reuse
    # --- token-budget step scheduler ---
    max_step_tokens: Optional[int] = None  # per-step cap on prefill
    # tokens (chunk/prefill dispatches at their compiled width) + decode
    # lanes; None = unbudgeted. Must be >= the compiled prefill width + 1
    # so an admission can always make progress on an idle engine
    max_pages_per_request: Optional[int] = None  # paged: hard page quota
    # per request — clamps the decode budget so prompt+generation never
    # maps more than this many pages (fairness under pool pressure)
    free_watermark: float = 0.0      # paged: fraction of the pool kept
    # free by evicting cold prefix pages ahead of demand each step
    # (0 = evict only when an allocation would fail)
    # --- telemetry (serve.telemetry) ---
    telemetry: bool = False          # request/step tracing + latency
    # histograms + compile tracking; the metrics registry itself is
    # always live (stats()/metrics()/prometheus() are one snapshot)
    trace_sync: bool = False         # block_until_ready fence after device
    # dispatch so device time lands in the phase that launched it
    profile_dir: Optional[str] = None  # arm jax.profiler capture here
    profile_steps: int = 20          # engine steps to capture when armed
    # --- self-speculative decoding (Q-only draft, Q+LR verify) ---
    speculative: bool = False        # draft with the quantized base alone
    # (the LR sliver sliced to rank 0 — same resident weights, strictly
    # less work per token), then score spec_k tokens in one full-model
    # chunk dispatch; token-identical to non-speculative decode
    spec_k: int = 4                  # tokens scored per verify chunk
    # (1 fed last-token + spec_k-1 drafts); >= 2
    # --- runtime invariant sanitizer (serve.sanitizer) ---
    sanitize: bool = False           # audit page refcounts, block
    # tables, pos/slot_pos and int4 alignment after every step();
    # read-only (token-identical) but host-syncing — CI smokes and
    # debugging, not production
    # --- accuracy-drift monitor (repro.obs quantization observability) ---
    drift_monitor: bool = False      # sampled shadow comparison of the
    # serving logits against a reference lowering of the same quantized
    # params: per-lane KL / top-1 agreement / max-|Δlogit| histograms +
    # always-cheap NaN/inf guard counters. Read-only (token-identical);
    # costs one extra decode dispatch per sampled step
    drift_sample_rate: float = 0.05  # fraction of plain decode steps
    # shadow-compared (deterministic in the step counter, never in the
    # tokens); 1.0 = every step
    drift_ref_fused: str = "off"     # fused mode of the reference
    # lowering (auto | on | off); "off" = dequant-then-matmul, the
    # ungrouped ground-truth path the kernels are verified against


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (L,) int32
    max_new_tokens: Optional[int] = None  # deprecated shim — prefer
    # params.max_new_tokens; kept so pre-SamplingParams callers keep
    # working (params wins when both are set)
    t_submit: float = 0.0
    params: Optional[SamplingParams] = None  # per-request sampling/stop;
    # submit() resolves None fields against the ServeConfig defaults


@dataclasses.dataclass
class Result:
    """Timings are ``None`` when the underlying event never happened —
    a request retired without decoding (``max_new_tokens=0``) reports
    ``decode_s=None``/``ttft_s=None``, distinguishable from "decoded in
    ~0 seconds"; a missing submit timestamp yields ``latency_s=None``
    instead of a silent 0.0."""
    uid: int
    tokens: np.ndarray               # generated tokens (without prompt)
    prefill_s: Optional[float] = None  # prefill wall time for this request
    decode_s: Optional[float] = None   # first token → last token
    ttft_s: Optional[float] = None     # submit → first token
    latency_s: Optional[float] = None  # submit → done
    finish_reason: Optional[str] = None  # "stop" (EOS / stop id, token
    # included in tokens) | "length" (budget exhausted) | "abort"


@dataclasses.dataclass
class _PrefillJob:
    """A paged admission mid-chunked-prefill: the slot is allocated and
    its block table mapped, but the prompt is only prefilled up to
    ``next`` — one chunk advances per engine step, interleaved with the
    other slots' decode."""
    req: Request
    state: object                    # the scheduler's SlotState
    next: int                        # first not-yet-prefilled position
    matched_tokens: int              # prefix-cache tokens skipped
    prepaid: bool = False            # this step's chunk already charged
    # to the token budget at admission (don't double-charge)


class Engine:
    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None):
        if sc.scheduler not in ("continuous", "bucketed"):
            raise ValueError(f"unknown scheduler {sc.scheduler!r}")
        if sc.fused not in ("auto", "on", "off"):
            raise ValueError(f"unknown fused mode {sc.fused!r}")
        if sc.kv_dtype not in KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {sc.kv_dtype!r} "
                             f"(choose from {sorted(KV_DTYPES)})")
        if sc.paged:
            if sc.scheduler != "continuous":
                raise ValueError("paged KV needs scheduler='continuous'")
            unsupported = [k for k in cfg.block_pattern if k != "attn"]
            if (unsupported or cfg.attn_kind == "mla"
                    or cfg.is_encoder_decoder or cfg.n_vision_tokens):
                raise ValueError(
                    f"paged KV cache supports pure full-GQA-attention "
                    f"stacks (got pattern={cfg.block_pattern}, "
                    f"attn_kind={cfg.attn_kind!r}): recurrent states, MLA "
                    f"latents and encoder memories have no block-sharing "
                    f"story yet")
        if sc.speculative:
            if sc.scheduler != "continuous":
                raise ValueError("speculative decoding needs "
                                 "scheduler='continuous'")
            if sc.spec_k < 2:
                raise ValueError(
                    f"spec_k={sc.spec_k} must be >= 2 — one Q-only draft "
                    f"token plus the verify model's own next token")
            unsupported = [k for k in cfg.block_pattern if k != "attn"]
            if (unsupported or cfg.attn_kind == "mla"
                    or cfg.is_encoder_decoder or cfg.n_vision_tokens):
                raise ValueError(
                    f"speculative decoding verifies through the chunked "
                    f"attention path and needs a pure full-GQA-attention "
                    f"decoder (got pattern={cfg.block_pattern}, "
                    f"attn_kind={cfg.attn_kind!r})")
        if sc.drift_ref_fused not in ("auto", "on", "off"):
            raise ValueError(
                f"unknown drift_ref_fused {sc.drift_ref_fused!r}")
        if sc.drift_monitor:
            if sc.scheduler != "continuous":
                raise ValueError("drift_monitor shadows the continuous "
                                 "engine's decode dispatch — it needs "
                                 "scheduler='continuous'")
            if not 0.0 < sc.drift_sample_rate <= 1.0:
                raise ValueError(
                    f"drift_sample_rate={sc.drift_sample_rate} must be "
                    f"in (0, 1]")
        # absorb MLA decode weights once per engine session (identity-
        # cached across engines; switching to a non-MLA model frees any
        # previous model's cached absorption)
        if cfg.attn_kind == "mla":
            self.params = absorbed_params(params)
        else:
            self.params = params
            release_absorbed_params()
        self.cfg = cfg
        self.sc = sc
        self.extra = extra_inputs or {}
        # fused="auto" serves the Q+LR decomposition through the Pallas
        # kernels on TPU and the fused-XLA lowering elsewhere; "on"
        # forces the kernels (interpret off-TPU — validation runs).
        # use_pallas follows the resolved mode: whenever the matmul runs
        # as a kernel, prefill attention takes the flash kernel too —
        # the engine is inference-only, so the kernels' lack of a VJP
        # cannot bite here.
        from repro.models.linear import fused_mode
        ctx = Ctx(compute_dtype=KV_DTYPES[sc.compute_dtype], fused=sc.fused)
        ctx.use_pallas = fused_mode(ctx) == "kernel"
        self.ctx = ctx
        # the registry is always live (stats()/metrics()/prometheus()
        # are snapshots of it); the *recorder* — tracing, step-phase
        # histograms, compile tracking — is the no-op singleton unless
        # telemetry is on, so the hot loop pays one no-op dispatch per
        # call site when disabled
        self.registry = MetricsRegistry()
        if sc.telemetry or sc.profile_dir:
            self.tel = Telemetry(registry=self.registry, sync=sc.trace_sync,
                                 profile_dir=sc.profile_dir,
                                 profile_steps=sc.profile_steps)
        else:
            self.tel = NULL_TELEMETRY
        self.prefill_len = sc.prefill_len or sc.max_len
        if self.prefill_len > sc.max_len:
            raise ValueError(
                f"prefill_len={self.prefill_len} exceeds max_len="
                f"{sc.max_len}: the prefill shape must fit the cache")
        self._n_vis = cfg.n_vision_tokens or 0

        cdt = KV_DTYPES[sc.kv_dtype]
        self._init_cache = lambda: init_cache(
            cfg, sc.decode_batch, sc.max_len, dtype=cdt)

        ctx = self.ctx

        # per-lane sampling: `lanes` is a (temps, top_ps, top_ks, seeds,
        # idxs) tuple of (B,) arrays. PRNG keys are derived inside the
        # jit from (seed, token index) — counter-based, so a lane's draw
        # never depends on scheduling, batch composition, or step count.
        # `want_lp` is *static*: the logprob report (log_softmax + top-k)
        # is only traced into the graph when a live lane asked for it,
        # so the default hot path compiles exactly as before
        def _lp(lg, tok, want_lp):
            if not want_lp:
                return None
            lp = jax.nn.log_softmax(lg, axis=-1)
            chosen = jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0]
            top_lp, top_ids = jax.lax.top_k(lp, TOP_LOGPROBS)
            return chosen, top_lp, top_ids

        def _sample(logits, lanes, want_lp):
            lg = logits[:, -1].astype(jnp.float32)
            tok = sample_tokens(lg, *lanes)
            return (tok[:, None], _lp(lg, tok, want_lp))

        def _prefill(params, batch, cache, lengths, lanes, want_lp):
            logits, cache = prefill(ctx, params, batch, cfg, cache,
                                    lengths=lengths)
            return _sample(logits, lanes, want_lp), cache

        def _decode(params, token, cache, lanes, want_lp):
            logits, cache = decode_step(ctx, params, token, cache, cfg)
            return _sample(logits, lanes, want_lp), cache

        def _chunk(params, tokens, cache, row, start, length, lanes,
                   want_lp):
            logits, cache = prefill_chunk(ctx, params, tokens, cfg, cache,
                                          row, start, length)
            return _sample(logits, lanes, want_lp), cache

        self._prefill = jax.jit(_prefill, static_argnums=(5,))
        self._decode = jax.jit(_decode, static_argnums=(4,))
        self._chunk = jax.jit(_chunk, static_argnums=(7,))

        # --- self-speculative closures ---------------------------------
        # draft: the identical lockstep decode graph with the low-rank
        # sliver sliced to rank 0 (Ctx.draft) — Q-only logits, with the
        # drafted tokens' KV persisted at the usual slots through the
        # very same step graph a plain decode uses (the verify chunk is
        # read-only, so accepted slots keep these step-graph entries)
        dctx = dataclasses.replace(ctx, draft=True)

        def _draft(params, token, cache, lanes):
            logits, cache = decode_step(dctx, params, token, cache, cfg)
            tok = sample_tokens(logits[:, -1].astype(jnp.float32), *lanes)
            return tok[:, None], cache

        # the whole k-1 draft chain runs as ONE compiled dispatch:
        # dispatch + host-sync overhead per round stays O(1) in k
        # instead of O(k), which is where the CPU speedup lives and
        # what keeps TPU launch counts flat. The chain is unrolled in
        # the trace rather than lax.scan'd — XLA:CPU serializes loop
        # bodies onto one thread (measured ~30x slower per round) while
        # the unrolled chain keeps intra-op parallelism, and k <=
        # spec_k keeps the trace small. Static k: at most spec_k-1
        # compiled variants (k clamps down only when a lane nears its
        # token budget), all pre-compiled by warmup()
        def _draft_span(params, token, cache, lanes, k):
            toks = []
            for _ in range(k - 1):
                token, cache = _draft(params, token, cache, lanes)
                toks.append(token)
            return jnp.stack(toks), cache  # (k-1, B, 1)

        # verify: one (1, spec_k) chunk re-scores [last token ‖ drafts]
        # with the full Q+LR model; every position is sampled in-graph
        # with the lane's counter-based keys (idx0 + j). Chunk logits
        # only ever gate acceptance (and supply logprobs for tokens the
        # draft already proposed) — emitted tokens all originate in the
        # step-shaped graph, see _spec_round
        # read-only verify when the draft IS the target (no LR params
        # to slice): storage keeps the drafts' bit-exact step-graph
        # K/V and greedy spec output is structurally identical to
        # non-speculative decode. Models with LR slivers need the
        # chunk to upgrade the drafts' Q-only K/V to full-model
        # entries — see verify_chunk for the parity consequences.
        spec_store = _params_have_lowrank(params)

        def _verify(params, tokens, cache, row, start, length, lane,
                    want_lp):
            logits, cache = verify_chunk(ctx, params, tokens, cfg, cache,
                                         row, start, length,
                                         store=spec_store)
            lg = logits[0].astype(jnp.float32)
            kk = lg.shape[0]
            temp, top_p, top_k, seed, idx0 = lane
            tok = sample_tokens(
                lg, jnp.full((kk,), temp, jnp.float32),
                jnp.full((kk,), top_p, jnp.float32),
                jnp.full((kk,), top_k, jnp.int32),
                jnp.full((kk,), seed, jnp.int32),
                idx0 + jnp.arange(kk, dtype=jnp.int32))
            return (tok, _lp(lg, tok, want_lp)), cache

        # rollback: rewrite the verified rows' positions to
        # p + n_accepted (one fused dispatch over every layer's pos
        # leaf; the groups stack broadcasts over its leading axis).
        # Rejected-tail KV needs no page work — its slots live in pages
        # the request already owns (pre-allocated at admission), and
        # the pos predicate masks them dead until overwritten
        def _rewind(cache, mask, newpos):
            def walk(c):
                if isinstance(c, dict):
                    out = {k: walk(v) for k, v in c.items()}
                    if "pos" in c and hasattr(c["pos"], "ndim"):
                        p = c["pos"]
                        m, np_ = ((mask, newpos) if p.ndim == 1
                                  else (mask[None], newpos[None]))
                        out["pos"] = jnp.where(m, np_.astype(p.dtype), p)
                    return out
                if isinstance(c, list):
                    return [walk(v) for v in c]
                if isinstance(c, tuple):
                    return tuple(walk(v) for v in c)
                return c
            return walk(cache)

        self._draft_span = jax.jit(_draft_span, static_argnums=(4,))
        self._verify = jax.jit(_verify, static_argnums=(7,))
        self._rewind = jax.jit(_rewind)

        # --- accuracy-drift probe --------------------------------------
        # one jitted shadow dispatch re-runs this step's decode over the
        # *pre-step* cache twice — under the serving lowering and under a
        # reference lowering of the same quantized params (default
        # fused="off": the dequant-then-matmul ground truth the fused
        # kernels are verified against) — and reduces the final-position
        # logits to per-lane KL(serving ‖ reference), argmax agreement,
        # max-|Δlogit| and a non-finite element count. Both cache outputs
        # are discarded and nothing is donated, so the probe is read-only
        # by construction: served tokens are bit-identical with the
        # monitor on or off.
        if sc.drift_monitor:
            rctx = dataclasses.replace(ctx, fused=sc.drift_ref_fused)
            rctx.use_pallas = fused_mode(rctx) == "kernel"

            def _drift_probe(params, token, cache):
                lg_s, _ = decode_step(ctx, params, token, cache, cfg)
                with jax.named_scope("drift_ref"):
                    lg_r, _ = decode_step(rctx, params, token, cache, cfg)
                s = lg_s[:, -1].astype(jnp.float32)
                r = lg_r[:, -1].astype(jnp.float32)
                logp_s = jax.nn.log_softmax(s)
                logp_r = jax.nn.log_softmax(r)
                kl = jnp.sum(jnp.exp(logp_s) * (logp_s - logp_r), axis=-1)
                agree = jnp.argmax(s, axis=-1) == jnp.argmax(r, axis=-1)
                delta = jnp.max(jnp.abs(s - r), axis=-1)
                bad = (jnp.sum(~jnp.isfinite(s), axis=-1)
                       + jnp.sum(~jnp.isfinite(r), axis=-1))
                return kl, agree, delta, bad

            self._drift_probe = jax.jit(_drift_probe)
            self._drift_every = max(1, round(1.0 / sc.drift_sample_rate))
        else:
            self._drift_probe = None
            self._drift_every = 0
        self._drift_step = 0

        # paged geometry: the chunk width is the (even) prefill length,
        # chunk starts are page-aligned (matched prefixes are whole
        # pages), so int4 nibble pairs always land whole
        self.page_size = sc.page_size + sc.page_size % 2
        if sc.paged:
            # construction-time layout check against the shared kernel
            # constraints — a clear error here instead of a Mosaic
            # lowering failure on the first compiled dispatch. Strict
            # (sublane-tile) floors only bind where the kernels compile
            # for real hardware; interpret-mode CPU runs take any even
            # size.
            validate_page_size(self.page_size,
                               packed=sc.kv_dtype == "int4",
                               strict=jax.default_backend() == "tpu")
        self._chunk_len = self.prefill_len + self.prefill_len % 2 \
            if sc.paged else self.prefill_len

        # token-budget config: the unit of prefill work is one compiled-
        # width dispatch (a "partial" chunk still computes the full
        # width), and an admission whose prefill completes immediately
        # also joins decode the same step (+1)
        self._step_unit = self._chunk_len if sc.paged else self.prefill_len
        if sc.max_step_tokens is not None:
            if sc.scheduler != "continuous":
                raise ValueError("max_step_tokens needs "
                                 "scheduler='continuous'")
            if sc.max_step_tokens < self._step_unit + 1:
                raise ValueError(
                    f"max_step_tokens={sc.max_step_tokens} cannot cover "
                    f"one prefill dispatch ({self._step_unit} compiled "
                    f"tokens) plus its first decode lane — an idle "
                    f"engine could never admit anything")
        if not 0.0 <= sc.free_watermark < 1.0:
            raise ValueError(f"free_watermark={sc.free_watermark} must "
                             f"be in [0, 1)")
        if sc.max_pages_per_request is not None \
                and sc.max_pages_per_request < 1:
            raise ValueError("max_pages_per_request must be >= 1")
        if (sc.max_pages_per_request is not None
                or sc.free_watermark > 0.0) and not sc.paged:
            raise ValueError("max_pages_per_request / free_watermark "
                             "need ServeConfig(paged=True)")

        # --- continuous-scheduler state ---------------------------------
        self.slots = None                # SlotKVCache | PagedKVCache
        self.sched: Optional[ContinuousScheduler] = None
        self.pool: Optional[PagePool] = None
        self.prefix: Optional[RadixPrefixCache] = None
        self._tok = None
        self._base_seed = sc.seed        # sampling stream base for
        # submit()/step(); generate(seed=) overrides per run
        # per-lane sampling state mirrored into the decode dispatch
        b = sc.decode_batch
        self._lane_temp = np.zeros((b,), np.float32)
        self._lane_top_p = np.ones((b,), np.float32)
        self._lane_top_k = np.zeros((b,), np.int32)
        self._lane_seed = np.zeros((b,), np.int32)
        self._lane_lp = np.zeros((b,), bool)
        self._want_lp = False            # any live lane wants logprobs
        # self-speculative accounting (published unconditionally)
        self._spec_rounds = 0
        self._spec_draft_tokens = 0
        self._spec_accepted_tokens = 0
        self._h_accept = self.registry.histogram(
            "spec_accept_per_round",
            "accepted draft tokens per lane per speculative round")
        # drift-monitor accounting (published unconditionally, like the
        # spec counters: zeros when the monitor is off)
        self._drift_checks = 0
        self._drift_agree = 0
        self._drift_nonfinite = 0
        self._guard_oob = 0
        self._h_drift_kl = self.registry.histogram(
            "drift_kl",
            "per-lane KL(serving ‖ reference) at drift-sampled steps",
            buckets=log_buckets(1e-12, 100.0, 2))
        self._h_drift_delta = self.registry.histogram(
            "drift_logit_delta",
            "per-lane max |Δlogit| vs the reference lowering at "
            "drift-sampled steps",
            buckets=log_buckets(1e-12, 100.0, 2))
        # streaming hook: called as on_token(uid, token, info) for every
        # generated token the moment it is recorded (serve.http fans
        # these out to SSE connections); info is the logprob record when
        # the request asked for logprobs, else None
        self.on_token: Optional[Callable[[int, int, Optional[Dict]],
                                         None]] = None
        self._bucket_stats = SchedulerStats(n_slots=sc.decode_batch)
        if sc.sanitize and sc.scheduler != "continuous":
            raise ValueError("sanitize=True audits the continuous "
                             "engine's slot/page state — it needs "
                             "scheduler='continuous'")
        self._san = Sanitizer() if sc.sanitize else None
        if sc.scheduler == "continuous":
            self._reset_continuous()

    # ------------------------------------------------------------------
    def _reset_continuous(self) -> None:
        sc = self.sc
        self.sched = ContinuousScheduler(sc.decode_batch, sc.eos_id,
                                         sc.max_new_tokens,
                                         max_step_tokens=sc.max_step_tokens)
        self._need_plain = False         # a spec-round rejection forces
        # one step-graph decode (the correction token's source)
        self._tok = jnp.zeros((sc.decode_batch, 1), jnp.int32)
        if not sc.paged:
            self.slots = SlotKVCache(self.cfg, sc.decode_batch, sc.max_len,
                                     sc.kv_dtype)
            return
        ps = self.page_size
        nb = -(-sc.max_len // ps)
        # full residency for every lane + its parked page + one request's
        # worth of prefix-retention headroom
        n_pages = sc.n_pages or (sc.decode_batch * (nb + 1) + nb)
        if n_pages < nb + sc.decode_batch:
            raise ValueError(
                f"n_pages={n_pages} cannot hold one parked page per slot "
                f"plus one full request ({nb} blocks at page_size={ps})")
        self.slots = PagedKVCache(self.cfg, sc.decode_batch, sc.max_len,
                                  sc.kv_dtype, ps, n_pages)
        self.pool = PagePool(n_pages, ps)
        self.prefix = RadixPrefixCache(self.pool) if sc.prefix_cache else None
        # one permanently-allocated private page per slot: retired (and
        # still-prefilling) rows point every unused block-table entry at
        # it, so the decode step's unconditional write never lands in a
        # page another request owns
        self._parked = self.pool.alloc(sc.decode_batch)
        self._row_pages: Dict[int, List[int]] = {}
        self._prefill_jobs: Dict[int, "_PrefillJob"] = {}
        self._prefill_chunks = 0
        self._prefill_tokens_computed = 0
        self._prompt_tokens_total = 0
        self._prefix_hit_tokens = 0
        for slot in range(sc.decode_batch):
            self.slots.set_row(slot, [self._parked[slot]] * nb, 0)

    def _req_budget(self, r: Request) -> int:
        """Per-request token budget; ``is not None`` (not truthiness) so
        an explicit max_new_tokens=0 stays 0 — mirror of the scheduler's
        next_admission fix."""
        if r.params is not None and r.params.max_new_tokens is not None:
            return r.params.max_new_tokens
        return (r.max_new_tokens if r.max_new_tokens is not None
                else self.sc.max_new_tokens)

    def _resolve(self, req: Request) -> SamplingParams:
        """Fill a request's ``SamplingParams`` None fields from the
        ServeConfig defaults (and the deprecated ``Request.
        max_new_tokens`` shim) — after this, every field is concrete."""
        sp = req.params or SamplingParams()
        t = (sp.temperature if sp.temperature is not None
             else self.sc.temperature)
        mnt = sp.max_new_tokens
        if mnt is None:
            mnt = req.max_new_tokens
        if mnt is None:
            mnt = self.sc.max_new_tokens
        return dataclasses.replace(sp, temperature=float(t),
                                   max_new_tokens=int(mnt))

    # --- per-lane sampling plumbing -----------------------------------
    def _lanes_for(self, state, idx: int):
        """Single-row lane arrays for a prefill/chunk dispatch sampling
        this request's token number ``idx``."""
        sp = state.sampling
        return (jnp.asarray([sp.temperature], jnp.float32),
                jnp.asarray([sp.top_p], jnp.float32),
                jnp.asarray([sp.top_k], jnp.int32),
                jnp.asarray([state.seed], jnp.int32),
                jnp.asarray([idx], jnp.int32))

    def _decode_lanes(self):
        """(B,) lane arrays for the lockstep decode dispatch; retired /
        mid-prefill lanes ride greedy (their draw is never read)."""
        idxs = np.zeros((self.sc.decode_batch,), np.int32)
        for s, st in self.sched.table.active.items():
            idxs[s] = len(st.tokens)
        return (jnp.asarray(self._lane_temp), jnp.asarray(self._lane_top_p),
                jnp.asarray(self._lane_top_k), jnp.asarray(self._lane_seed),
                jnp.asarray(idxs))

    def _set_lane(self, slot: int, state) -> None:
        sp = state.sampling
        self._lane_temp[slot] = sp.temperature
        self._lane_top_p[slot] = sp.top_p
        self._lane_top_k[slot] = sp.top_k
        self._lane_seed[slot] = state.seed
        self._lane_lp[slot] = sp.logprobs is not None
        self._want_lp = bool(self._lane_lp.any())

    def _clear_lane(self, slot: int) -> None:
        self._lane_temp[slot] = 0.0
        self._lane_top_p[slot] = 1.0
        self._lane_top_k[slot] = 0
        self._lane_seed[slot] = 0
        self._lane_lp[slot] = False
        self._want_lp = bool(self._lane_lp.any())

    def _lp_entry(self, state, chosen, top_lp,
                  top_ids) -> Optional[Dict]:
        """One request-facing logprob record from host-side values:
        the sampled token's logprob plus the top-n alternatives the
        request asked for (compiled width TOP_LOGPROBS, trimmed here)."""
        n = state.sampling.logprobs
        if n is None:
            return None
        top = [(int(i), float(v))
               for i, v in zip(top_ids[:n], top_lp[:n])]
        return {"logprob": float(chosen), "top_logprobs": top}

    def _record(self, slot: int, token: int, info=None) -> bool:
        """record_token + the streaming on_token fanout."""
        state = self.sched.table.active[slot]
        done = self.sched.record_token(slot, token)
        if self.on_token is not None:
            self.on_token(state.uid, int(token), info)
        return done

    def _validate(self, req: Request) -> None:
        plen = len(req.prompt)
        eff = plen + self._n_vis
        if plen < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.params is not None:
            try:
                req.params.validate()
            except ValueError as e:
                raise ValueError(f"request {req.uid}: {e}") from None
        if self.sc.max_pages_per_request is not None \
                and eff >= self.sc.max_pages_per_request * self.page_size:
            raise ValueError(
                f"request {req.uid}: prompt length {plen} fills the "
                f"max_pages_per_request={self.sc.max_pages_per_request} "
                f"page quota ({self.page_size} slots/page) with no "
                f"decode budget left")
        if eff >= self.sc.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {plen}"
                + (f" (+{self._n_vis} vision tokens)" if self._n_vis else "")
                + f" leaves no decode budget within max_len={self.sc.max_len}"
                f" — raise ServeConfig.max_len or shorten the prompt")
        if (self.sc.scheduler == "continuous" and not self.sc.paged
                and eff > self.prefill_len):
            # the paged engine has no such cap: chunked prefill feeds any
            # prompt < max_len through the one compiled chunk shape
            raise ValueError(
                f"request {req.uid}: prompt length {plen} exceeds the "
                f"compiled prefill shape prefill_len={self.prefill_len} "
                f"(ServeConfig(paged=True) lifts this via chunked prefill)")

    def _batch_for(self, prompts: np.ndarray) -> Dict[str, jax.Array]:
        b, s = prompts.shape
        batch: Dict[str, jax.Array] = {"tokens": jnp.asarray(prompts)}
        if self.cfg.is_encoder_decoder:
            frames = self.extra.get("frames")
            if frames is None:
                frames = np.zeros(
                    (b, self.cfg.enc_seq, self.cfg.d_frontend), np.float32)
            batch["frames"] = jnp.asarray(frames[:b])
        if self.cfg.n_vision_tokens:
            vis = self.extra.get("vision")
            if vis is None:
                vis = np.zeros((b, self.cfg.n_vision_tokens,
                                self.cfg.d_frontend or self.cfg.d_model),
                               np.float32)
            batch["vision"] = jnp.asarray(vis[:b])
        return batch

    # ==================================================================
    # Streaming API (continuous scheduler)
    # ==================================================================
    def submit(self, req: Request) -> int:
        """Queue a request; it is admitted on the next step() with a free
        slot. Returns the request uid."""
        if self.sc.scheduler != "continuous":
            raise RuntimeError("submit()/step()/drain() need "
                               "ServeConfig(scheduler='continuous')")
        self._validate(req)
        req.params = self._resolve(req)
        req.t_submit = req.t_submit or time.perf_counter()
        self.sched.submit(req)
        self.tel.request_queued(req.uid)
        return req.uid

    # ------------------------------------------------------------------
    # Paged admission: map pages (prefix hits + fresh allocations) into
    # the slot's block table; the prompt then prefills chunk-by-chunk
    # across engine steps (interleaved with decode) instead of in one
    # blocking call.
    # ------------------------------------------------------------------
    def _admit_paged(self, budget: StepBudget) -> Optional[List[Result]]:
        if not self.sched.queue or self.sched.table.n_free == 0:
            return None
        # the admission's first chunk runs this step (prepaid below);
        # cheap gate before touching the prefix tree — the exact cost
        # (is the first chunk final?) is re-checked after matching
        if not budget.can(self._chunk_len):
            self.sched.stats.budget_deferred_admissions += 1
            return None
        nxt = self.sched.next_admission()
        req, state = nxt
        eff = state.prompt_len
        state.budget = min(state.budget, self.sc.max_len - eff)
        ps, nb = self.page_size, self.slots.n_blocks
        if self.sc.max_pages_per_request is not None:
            # page quota: prompt + generation never map more pages than
            # the quota (prompt-only overflow was rejected at submit)
            state.budget = min(state.budget,
                               self.sc.max_pages_per_request * ps - eff)
        matched: List[int] = []
        if self.prefix is not None:
            # cap: at least one prompt token is recomputed — the final
            # chunk's logits seed the first sampled token
            matched = self.prefix.match(req.prompt,
                                        max_blocks=(eff - 1) // ps)
        m_tok = len(matched) * ps
        # exact budget cost: one compiled-width chunk, +1 decode lane if
        # that chunk already completes the prompt (the slot joins decode
        # this very step)
        cost = self._chunk_len + (1 if eff - m_tok <= self._chunk_len
                                  else 0)
        need = -(-(eff + max(state.budget, 0)) // ps) - len(matched)
        fresh = self.pool.alloc(need) if budget.can(cost) else None
        if fresh is None:
            # pool pressure (or the exact budget cost no longer fits):
            # roll the match back (refs AND counters, so retries don't
            # inflate hit stats), put the request back at the queue
            # head, retry when a retirement frees pages / budget
            if self.prefix is not None:
                self.prefix.release_match(matched, (eff - 1) // ps)
            self.sched.queue.appendleft(req)
            if not budget.can(cost):
                self.sched.stats.budget_deferred_admissions += 1
            return None
        state.seed = lane_seed(state.sampling.seed, self._base_seed,
                               req.uid)
        budget.take(cost)
        slot = self.sched.admit(state)
        self._set_lane(slot, state)
        self.tel.request_admitted(req.uid)
        row = matched + fresh
        self._row_pages[slot] = row
        self.slots.set_row(slot, row + [self._parked[slot]] * (nb - len(row)),
                           m_tok)
        self._prefill_jobs[slot] = _PrefillJob(req=req, state=state,
                                               next=m_tok,
                                               matched_tokens=m_tok,
                                               prepaid=True)
        self._prompt_tokens_total += eff
        self._prefix_hit_tokens += m_tok
        return []

    def _advance_prefill(self, slot: int) -> List[Result]:
        """Run one prefill chunk for a mid-admission slot; on the final
        chunk, sample the first token and (maybe) retire."""
        job = self._prefill_jobs[slot]
        eff = job.state.prompt_len
        c = self._chunk_len
        start = job.next
        length = min(c, eff - start)
        tokens = np.zeros((1, c), np.int32)
        tokens[0, :length] = job.req.prompt[start:start + length]
        final = start + length >= eff
        want_lp = final and job.state.sampling.logprobs is not None
        t0 = time.perf_counter()
        with self.tel.entry("prefill_chunk", (1, c)):
            # non-final chunks discard the sampled token — the lane
            # arrays still ride along so the compiled shape is uniform
            (tok, lpd), self.slots.cache = self._chunk(
                self.params, jnp.asarray(tokens), self.slots.cache,
                jnp.int32(slot), jnp.int32(start), jnp.int32(length),
                self._lanes_for(job.state, 0), want_lp)
            if final:
                first = int(jax.device_get(tok)[0, 0])
            elif self.tel.sync:
                jax.block_until_ready(tok)
        t1 = time.perf_counter()
        job.state.t_prefill += t1 - t0
        self.tel.request_prefill(job.req.uid, start // c, t0, t1)
        job.next = start + length
        self._prefill_chunks += 1
        self._prefill_tokens_computed += length
        if not final:
            return []
        del self._prefill_jobs[slot]
        if self.prefix is not None:
            # register the prompt's *full* blocks (a partial tail block
            # will also hold this request's decode tokens — unshareable)
            self.prefix.insert(job.req.prompt,
                               self._row_pages[slot][:eff // self.page_size])
        if job.state.budget <= 0:
            # degenerate max_new_tokens=0 — same semantics as unpaged
            job.state.finish_reason = "length"
            return [self._finish(slot)]
        self._tok = self._tok.at[slot, 0].set(first)
        info = None
        if lpd is not None:
            ch, tl, ti = jax.device_get(lpd)
            info = self._lp_entry(job.state, ch[0], tl[0], ti[0])
        done = self._record(slot, first, info)
        self.tel.request_first_token(job.req.uid)
        if done:
            return [self._finish(slot)]
        return []

    def _admit_one(self, budget: StepBudget) -> Optional[List[Result]]:
        """Prefill the next queued request into a free slot (if any)."""
        if self.sc.paged:
            return self._admit_paged(budget)
        if not self.sched.queue or self.sched.table.n_free == 0:
            return None
        # one compiled-width prefill dispatch + the decode lane the new
        # slot occupies this very step
        if not budget.try_take(self.prefill_len + 1):
            self.sched.stats.budget_deferred_admissions += 1
            return None
        nxt = self.sched.next_admission()
        req, state = nxt
        state.seed = lane_seed(state.sampling.seed, self._base_seed,
                               req.uid)
        self.tel.request_admitted(req.uid)
        eff = state.prompt_len + self._n_vis
        state.budget = min(state.budget, self.sc.max_len - eff)

        prompts = np.zeros((1, self.prefill_len), np.int32)
        prompts[0, :state.prompt_len] = req.prompt
        t0 = time.perf_counter()
        # the pristine zero template goes in; a fresh populated copy comes
        # out (never fed back — that would leak recurrent state between
        # consecutive admissions through this buffer)
        with self.tel.entry("prefill", prompts.shape):
            (first, lpd), pf_cache = self._prefill(
                self.params, self._batch_for(prompts),
                self.slots.prefill_cache, jnp.asarray([eff], jnp.int32),
                self._lanes_for(state, 0),
                state.sampling.logprobs is not None)
            first = int(jax.device_get(first)[0, 0])
        t1 = time.perf_counter()
        self.tel.request_prefill(req.uid, 0, t0, t1)

        slot = self.sched.admit(state)
        self._set_lane(slot, state)
        state.t_prefill = t1 - t0
        if state.budget <= 0:
            # degenerate max_new_tokens=0: the prefill token is dropped so
            # both schedulers agree on "0 new tokens" (bucketed truncates
            # to the budget); the slot frees on the same step
            state.finish_reason = "length"
            return [self._finish(slot)]
        self.slots.admit(pf_cache, slot)
        self._tok = self._tok.at[slot, 0].set(first)
        info = None
        if lpd is not None:
            ch, tl, ti = jax.device_get(lpd)
            info = self._lp_entry(state, ch[0], tl[0], ti[0])
        done = self._record(slot, first, info)
        self.tel.request_first_token(req.uid)
        if done:
            return [self._finish(slot)]
        return []

    def _finish(self, slot: int) -> Result:
        state = self.sched.retire(slot)
        self._clear_lane(slot)
        if self.sc.paged:
            # release the slot's pages (tree-registered prompt blocks go
            # cold/retained; private blocks free) and park the row so
            # the lockstep decode write stays harmless
            self.pool.decref(self._row_pages.pop(slot, []))
            self.slots.set_row(
                slot, [self._parked[slot]] * self.slots.n_blocks, 0)
        now = time.perf_counter()
        toks = np.asarray(state.tokens, np.int32)
        # None (not 0.0) when the event never happened: a request that
        # retired without decoding must not look like it decoded
        # instantly, and a missing submit stamp must not fake latency
        ft = state.t_first_token or None
        decode_s = now - ft if ft else None
        ttft_s = ft - state.t_submit if ft and state.t_submit else None
        latency_s = now - state.t_submit if state.t_submit else None
        self.tel.request_retired(state.uid, len(toks), ttft_s, latency_s,
                                 decode_s)
        return Result(
            uid=state.uid, tokens=toks,
            prefill_s=getattr(state, "t_prefill", 0.0) or None,
            decode_s=decode_s, ttft_s=ttft_s, latency_s=latency_s,
            finish_reason=state.finish_reason)

    def abort(self, uid: int) -> Optional[Result]:
        """Cancel a request anywhere in its lifecycle and free its
        resources immediately. Queued: removed before admission.
        Mid-chunked-prefill: the ``_PrefillJob`` is dropped and the
        slot's pages decref'd — prefix-matched pages lose the reference
        the match took, fresh pages free — so a cancel before the first
        token never leaks a refcount. Decoding: the slot retires as if
        the request finished, with the tokens generated so far. Returns
        the (partial) :class:`Result` with ``finish_reason="abort"``, or
        ``None`` when the uid is unknown (already finished or never
        submitted)."""
        if self.sc.scheduler != "continuous":
            raise RuntimeError("abort() needs scheduler='continuous'")
        for i, req in enumerate(self.sched.queue):
            if req.uid == uid:
                del self.sched.queue[i]
                self.sched.stats.aborted += 1
                self.tel.request_retired(uid, 0, None, None, None)
                return Result(uid=uid, tokens=np.zeros((0,), np.int32),
                              finish_reason="abort")
        for slot, state in list(self.sched.table.active.items()):
            if state.uid == uid:
                if self.sc.paged:
                    # a mid-prefill cancel: the job dies here; _finish
                    # releases the mapped pages and re-parks the row
                    self._prefill_jobs.pop(slot, None)
                self.sched.stats.aborted += 1
                state.finish_reason = "abort"
                return self._finish(slot)
        return None

    def step(self) -> List[Result]:
        """Open this step's token-budget ledger, admit queued requests
        while budget and slots allow, advance in-flight chunked prefills
        (paged; oldest-admitted first, each chunk charged against the
        budget), then run one decode step over the decoding slots.
        Returns requests finished now."""
        if self.sc.scheduler != "continuous":
            raise RuntimeError("step() needs scheduler='continuous'")
        tel = self.tel
        tel.step_begin()
        finished: List[Result] = []
        with tel.phase("budget"):
            # charge the lanes already decoding (active minus mid-
            # prefill) — they run regardless; admissions/chunks below
            # compete for what's left
            n_jobs = len(self._prefill_jobs) if self.sc.paged else 0
            budget = self.sched.begin_step(
                self.sched.table.n_active - n_jobs)
            if self.sc.paged and self.sc.free_watermark > 0.0:
                self.pool.ensure_free(
                    int(self.sc.free_watermark * self.pool.n_pages))
        with tel.phase("admission"):
            while True:
                done = self._admit_one(budget)
                if done is None:
                    break
                finished.extend(done)

        if self.sc.paged:
            # one chunk per prefilling slot per step — oldest admission
            # first, so FIFO order also bounds prefill wait — with each
            # dispatch charged at its compiled width (+1 when the final
            # chunk promotes the slot to decode this step); jobs the
            # budget cannot cover resume on a later step
            with tel.phase("prefill"):
                jobs = sorted(self._prefill_jobs.items(),
                              key=lambda kv: kv[1].state.t_admit)
                for slot, job in jobs:
                    if job.prepaid:
                        job.prepaid = False
                    else:
                        eff = job.state.prompt_len
                        cost = self._chunk_len + (
                            1 if eff - job.next <= self._chunk_len else 0)
                        if not budget.try_take(cost):
                            self.sched.stats.budget_capped_chunks += 1
                            continue
                    finished.extend(self._advance_prefill(slot))
            decoding = [s for s in self.sched.table.active_slots()
                        if s not in self._prefill_jobs]
        else:
            decoding = self.sched.table.active_slots()
        if not decoding:
            tel.step_end(0)
            self._sanitize()
            return finished

        k_round = (self._spec_k_for(decoding, budget)
                   if self.sc.speculative else 0)
        if k_round:
            finished.extend(self._spec_round(decoding, k_round))
            self.sched.note_decode_step(len(decoding))
            tel.step_end(len(decoding))
            self._sanitize()
            return finished

        # drift monitor: keep references to the *pre-step* token/cache —
        # the decode jit is functional (nothing donated), so they stay
        # valid for the shadow probe dispatched after the transfer
        drift_in = ((self._tok, self.slots.cache)
                    if self._drift_due() else None)
        with tel.phase("decode"), tel.entry("decode", self._tok.shape):
            (self._tok, lpd), self.slots.cache = self._decode(
                self.params, self._tok, self.slots.cache,
                self._decode_lanes(), self._want_lp)
            if tel.sync:
                # fence: device time stays in this phase instead of
                # hiding inside the next host transfer
                jax.block_until_ready(self._tok)
        self.sched.note_decode_step(len(decoding))
        with tel.phase("transfer"):
            toks = np.asarray(jax.device_get(self._tok))[:, 0]
            lp_host = jax.device_get(lpd) if lpd is not None else None
        self._host_guard(toks, decoding)
        if drift_in is not None:
            self._observe_drift(drift_in[0], drift_in[1], decoding)
        for slot in decoding:
            info = None
            if lp_host is not None:
                info = self._lp_entry(self.sched.table.active[slot],
                                      lp_host[0][slot], lp_host[1][slot],
                                      lp_host[2][slot])
            if self._record(slot, toks[slot], info):
                finished.append(self._finish(slot))
        tel.step_end(len(decoding))
        self._sanitize()
        return finished

    def _sanitize(self) -> None:
        """Post-step invariant audit (``ServeConfig(sanitize=True)``):
        raises :class:`~repro.serve.sanitizer.SanitizerError` when the
        host bookkeeping and device state disagree. Read-only — a
        sanitized engine emits exactly the tokens a bare one does."""
        if self._san is not None:
            self._san.check(self)

    # ------------------------------------------------------------------
    # Accuracy-drift monitor (ServeConfig(drift_monitor=True))
    # ------------------------------------------------------------------
    def _drift_due(self) -> bool:
        """Deterministic sampling cadence over plain decode steps: the
        decision depends only on the step counter, never on tokens, so a
        monitored run replays identically."""
        if self._drift_probe is None:
            return False
        due = self._drift_step % self._drift_every == 0
        self._drift_step += 1
        return due

    def _observe_drift(self, token, cache, decoding: List[int]) -> None:
        """Shadow-compare this step's serving logits against the
        reference lowering and fold the per-lane divergences into the
        registry. Read-only: the probe's cache outputs are discarded."""
        out = self._drift_probe(self.params, token, cache)
        with jax.named_scope("drift_probe"):
            # fence: the probe's sync is the sampled monitoring cost, not
            # part of the serving step's transfer budget
            kl, agree, delta, bad = map(np.asarray, jax.device_get(out))
        for slot in decoding:
            self._drift_checks += 1
            self._drift_agree += int(agree[slot])
            self._drift_nonfinite += int(bad[slot])
            if np.isfinite(kl[slot]):
                # tiny negative KL is float32 round-off, clamp to the
                # histogram's domain
                self._h_drift_kl.observe(max(float(kl[slot]), 0.0))
            if np.isfinite(delta[slot]):
                self._h_drift_delta.observe(float(delta[slot]))

    def _host_guard(self, toks: np.ndarray, decoding: List[int]) -> None:
        """Always-cheap sanity counter over the tokens just sampled: a
        token outside [0, vocab) means the logits went bad upstream
        (NaN/inf collapse the in-graph sample to lane garbage). Pure
        host arithmetic on an already-transferred array."""
        t = toks[decoding]
        self._guard_oob += int(np.sum((t < 0) | (t >= self.cfg.vocab)))

    # ------------------------------------------------------------------
    # Self-speculative decoding: Q-only draft, full Q+LR verify
    # ------------------------------------------------------------------
    def _spec_k_for(self, decoding: List[int],
                    budget: StepBudget) -> int:
        """Speculative-round eligibility, returning the window width k
        (0 = run plain per-token decode this step). Requires every
        decoding lane greedy — temperature lanes fall back to per-token
        decode, whose counter-based draws are per-token by construction
        — no pending post-rejection correction (``_need_plain``),
        enough per-lane budget for the up-to-(k-1) emitted drafts, and
        step-budget headroom for the extra compiled dispatches: (k-1)
        draft dispatches over n lanes plus n verify chunks of width k,
        beyond the one decode already charged at begin_step."""
        if self._need_plain:
            self._need_plain = False
            return 0
        active = self.sched.table.active
        k = self.sc.spec_k
        for s in decoding:
            st = active[s]
            if st.sampling.temperature > 0.0:
                return 0
            # a round emits at most k-1 tokens for this lane
            k = min(k, st.budget - len(st.tokens) + 1)
        if k < 2:
            return 0
        n = len(decoding)
        if not budget.try_take((k - 1) * n + k * n):
            return 0
        return k

    def _verify_lane(self, state):
        """Scalar lane tuple for one verify chunk: the request's
        sampling controls plus its next token index (position j of the
        chunk samples with counter key idx0 + j)."""
        sp = state.sampling
        return (jnp.float32(sp.temperature), jnp.float32(sp.top_p),
                jnp.int32(sp.top_k), jnp.int32(state.seed),
                jnp.int32(len(state.tokens)))

    def _spec_round(self, decoding: List[int], k: int) -> List[Result]:
        """One self-speculative round over the (all-greedy) decoding
        lanes: k-1 Q-only draft steps chain through the lockstep decode
        graph, then one full-model verify chunk per lane re-scores
        [last token ‖ drafts] — read-only over the KV storage, see
        :func:`verify_chunk` — and the longest draft prefix matching
        the verify model's predictions is accepted. Only those accepted
        drafts are emitted: the verify model's own next token (the
        classic correction/bonus token) is deliberately NOT taken from
        the chunk. A chunk computes attention with a different float
        reduction order than the per-token decode graph, so its argmax
        can flip on near-tied logits — emitting it would make spec
        output diverge from non-speculative decode on exactly those
        ties. Instead the round marks the engine for one plain decode
        step (``_need_plain``) whenever any lane rejected, and the
        correction token comes out of the step graph itself; a fully
        accepting lane just lets the next round's verify position 0
        re-score what would have been its bonus token. Greedy spec
        output is therefore token-identical to non-speculative decode
        by construction, not by numerical luck. Positions rewind to
        p + n_emitted; rejected-tail KV lives in pages the request
        already owns (pre-allocated at admission), so no page alloc or
        decref happens inside a round — refcounts are conserved by
        construction and the stale tail is masked dead by the pos
        predicate until the next write lands there."""
        tel = self.tel
        sc = self.sc
        active = self.sched.table.active
        states = {s: active[s] for s in decoding}
        # next-write slot per lane: pos = prompt(+vision) + generated - 1
        p0 = {s: states[s].prompt_len + self._n_vis
              + len(states[s].tokens) - 1 for s in decoding}
        lanes = self._decode_lanes()
        with tel.phase("decode"), \
                tel.entry("draft", (k - 1,) + tuple(self._tok.shape)):
            drafts, self.slots.cache = self._draft_span(
                self.params, self._tok, self.slots.cache, lanes, k)
        results: List[Result] = []
        b = sc.decode_batch
        mask = np.zeros((b,), bool)
        newpos = np.zeros((b,), np.int32)
        n_accepted = 0
        with tel.phase("verify"):
            # (k-1, B, 1) scan stack → (B, k-1) host table
            draft_host = np.asarray(jax.device_get(drafts))[:, :, 0].T
            verify = {}
            for s in decoding:
                st = states[s]
                fed = np.zeros((1, sc.spec_k), np.int32)
                fed[0, 0] = st.tokens[-1]
                fed[0, 1:k] = draft_host[s, :k - 1]
                with tel.entry("verify", (1, sc.spec_k)):
                    verify[s], self.slots.cache = self._verify(
                        self.params, jnp.asarray(fed), self.slots.cache,
                        jnp.int32(s), jnp.int32(p0[s]), jnp.int32(k),
                        self._verify_lane(st),
                        st.sampling.logprobs is not None)
        with tel.phase("transfer"):
            tok_host = np.asarray(jax.device_get(self._tok)).copy()
            hosted = {s: (np.asarray(jax.device_get(tv)),
                          jax.device_get(lpd) if lpd is not None else None)
                      for s, (tv, lpd) in verify.items()}
        for s in decoding:
            st = states[s]
            tgt, lp_host = hosted[s]
            # acceptance: draft j survives while it matches the verify
            # model's prediction at the same position — the greedy-
            # speculative rule. An accepted draft IS the verify token
            # (they compared equal), so emitting tgt[j] below emits the
            # draft, with the chunk's logprob row for that position.
            n_acc = 1
            while n_acc < k and draft_host[s, n_acc - 1] == tgt[n_acc - 1]:
                n_acc += 1
            n_accepted += n_acc - 1
            self._h_accept.observe(n_acc - 1)
            if n_acc < k:
                # a rejected draft would be re-proposed verbatim next
                # round (drafting is deterministic); the correction
                # must come from a plain step-graph decode
                self._need_plain = True
            rec = 0
            done = False
            for j in range(n_acc - 1):
                info = None
                if lp_host is not None:
                    info = self._lp_entry(st, lp_host[0][j],
                                          lp_host[1][j], lp_host[2][j])
                rec += 1
                # a stop token inside the accepted window truncates
                # here — tokens past it are never recorded, matching
                # non-speculative retirement exactly
                if self._record(s, int(tgt[j]), info):
                    done = True
                    break
            if rec:
                tok_host[s, 0] = int(tgt[rec - 1])
            if done:
                # _finish re-parks the row at pos 0 — keep the lane out
                # of the rewind so the reset sticks instead of being
                # overwritten with the stale frontier
                results.append(self._finish(s))
            else:
                mask[s] = True
                newpos[s] = p0[s] + rec
        with tel.phase("verify"):
            self._tok = jnp.asarray(tok_host)
            self.slots.cache = self._rewind(
                self.slots.cache, jnp.asarray(mask), jnp.asarray(newpos))
        self._spec_rounds += 1
        self._spec_draft_tokens += (k - 1) * len(decoding)
        self._spec_accepted_tokens += n_accepted
        return results

    def drain(self) -> List[Result]:
        """Run step() until queue and slots are empty; results by uid."""
        if self.sc.scheduler != "continuous":
            raise RuntimeError("drain() needs scheduler='continuous'")
        results: List[Result] = []
        while self.sched.has_work:
            results.extend(self.step())
        results.sort(key=lambda r: r.uid)
        return results

    def _collect(self) -> MetricsRegistry:
        """Publish every live component's series into the registry and
        return it — the single collection path behind ``stats()``,
        ``metrics()``, and ``prometheus()``. Both scheduler modes emit
        the same common key set (bucketed counts admissions/retirements
        too), so downstream consumers never branch on scheduler type;
        the paged engine adds page-pool, prefix-cache, and
        chunked-prefill work accounting, and an enabled telemetry
        recorder adds latency/phase histograms + compile tracking."""
        reg = self.registry
        s = (self._bucket_stats if self.sc.scheduler == "bucketed"
             else self.sched.stats)
        s.publish(reg)
        if self.sc.paged:
            self.pool.publish(reg)
            if self.prefix is not None:
                self.prefix.publish(reg)
            hit = self._prefix_hit_tokens
            total = self._prompt_tokens_total
            reg.counter("prefill_chunks", "chunked-prefill dispatches"
                        ).set(self._prefill_chunks)
            reg.counter("prefill_tokens_computed",
                        "prompt tokens actually prefilled"
                        ).set(self._prefill_tokens_computed)
            reg.counter("prompt_tokens_total", "prompt tokens submitted"
                        ).set(total)
            reg.counter("prefix_hit_tokens",
                        "prompt tokens served from the prefix cache"
                        ).set(hit)
            reg.gauge("prefix_hit_rate", "prefix_hit_tokens / "
                      "prompt_tokens_total"
                      ).set(round(hit / total, 4) if total else 0.0)
        # speculative counters are part of the uniform key set (zeros
        # when the mode is off) so dashboards never branch on config
        reg.counter("spec_rounds", "self-speculative rounds executed"
                    ).set(self._spec_rounds)
        reg.counter("spec_draft_tokens", "Q-only draft tokens proposed"
                    ).set(self._spec_draft_tokens)
        reg.counter("spec_accepted_tokens",
                    "draft tokens accepted by the Q+LR verify"
                    ).set(self._spec_accepted_tokens)
        reg.gauge("spec_acceptance_rate",
                  "spec_accepted_tokens / spec_draft_tokens").set(
            round(self._spec_accepted_tokens / self._spec_draft_tokens, 4)
            if self._spec_draft_tokens else 0.0)
        # drift-monitor counters follow the same uniform-key-set rule
        reg.counter("drift_checks",
                    "per-lane shadow comparisons executed"
                    ).set(self._drift_checks)
        reg.counter("drift_top1_agree",
                    "shadow comparisons whose argmax matched the "
                    "reference lowering").set(self._drift_agree)
        reg.counter("drift_nonfinite",
                    "non-finite logit elements seen by the drift probe"
                    ).set(self._drift_nonfinite)
        reg.counter("guard_token_oob",
                    "sampled tokens outside [0, vocab) — upstream "
                    "logit corruption").set(self._guard_oob)
        reg.gauge("drift_top1_agreement_rate",
                  "drift_top1_agree / drift_checks").set(
            round(self._drift_agree / self._drift_checks, 4)
            if self._drift_checks else 1.0)
        self.tel.publish()
        return reg

    def stats(self) -> Dict[str, float]:
        """One uniform registry snapshot across scheduler modes —
        legacy keys preserved (``admitted``/``retired``/``eos_retired``
        /``decode_steps``/``occupancy`` everywhere; page-pool, prefix
        and chunk accounting under the paged engine; telemetry
        histograms as nested summaries when enabled)."""
        return self._collect().snapshot()

    # ``metrics()`` is the serving-convention alias
    metrics = stats

    def prometheus(self) -> str:
        """Prometheus text exposition of the same registry snapshot."""
        return self._collect().prometheus()

    def write_trace(self, path: str, jsonl_path: Optional[str] = None) -> str:
        """Export the Chrome trace-event JSON (Perfetto-loadable); with
        ``jsonl_path``, also the flat JSONL event stream. Needs
        ``ServeConfig(telemetry=True)``."""
        if not self.tel.enabled:
            raise RuntimeError("trace export needs ServeConfig("
                               "telemetry=True)")
        out = self.tel.tracer.write_chrome(path)
        if jsonl_path:
            self.tel.tracer.write_jsonl(jsonl_path)
        return out

    def reset_stats(self) -> None:
        """Start a fresh measurement window — histograms, counters,
        pool/prefix stats, trace — without touching scheduler state or
        compiled shapes. ``generate()`` calls this implicitly; callers
        driving ``submit()``/``step()`` directly (benchmarks timing
        repeated runs on one warmed engine) call it between runs."""
        self._reset_stats()

    def _reset_stats(self) -> None:
        if self.sched is not None:
            self.sched.stats = type(self.sched.stats)(
                n_slots=self.sc.decode_batch)
        self._bucket_stats = SchedulerStats(n_slots=self.sc.decode_batch)
        if self.sc.paged:
            self.pool.reset_stats()
            if self.prefix is not None:
                self.prefix.reset_stats()
            self._prefill_chunks = 0
            self._prefill_tokens_computed = 0
            self._prompt_tokens_total = 0
            self._prefix_hit_tokens = 0
        self._spec_rounds = 0
        self._spec_draft_tokens = 0
        self._spec_accepted_tokens = 0
        self._drift_checks = 0
        self._drift_agree = 0
        self._drift_nonfinite = 0
        self._guard_oob = 0
        # histogram samples reset even with telemetry off — the
        # acceptance histogram is registry-resident either way
        self.registry.reset_histograms()
        # fresh trace + histograms per measured run (compile accounting
        # survives — it describes the engine session)
        self.tel.reset_run()

    def warmup(self) -> None:
        """Trigger the compiles (prefill + decode; + draft/verify/rewind
        under speculative mode — the dummy's budget covers one full-k
        round) with a dummy request so steady-state timing excludes
        compilation. Counters are reset afterwards — the dummy never
        shows in stats()."""
        if self.sc.scheduler != "continuous":
            return
        # speculative: spec_k + 1 covers one full-k round plus a
        # clamped k=2 round for the leftover token
        mnt = self.sc.spec_k + 1 if self.sc.speculative else 2
        dummy = Request(uid=-1, prompt=np.zeros((1,), np.int32),
                        max_new_tokens=mnt)
        self.submit(dummy)
        while self.sched.has_work:
            self.step()
        if self.sc.speculative:
            # the dummy run only exercises k = spec_k; the clamped
            # variants (a lane close to its token budget shrinks the
            # round) would otherwise compile mid-serve, which a short
            # benchmark reads as a 100x throughput cliff. jit is pure:
            # call each variant on the idle state and drop the result
            lanes = self._decode_lanes()
            for kk in range(2, self.sc.spec_k + 1):
                jax.block_until_ready(self._draft_span(
                    self.params, self._tok, self.slots.cache, lanes, kk)[0])
            # post-rejection correction tokens come from the plain
            # decode path, which a fully-accepting dummy run never
            # touches — compile it here so the first rejection
            # mid-serve doesn't stall on a compile
            jax.block_until_ready(self._decode(
                self.params, self._tok, self.slots.cache, lanes,
                False)[0][0])
        self._reset_stats()

    # ==================================================================
    # Bucketed baseline (dry-run-grade scheduler)
    # ==================================================================
    def _bucket_lanes(self, reqs: List[Request], seeds: List[int],
                      idx: int):
        """(B,) lane arrays for one bucket dispatch at token ``idx`` —
        same counter-based streams as the continuous engine, so the two
        schedulers agree token-for-token per request. Padding lanes
        ride greedy."""
        b = self.sc.decode_batch
        temps = np.zeros((b,), np.float32)
        top_ps = np.ones((b,), np.float32)
        top_ks = np.zeros((b,), np.int32)
        sds = np.zeros((b,), np.int32)
        for i, r in enumerate(reqs):
            temps[i] = r.params.temperature
            top_ps[i] = r.params.top_p
            top_ks[i] = r.params.top_k
            sds[i] = seeds[i]
        return (jnp.asarray(temps), jnp.asarray(top_ps),
                jnp.asarray(top_ks), jnp.asarray(sds),
                jnp.full((b,), idx, jnp.int32))

    def _run_bucket(self, reqs: List[Request],
                    base_seed: int) -> List[Result]:
        sc = self.sc
        b = sc.decode_batch
        plen = len(reqs[0].prompt)
        assert all(len(r.prompt) == plen for r in reqs)
        prompts = np.zeros((b, plen), np.int32)
        stops: List[frozenset] = []
        seeds: List[int] = []
        for i, r in enumerate(reqs):
            prompts[i] = r.prompt
            st = frozenset(r.params.stop)
            if sc.eos_id >= 0:
                st = st | {sc.eos_id}
            stops.append(st)
            seeds.append(lane_seed(r.params.seed, base_seed, r.uid))

        t0 = time.perf_counter()
        cache = self._init_cache()
        # first token goes through the same per-lane sampling path as
        # decode (token index 0, like the continuous engine's prefill)
        (tok, _), cache = self._prefill(self.params,
                                        self._batch_for(prompts),
                                        cache, None,
                                        self._bucket_lanes(reqs, seeds, 0),
                                        False)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()

        budget = max(self._req_budget(r) for r in reqs)
        budget = min(budget, sc.max_len - plen - self._n_vis)
        out = np.zeros((b, budget), np.int32)
        done = np.zeros((b,), bool)
        n = 0
        for step in range(budget):
            out[:, step] = np.asarray(tok[:, 0])
            for i in range(len(reqs)):
                done[i] |= int(out[i, step]) in stops[i]
            n = step + 1
            if done[:len(reqs)].all():
                break
            # a lane is useful only while its (real) request still needs
            # tokens — padding rows and early-stop rows ride along wasted
            self._bucket_stats.decode_steps += 1
            self._bucket_stats.decode_slot_steps += sum(
                1 for i, r in enumerate(reqs)
                if not done[i]
                and step < self._req_budget(r))
            # token index step+1: out[:, step] was token `step`
            (tok, _), cache = self._decode(
                self.params, tok, cache,
                self._bucket_lanes(reqs, seeds, step + 1), False)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()

        results = []
        self._bucket_stats.admitted += len(reqs)
        self._bucket_stats.retired += len(reqs)
        for i, r in enumerate(reqs):
            toks = out[i, :n]
            # stop truncation first (stop wins over budget on the same
            # token — continuous semantics), then the per-request budget
            cut = next((j for j in range(len(toks))
                        if int(toks[j]) in stops[i]), None)
            if cut is not None:
                toks = toks[:cut + 1]
            lim = self._req_budget(r)
            lim = min(lim, sc.max_len - plen - self._n_vis)
            toks = toks[:lim]
            stopped = (cut is not None and cut < lim)
            finish = "stop" if stopped else "length"
            if stopped and sc.eos_id >= 0 and toks[-1] == sc.eos_id:
                self._bucket_stats.eos_retired += 1
            since = r.t_submit or t0     # queue wait counts toward latency
            results.append(Result(uid=r.uid, tokens=toks,
                                  prefill_s=t1 - t0, decode_s=t2 - t1,
                                  ttft_s=t1 - since,
                                  latency_s=t2 - since,
                                  finish_reason=finish))
        return results

    def _generate_bucketed(self, requests: Sequence[Request],
                           seed: int) -> List[Result]:
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        results: List[Result] = []
        for plen in sorted(buckets):
            queue = buckets[plen]
            for i in range(0, len(queue), self.sc.decode_batch):
                results.extend(
                    self._run_bucket(queue[i: i + self.sc.decode_batch],
                                     seed))
        results.sort(key=lambda r: r.uid)
        return results

    # ==================================================================
    def generate(self, requests: Sequence[Request],
                 seed: int = 0) -> List[Result]:
        """Run all requests through the configured scheduler. Each call
        is a fresh run: sampling stream re-seeded, stats() reset, and
        submission timestamps re-stamped (so reusing Request objects
        across runs cannot inflate latency metrics)."""
        now = time.perf_counter()
        for r in requests:
            self._validate(r)
            r.params = self._resolve(r)
            r.t_submit = now
        self._reset_stats()
        if self.sc.scheduler == "bucketed":
            return self._generate_bucketed(requests, seed)
        self._base_seed = seed
        for r in requests:
            self.submit(r)
        out = self.drain()
        self.tel.stop_profiler()     # a short run may never hit the
        # profile_steps threshold; don't leave the capture open
        return out
