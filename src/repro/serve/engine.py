"""Serving engine: batched prefill + decode over a (quantized) model.

The engine serves the paper's deployment artifact — a ``Q + LR`` model —
through the same forward code paths the dry-run lowers at pod scale:

  * **prefill** processes the whole prompt through ``models.prefill``
    (blockwise attention, no S×S materialization) and populates the
    contiguous KV cache;
  * **decode** batches one ``decode_step`` per new token across requests;
  * **int8 KV** (``kv_dtype="int8"``) halves cache HBM — the
    quantization-native option that makes 32k-context MHA models fit.

Scheduling: requests queue up and are grouped into fixed-size decode
batches *bucketed by prompt length* (the KV cache tracks one scalar
write position per batch, so co-batched prompts must align; production
slot-level continuous batching with per-slot positions is a documented
extension, not needed for dry-run-grade serving). Short buckets are
padded up to ``decode_batch`` with dummy rows so every compiled shape is
stable (two compilations total: one prefill, one decode).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Ctx, decode_step, init_cache, prefill


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512               # cache slots (prompt + generation)
    decode_batch: int = 8
    max_new_tokens: int = 64
    eos_id: int = -1                 # -1: never stop early
    kv_dtype: str = "bf16"           # bf16 | f32 | int8
    temperature: float = 0.0         # 0 = greedy
    compute_dtype: str = "f32"


_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32, "int8": jnp.int8}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (L,) int32
    max_new_tokens: Optional[int] = None


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray               # generated tokens (without prompt)
    prefill_s: float
    decode_s: float


class Engine:
    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None):
        self.params = params
        self.cfg = cfg
        self.sc = sc
        self.extra = extra_inputs or {}
        self.ctx = Ctx(compute_dtype=_DTYPES[sc.compute_dtype])

        cdt = _DTYPES[sc.kv_dtype]
        self._init_cache = lambda: init_cache(
            cfg, sc.decode_batch, sc.max_len, dtype=cdt)

        ctx = self.ctx

        def _prefill(params, batch, cache):
            return prefill(ctx, params, batch, cfg, cache)

        def _decode(params, token, cache, key):
            logits, cache = decode_step(ctx, params, token, cache, cfg)
            logits = logits[:, -1].astype(jnp.float32)
            if sc.temperature > 0:
                tok = jax.random.categorical(key, logits / sc.temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            return tok.astype(jnp.int32)[:, None], cache

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------
    def _batch_for(self, prompts: np.ndarray) -> Dict[str, jax.Array]:
        b, s = prompts.shape
        batch: Dict[str, jax.Array] = {"tokens": jnp.asarray(prompts)}
        if self.cfg.is_encoder_decoder:
            frames = self.extra.get("frames")
            if frames is None:
                frames = np.zeros(
                    (b, self.cfg.enc_seq, self.cfg.d_frontend), np.float32)
            batch["frames"] = jnp.asarray(frames[:b])
        if self.cfg.n_vision_tokens:
            vis = self.extra.get("vision")
            if vis is None:
                vis = np.zeros((b, self.cfg.n_vision_tokens,
                                self.cfg.d_frontend or self.cfg.d_model),
                               np.float32)
            batch["vision"] = jnp.asarray(vis[:b])
        return batch

    def _run_bucket(self, reqs: List[Request], key: jax.Array) -> List[Result]:
        sc = self.sc
        b = sc.decode_batch
        plen = len(reqs[0].prompt)
        assert all(len(r.prompt) == plen for r in reqs)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i] = r.prompt

        t0 = time.perf_counter()
        cache = self._init_cache()
        logits, cache = self._prefill(self.params, self._batch_for(prompts),
                                      cache)
        first = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        tok = first.astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t1 = time.perf_counter()

        budget = max((r.max_new_tokens or sc.max_new_tokens) for r in reqs)
        budget = min(budget, sc.max_len - plen)
        out = np.zeros((b, budget), np.int32)
        done = np.zeros((b,), bool)
        n = 0
        for step in range(budget):
            out[:, step] = np.asarray(tok[:, 0])
            done |= out[:, step] == sc.eos_id
            n = step + 1
            if done[:len(reqs)].all():
                break
            key, sub = jax.random.split(key)
            tok, cache = self._decode(self.params, tok, cache, sub)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()

        results = []
        for i, r in enumerate(reqs):
            toks = out[i, :n]
            if sc.eos_id >= 0 and (toks == sc.eos_id).any():
                toks = toks[: int(np.argmax(toks == sc.eos_id)) + 1]
            lim = r.max_new_tokens or sc.max_new_tokens
            results.append(Result(uid=r.uid, tokens=toks[:lim],
                                  prefill_s=t1 - t0, decode_s=t2 - t1))
        return results

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request],
                 seed: int = 0) -> List[Result]:
        """Run all requests: bucket by prompt length, batch, decode."""
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        results: List[Result] = []
        key = jax.random.PRNGKey(seed)
        for plen in sorted(buckets):
            queue = buckets[plen]
            for i in range(0, len(queue), self.sc.decode_batch):
                key, sub = jax.random.split(key)
                results.extend(
                    self._run_bucket(queue[i: i + self.sc.decode_batch], sub))
        results.sort(key=lambda r: r.uid)
        return results
