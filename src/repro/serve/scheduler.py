"""Request scheduler for continuous batching.

Policy (vLLM-style, minus preemption — slots are sized so an admitted
request always fits ``max_len``):

  * FIFO admission: whenever a slot is free and the queue is non-empty,
    the next request is prefilled *immediately* (prefill-on-admit) and
    its slot joins the decode batch on the very next step.
  * Decode runs every step over all slots in lockstep (one compiled
    shape); retired/empty slots ride along masked — their lanes compute
    garbage that nothing reads.
  * Retirement: a request leaves its slot as soon as it hits its own
    ``max_new_tokens`` or emits a stop token (EOS or any id in its
    ``SamplingParams.stop``); the slot is handed to the next queued
    request on the same engine step. The reason lands on
    ``SlotState.finish_reason`` (``"stop"`` / ``"length"``; the engine
    stamps ``"abort"`` on cancellation).
  * Token budget (``max_step_tokens``, optional): each step opens a
    :class:`StepBudget` ledger charged with the planned decode lanes;
    admissions and prefill-chunk dispatches then draw from the
    remainder, so ``prefill tokens + decode lanes <= max_step_tokens``
    every step and a burst of long prompts cannot stall live decode
    lanes. ``None`` keeps the unbudgeted admit-everything behavior.

The scheduler is pure host-side bookkeeping: the engine owns the device
arrays and calls in here to decide *which* request occupies *which*
slot, and *when* one is finished.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Optional, Tuple

from repro.serve.slots import SlotState, SlotTable


@dataclasses.dataclass
class SchedulerStats:
    n_slots: int = 1
    admitted: int = 0
    retired: int = 0
    eos_retired: int = 0            # retired early by EOS (freed budget)
    aborted: int = 0                # cancelled via Engine.abort()
    decode_steps: int = 0
    decode_slot_steps: int = 0      # steps × active slots (useful work)
    budget_deferred_admissions: int = 0  # admissions pushed to a later
    # step because the token budget could not cover their prefill
    budget_capped_chunks: int = 0   # prefill-chunk dispatches skipped
    # this step by the token budget (the job resumes next step)

    @property
    def occupancy(self) -> float:
        """Mean fraction of decode lanes doing useful work."""
        if self.decode_steps == 0:
            return 0.0
        return self.decode_slot_steps / (self.decode_steps * self.n_slots)

    def publish(self, reg) -> None:
        """Publish the scheduler series into a telemetry
        ``MetricsRegistry`` — the one common key set every scheduler
        mode emits (bucketed counts admissions/retirements too, so
        downstream consumers never branch on scheduler type). The
        budget counters publish unconditionally (zeros when
        ``max_step_tokens`` is off) so the snapshot schema is stable."""
        reg.counter("admitted", "requests admitted to decode lanes"
                    ).set(self.admitted)
        reg.counter("retired", "requests retired").set(self.retired)
        reg.counter("eos_retired", "requests retired early by EOS"
                    ).set(self.eos_retired)
        reg.counter("aborted", "requests cancelled via Engine.abort()"
                    ).set(self.aborted)
        reg.counter("decode_steps", "decode dispatches"
                    ).set(self.decode_steps)
        reg.counter("decode_slot_steps",
                    "decode steps x active lanes (useful work)"
                    ).set(self.decode_slot_steps)
        reg.counter("budget_deferred_admissions",
                    "admissions deferred by the token budget"
                    ).set(self.budget_deferred_admissions)
        reg.counter("budget_capped_chunks",
                    "prefill chunks deferred by the token budget"
                    ).set(self.budget_capped_chunks)
        reg.gauge("occupancy", "mean fraction of decode lanes doing "
                  "useful work").set(round(self.occupancy, 4))


class StepBudget:
    """One engine step's token ledger. ``limit=None`` is unbounded (the
    pre-budget behavior: every check passes, nothing is counted against
    anything). Decode lanes are charged unconditionally via
    :meth:`take` — a lockstep decode dispatch cannot be split — while
    admissions and chunk dispatches ask first via :meth:`can` /
    :meth:`try_take` and wait for a later step when refused."""

    def __init__(self, limit: Optional[int]):
        self.limit = limit
        self.used = 0

    def can(self, n: int) -> bool:
        return self.limit is None or self.used + n <= self.limit

    def take(self, n: int) -> None:
        self.used += n

    def try_take(self, n: int) -> bool:
        if not self.can(n):
            return False
        self.used += n
        return True


class ContinuousScheduler:
    """FIFO queue + slot table + retirement policy."""

    def __init__(self, n_slots: int, eos_id: int, default_budget: int,
                 max_step_tokens: Optional[int] = None):
        self.table = SlotTable(n_slots)
        self.eos_id = eos_id
        self.default_budget = default_budget
        self.max_step_tokens = max_step_tokens
        self.queue: Deque = collections.deque()
        self.stats = SchedulerStats(n_slots=n_slots)

    # ------------------------------------------------------------------
    def submit(self, request) -> None:
        self.queue.append(request)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.table.n_active > 0

    def begin_step(self, n_decode: int) -> StepBudget:
        """Open this step's token ledger, pre-charged with the decode
        lanes that will run regardless (they're already mid-flight)."""
        budget = StepBudget(self.max_step_tokens)
        budget.take(n_decode)
        return budget

    def next_admission(self) -> Optional[Tuple[object, SlotState]]:
        """Pop the next request if a slot is free; returns (request,
        fresh SlotState) — the engine prefills, then calls admit()."""
        if not self.queue or self.table.n_free == 0:
            return None
        req = self.queue.popleft()
        sp = getattr(req, "params", None)
        # `is not None`, not truthiness: an explicit max_new_tokens=0 is
        # a real (degenerate) budget, not a request for the default
        if sp is not None and sp.max_new_tokens is not None:
            budget = sp.max_new_tokens
        elif req.max_new_tokens is not None:
            budget = req.max_new_tokens
        else:
            budget = self.default_budget
        stop = frozenset(sp.stop) if sp is not None else frozenset()
        if self.eos_id >= 0:
            stop = stop | {self.eos_id}
        state = SlotState(uid=req.uid, prompt_len=len(req.prompt),
                          budget=budget,
                          t_submit=getattr(req, "t_submit", 0.0),
                          sampling=sp, stop=stop)
        return req, state

    def admit(self, state: SlotState) -> int:
        slot = self.table.alloc(state)
        self.stats.admitted += 1
        return slot

    # ------------------------------------------------------------------
    def record_token(self, slot: int, token: int) -> bool:
        """Append a generated token; True iff the request just finished.
        Stops (EOS or a per-request stop id) win over budget exhaustion
        when both land on the same token."""
        state = self.table.active[slot]
        if not state.tokens:
            state.t_first_token = time.perf_counter()
        state.tokens.append(int(token))
        hit_stop = int(token) in state.stop
        done = hit_stop or len(state.tokens) >= state.budget
        if done:
            state.finish_reason = "stop" if hit_stop else "length"
            if hit_stop and int(token) == self.eos_id:
                self.stats.eos_retired += 1
        return done

    def retire(self, slot: int) -> SlotState:
        self.stats.retired += 1
        return self.table.free(slot)

    def note_decode_step(self, n_useful: Optional[int] = None) -> None:
        """``n_useful`` overrides the useful-lane count for this step —
        the paged engine excludes slots still mid-chunked-prefill (they
        occupy a lane but ride the decode dispatch masked)."""
        self.stats.decode_steps += 1
        self.stats.decode_slot_steps += (self.table.n_active
                                         if n_useful is None else n_useful)
