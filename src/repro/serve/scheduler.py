"""Request scheduler for continuous batching.

Policy (vLLM-style, minus preemption — slots are sized so an admitted
request always fits ``max_len``):

  * FIFO admission: whenever a slot is free and the queue is non-empty,
    the next request is prefilled *immediately* (prefill-on-admit) and
    its slot joins the decode batch on the very next step.
  * Decode runs every step over all slots in lockstep (one compiled
    shape); retired/empty slots ride along masked — their lanes compute
    garbage that nothing reads.
  * Retirement: a request leaves its slot as soon as it hits its own
    ``max_new_tokens`` or emits ``eos_id``; the slot is handed to the
    next queued request on the same engine step.

The scheduler is pure host-side bookkeeping: the engine owns the device
arrays and calls in here to decide *which* request occupies *which*
slot, and *when* one is finished.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Optional, Tuple

from repro.serve.slots import SlotState, SlotTable


@dataclasses.dataclass
class SchedulerStats:
    n_slots: int = 1
    admitted: int = 0
    retired: int = 0
    eos_retired: int = 0            # retired early by EOS (freed budget)
    decode_steps: int = 0
    decode_slot_steps: int = 0      # steps × active slots (useful work)

    @property
    def occupancy(self) -> float:
        """Mean fraction of decode lanes doing useful work."""
        if self.decode_steps == 0:
            return 0.0
        return self.decode_slot_steps / (self.decode_steps * self.n_slots)

    def publish(self, reg) -> None:
        """Publish the scheduler series into a telemetry
        ``MetricsRegistry`` — the one common key set every scheduler
        mode emits (bucketed counts admissions/retirements too, so
        downstream consumers never branch on scheduler type)."""
        reg.counter("admitted", "requests admitted to decode lanes"
                    ).set(self.admitted)
        reg.counter("retired", "requests retired").set(self.retired)
        reg.counter("eos_retired", "requests retired early by EOS"
                    ).set(self.eos_retired)
        reg.counter("decode_steps", "decode dispatches"
                    ).set(self.decode_steps)
        reg.counter("decode_slot_steps",
                    "decode steps x active lanes (useful work)"
                    ).set(self.decode_slot_steps)
        reg.gauge("occupancy", "mean fraction of decode lanes doing "
                  "useful work").set(round(self.occupancy, 4))


class ContinuousScheduler:
    """FIFO queue + slot table + retirement policy."""

    def __init__(self, n_slots: int, eos_id: int, default_budget: int):
        self.table = SlotTable(n_slots)
        self.eos_id = eos_id
        self.default_budget = default_budget
        self.queue: Deque = collections.deque()
        self.stats = SchedulerStats(n_slots=n_slots)

    # ------------------------------------------------------------------
    def submit(self, request) -> None:
        self.queue.append(request)

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or self.table.n_active > 0

    def next_admission(self) -> Optional[Tuple[object, SlotState]]:
        """Pop the next request if a slot is free; returns (request,
        fresh SlotState) — the engine prefills, then calls admit()."""
        if not self.queue or self.table.n_free == 0:
            return None
        req = self.queue.popleft()
        # `is not None`, not truthiness: an explicit max_new_tokens=0 is
        # a real (degenerate) budget, not a request for the default
        budget = (req.max_new_tokens if req.max_new_tokens is not None
                  else self.default_budget)
        state = SlotState(uid=req.uid, prompt_len=len(req.prompt),
                          budget=budget, t_submit=getattr(req, "t_submit", 0.0))
        return req, state

    def admit(self, state: SlotState) -> int:
        slot = self.table.alloc(state)
        self.stats.admitted += 1
        return slot

    # ------------------------------------------------------------------
    def record_token(self, slot: int, token: int) -> bool:
        """Append a generated token; True iff the request just finished."""
        state = self.table.active[slot]
        if not state.tokens:
            state.t_first_token = time.perf_counter()
        state.tokens.append(int(token))
        hit_eos = self.eos_id >= 0 and int(token) == self.eos_id
        done = hit_eos or len(state.tokens) >= state.budget
        if done and hit_eos:
            self.stats.eos_retired += 1
        return done

    def retire(self, slot: int) -> SlotState:
        self.stats.retired += 1
        return self.table.free(slot)

    def note_decode_step(self, n_useful: Optional[int] = None) -> None:
        """``n_useful`` overrides the useful-lane count for this step —
        the paged engine excludes slots still mid-chunked-prefill (they
        occupy a lane but ride the decode dispatch masked)."""
        self.stats.decode_steps += 1
        self.stats.decode_slot_steps += (self.table.n_active
                                         if n_useful is None else n_useful)
