"""Slot-based KV cache: the device-side substrate for continuous batching.

The model's decode cache (``models.init_cache``) is a pytree whose every
leaf carries the batch dimension — attention K/V pages, per-(row, slot)
position maps, recurrent states, MLA latents, cross-attention memories.
Under continuous batching each batch row is a *slot*: an independent
request lane with its own write position and valid-length mask (the
per-row ``pos`` / ``slot_pos`` arrays the model layer maintains).

This module adds the two operations the scheduler needs on top of that
pytree, both compiled once:

  * ``write_slot``   — scatter a freshly prefilled single-request cache
    (batch=1, same ``max_len``) into slot *i* of the live cache. Admission
    happens mid-flight: the other slots keep decoding untouched.
  * ``SlotTable``    — host-side alloc/free bookkeeping mapping slots to
    request state (uid, budget, output tokens, timing).

Supports ``bf16 | f32 | int8 | int4`` KV: the copy is dtype-agnostic (it
walks whatever leaves the cache has, including int8 codes + f32 scales
and the int4 path's packed uint8 nibble pages — a packed page row is
still one leaf row, so slot admission never unpacks anything).

Cache pytree layout (see ``transformer.init_cache``): ``prefix`` /
``suffix`` hold per-layer dicts whose leaves have batch at axis 0;
``groups`` holds scan-stacked trees whose leaves carry (n_groups, B, ...)
— batch at axis 1. The scatter respects both.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, FrozenSet, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import init_cache

# "int4" is a sentinel (there is no sub-byte jnp dtype): the model layer
# allocates packed uint8 nibble pages for it (models.attention.INT4)
KV_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32, "int8": jnp.int8,
             "int4": "int4"}


def _copy_row(batch_axis: int):
    def copy(dst: jax.Array, src: jax.Array, slot) -> jax.Array:
        row = jax.lax.index_in_dim(src, 0, batch_axis, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(dst, row, slot, batch_axis)
    return copy


def write_slot(dst_cache: Dict, src_cache: Dict, slot: jax.Array) -> Dict:
    """Copy row 0 of ``src_cache`` (batch=1, same max_len) into ``slot``
    of ``dst_cache``. Pure function of pytrees — jit it once; ``slot`` is
    a traced scalar, so one compile covers every slot."""
    c0 = _copy_row(0)
    c1 = _copy_row(1)
    out = dict(dst_cache)
    out["prefix"] = jax.tree_util.tree_map(
        lambda d, s: c0(d, s, slot), dst_cache["prefix"], src_cache["prefix"])
    out["suffix"] = jax.tree_util.tree_map(
        lambda d, s: c0(d, s, slot), dst_cache["suffix"], src_cache["suffix"])
    out["groups"] = jax.tree_util.tree_map(
        lambda d, s: c1(d, s, slot), dst_cache["groups"], src_cache["groups"])
    return out


class SlotKVCache:
    """Device caches for a fixed number of slots + a *pristine* zeroed
    single-row prefill template (same ``max_len``, so admission is a
    plain row copy).

    ``prefill_cache`` is the immutable input to every admission prefill:
    jax prefill is functional, so each admit produces a fresh populated
    copy and the template stays all-zeros. Feeding the *previous* admit's
    output back in instead would leak recurrent state (RG-LRU conv
    history, xLSTM C/n/m, accumulated pos) across requests."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 kv_dtype: str = "bf16"):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.kv_dtype = kv_dtype
        dt = KV_DTYPES[kv_dtype]
        self.cache = init_cache(cfg, n_slots, max_len, dtype=dt)
        self.prefill_cache = init_cache(cfg, 1, max_len, dtype=dt)
        self._write = jax.jit(write_slot)

    def admit(self, prefilled: Dict, slot: int) -> None:
        """Scatter a populated single-row cache into ``slot`` (device op;
        other slots' lanes are untouched)."""
        self.cache = self._write(self.cache, prefilled, jnp.int32(slot))

    def hbm_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.cache))


# ==========================================================================
# Host-side slot bookkeeping
# ==========================================================================
@dataclasses.dataclass
class SlotState:
    """One active request occupying one slot."""
    uid: int
    prompt_len: int
    budget: int                       # max_new_tokens for this request
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_prefill: float = 0.0            # prefill wall time at admission
    sampling: Optional[object] = None  # resolved SamplingParams
    stop: FrozenSet[int] = frozenset()  # stop token ids (incl. eos)
    seed: int = 0                     # resolved lane PRNG seed
    finish_reason: Optional[str] = None  # "stop" | "length" | "abort"


class SlotTable:
    """Alloc/free of slot ids + per-slot request state."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))  # pop() → slot 0 first
        self.active: Dict[int, SlotState] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return len(self.active)

    def alloc(self, state: SlotState) -> int:
        slot = self._free.pop()
        state.t_admit = time.perf_counter()
        self.active[slot] = state
        return slot

    def free(self, slot: int) -> SlotState:
        state = self.active.pop(slot)
        self._free.append(slot)
        return state

    def active_slots(self) -> List[int]:
        return sorted(self.active)
