"""Runtime invariant sanitizer: the dynamic twin of ``tools/analysis``.

``ServeConfig(sanitize=True)`` (CLI ``--sanitize``) arms a post-step
audit of the engine's bookkeeping against the device state it is
supposed to mirror. The static passes prove the *code* follows the
serving conventions; this module checks each ``step()`` actually left
the *state* consistent:

  * **page-refcount conservation** — every pool page is in exactly one
    of free/hot/cold, ``free + hot + cold == n_pages``, and each page's
    refcount equals its appearances across live block-table rows plus
    its parked reservation (aliasing only via prefix-cache refcounts);
  * **block-table validity** — each slot's device table row is exactly
    its host-side page list padded with the slot's parked page, every
    entry a live page id, and any page shared by two rows is
    prefix-registered;
  * **pos / slot_pos consistency** — a decoding lane's device write
    position equals ``prompt_len (+ vision) + generated - 1`` (exact
    through speculative rollback), a mid-prefill lane sits at its
    chunk frontier, and a request's committed token count never
    decreases;
  * **prefix-cache agreement** — the radix tree and the pool's
    ``_cached`` flags describe the same page set: every page the pool
    marks cached is reachable from a tree node and vice versa (an
    orphaned flag pins a page forever; a ghost node hands out pages the
    pool may already have recycled);
  * **int4 nibble-pair alignment** — packed4 cache leaves hold exactly
    ``page_size / 2`` (or ``max_len / 2``) byte rows on the slot axis.

Reads only — the sanitized engine is token-identical to a bare one —
but it does force host syncs (``jax.device_get`` on the small
block-table/pos leaves), so it is a CI-smoke/debug tool, not a
production default. Violations raise :class:`SanitizerError` naming
the failing invariant.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import jax
import numpy as np

from repro.kernels.constraints import PACKED4_SLOT_ALIGN


class SanitizerError(AssertionError):
    """A serve-state invariant did not survive an engine step."""


def _fail(invariant: str, msg: str) -> None:
    raise SanitizerError(f"[sanitize:{invariant}] {msg}")


def _attn_layers(cache) -> Iterator[Tuple[str, Dict]]:
    """Yield (path, layer-dict) for every cache layer that carries a
    write position — attention layers in both the slot and the paged
    layout. Scan-stacked group layers come through with their leading
    group axis intact."""
    for part in ("prefix", "suffix"):
        for i, layer in enumerate(cache.get(part, []) or []):
            if isinstance(layer, dict) and "pos" in layer:
                yield f"{part}[{i}]", layer
    for name, layer in (cache.get("groups") or {}).items():
        if isinstance(layer, dict) and "pos" in layer:
            yield f"groups[{name}]", layer


def _host(leaf) -> np.ndarray:
    return np.asarray(jax.device_get(leaf))


def _destack(arr: np.ndarray, path: str, want_ndim: int) -> np.ndarray:
    """Collapse a scan-stacked leading group axis after checking the
    replicas agree (block tables / pos are broadcast per layer)."""
    if arr.ndim == want_ndim:
        return arr
    if not (arr == arr[:1]).all():
        _fail("block-table", f"{path}: scan-stacked replicas diverge")
    return arr[0]


class Sanitizer:
    """Stateful checker: holds per-request committed-token watermarks so
    rollback can never un-commit an emitted token."""

    def __init__(self):
        self._committed: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def check(self, engine) -> None:
        """Audit one engine against its device state; raises
        :class:`SanitizerError` on the first violated invariant."""
        if engine.sched is None:
            return
        self._check_committed(engine)
        if engine.sc.paged:
            self._check_pool(engine)
            self._check_tables(engine)
            self._check_prefix_cache(engine)
        self._check_pos(engine)
        if engine.sc.kv_dtype == "int4":
            self._check_packed4(engine)

    # ------------------------------------------------------------------
    def _check_committed(self, engine) -> None:
        live = {}
        for state in engine.sched.table.active.values():
            n = len(state.tokens)
            prev = self._committed.get(state.uid, 0)
            if n < prev:
                _fail("pos-monotonic",
                      f"request {state.uid}: committed tokens fell "
                      f"{prev} -> {n} (speculative rollback un-committed "
                      f"an emitted token)")
            live[state.uid] = n
        # retired uids drop out of the watermark table
        self._committed = live

    # ------------------------------------------------------------------
    def _check_pool(self, engine) -> None:
        pool = engine.pool
        free = list(pool._free)
        cold = set(pool._cold)
        hot = [p for p in range(pool.n_pages) if pool._ref[p] > 0]
        if len(free) + len(hot) + len(cold) != pool.n_pages:
            _fail("refcount",
                  f"page partition leaks: free={len(free)} hot={len(hot)} "
                  f"cold={len(cold)} != n_pages={pool.n_pages}")
        for name, group in (("free", free), ("cold", cold)):
            for p in group:
                if pool._ref[p] != 0:
                    _fail("refcount",
                          f"{name} page {p} has refcount {pool._ref[p]}")
        for p in cold:
            if not pool._cached[p]:
                _fail("refcount", f"cold page {p} is not prefix-registered")
        if cold & set(free):
            _fail("refcount", f"pages both free and cold: {cold & set(free)}")
        # conservation: refcount == row occurrences + parked reservation
        expect = [0] * pool.n_pages
        for row in engine._row_pages.values():
            for p in row:
                expect[p] += 1
        for p in engine._parked:
            expect[p] += 1
        for p in range(pool.n_pages):
            if pool._ref[p] != expect[p]:
                _fail("refcount",
                      f"page {p}: refcount {pool._ref[p]} != "
                      f"{expect[p]} (block-table rows + parked)")

    # ------------------------------------------------------------------
    def _check_tables(self, engine) -> None:
        nb = engine.slots.n_blocks
        n_pages = engine.pool.n_pages
        shared: Dict[int, int] = {}
        for row in engine._row_pages.values():
            for p in set(row):
                shared[p] = shared.get(p, 0) + 1
        for p, owners in shared.items():
            if owners > 1 and not engine.pool._cached[p]:
                _fail("block-table",
                      f"page {p} aliased by {owners} rows without a "
                      f"prefix-cache registration")
        for path, layer in _attn_layers(engine.slots.cache):
            if "block_table" not in layer:
                continue
            bt = _destack(_host(layer["block_table"]), path, 2)
            if bt.min() < 0 or bt.max() >= n_pages:
                _fail("block-table",
                      f"{path}: entry out of range [0, {n_pages}): "
                      f"min={bt.min()} max={bt.max()}")
            for slot in range(bt.shape[0]):
                row = engine._row_pages.get(slot, [])
                want = row + [engine._parked[slot]] * (nb - len(row))
                got = bt[slot].tolist()
                if got != want:
                    _fail("block-table",
                          f"{path} slot {slot}: device row {got} != "
                          f"host mapping {want}")

    # ------------------------------------------------------------------
    def _check_prefix_cache(self, engine) -> None:
        """Radix tree ↔ ``PagePool._cached`` agreement: both sides must
        name exactly the same page set. A cached flag with no tree node
        can never be released (the tree owns release_cached), and a node
        over an un-flagged page would map out pages the pool considers
        recyclable."""
        prefix, pool = engine.prefix, engine.pool
        if prefix is None:
            return
        tree = set(prefix._by_page)
        cached = {p for p in range(pool.n_pages) if pool._cached[p]}
        orphans = cached - tree
        if orphans:
            _fail("prefix-cache",
                  f"pages marked cached with no radix-tree node: "
                  f"{sorted(orphans)} — unreleasable without a tree owner")
        ghosts = tree - cached
        if ghosts:
            _fail("prefix-cache",
                  f"radix-tree nodes over pages the pool no longer marks "
                  f"cached: {sorted(ghosts)} — the tree would map out "
                  f"recyclable pages")

    # ------------------------------------------------------------------
    def _check_pos(self, engine) -> None:
        active = engine.sched.table.active
        jobs = getattr(engine, "_prefill_jobs", {}) if engine.sc.paged \
            else {}
        n_vis = engine._n_vis
        for path, layer in _attn_layers(engine.slots.cache):
            pos = _destack(_host(layer["pos"]), path, 1)
            for slot, state in active.items():
                if slot in jobs:
                    want = jobs[slot].next
                    tag = f"mid-prefill frontier {want}"
                elif state.tokens:
                    want = state.prompt_len + n_vis + len(state.tokens) - 1
                    tag = (f"prompt {state.prompt_len} + vision {n_vis} "
                           f"+ generated {len(state.tokens)} - 1 = {want}")
                else:
                    continue                   # admitted, nothing emitted
                if int(pos[slot]) != want:
                    _fail("pos",
                          f"{path} slot {slot} (uid {state.uid}): device "
                          f"pos {int(pos[slot])} != {tag}")
            # Parked lanes are NOT pinned at 0: lockstep decode advances
            # the shared pos vector for every lane, so a parked slot's
            # pos drifts while other lanes decode. That drift is safe
            # precisely because the parked row references only the
            # slot's private parked page — which _check_tables proves —
            # and admission resets pos via set_row.
            if "slot_pos" in layer and not engine.sc.paged:
                sp = _destack(_host(layer["slot_pos"]), path, 2)
                for slot in active:
                    bad = sp[slot][sp[slot] > int(pos[slot])]
                    if bad.size:
                        _fail("pos",
                              f"{path} slot {slot}: slot_pos holds "
                              f"positions {sorted(set(bad.tolist()))} "
                              f"beyond pos {int(pos[slot])}")

    # ------------------------------------------------------------------
    def _check_packed4(self, engine) -> None:
        span = engine.page_size if engine.sc.paged else engine.sc.max_len
        if span % PACKED4_SLOT_ALIGN:
            _fail("int4-align", f"slot span {span} is not nibble-pair "
                                f"aligned")
        for path, layer in _attn_layers(engine.slots.cache):
            for leaf in ("k", "v"):
                arr = layer.get(leaf)
                if arr is None or arr.dtype != np.uint8:
                    continue
                if arr.shape[-2] * 2 != span:
                    _fail("int4-align",
                          f"{path}.{leaf}: packed slot axis "
                          f"{arr.shape[-2]} bytes != {span} logical "
                          f"slots / 2")
