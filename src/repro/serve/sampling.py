"""Per-request sampling: params, lane-seed derivation, and the jitted
token sampler.

Sampling is **counter-based**: every lane draws with
``fold_in(PRNGKey(lane_seed), token_index)`` where ``token_index`` is
the request's own output position (0 = the first token, sampled off the
prefill logits). The draw therefore depends only on ``(seed, index)`` —
never on which slot the request landed in, which step admitted it, or
what else shares the batch — so the streaming engine, the bucketed
baseline, and the HTTP frontend all emit token-identical output for the
same ``(prompt, SamplingParams)``. That property is what the parity
tests (and the token-budget scheduler's output-invariance) lean on.

Greedy lanes (``temperature <= 0``) take the argmax of the *raw* logits
— top-k/top-p filtering never perturbs them — and an all-greedy batch
skips the sampling branch entirely via ``lax.cond``, keeping the decode
hot path as cheap as the old engine-global greedy sampler.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# compiled width of the per-token top-logprob report (OpenAI caps
# ``top_logprobs`` at 5); requests trim down from this on the host
TOP_LOGPROBS = 5


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode controls, carried on ``Request.params``.

    ``None`` fields fall back to the engine's ``ServeConfig`` defaults
    (``temperature``, ``max_new_tokens``) at submit time; ``seed=None``
    derives a deterministic per-request stream from the engine's base
    seed and the request uid. ``stop`` token ids retire the request the
    moment one is emitted (the stop token is kept in the output, like
    EOS); ``ServeConfig.eos_id`` is always an implicit stop.
    """
    temperature: Optional[float] = None  # None → ServeConfig.temperature
    top_p: float = 1.0                   # nucleus mass; 1.0 = off
    top_k: int = 0                       # 0 = off
    seed: Optional[int] = None           # None → derived from (base, uid)
    stop: Tuple[int, ...] = ()           # extra stop token ids
    max_new_tokens: Optional[int] = None  # None → ServeConfig default
    logprobs: Optional[int] = None       # None = off; n = report the
    # sampled token's logprob + the top-n alternatives per position

    def validate(self) -> None:
        if self.temperature is not None and self.temperature < 0:
            raise ValueError(f"temperature={self.temperature} must be >= 0")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p={self.top_p} must be in (0, 1]")
        if self.top_k < 0:
            raise ValueError(f"top_k={self.top_k} must be >= 0")
        if self.max_new_tokens is not None and self.max_new_tokens < 0:
            raise ValueError(
                f"max_new_tokens={self.max_new_tokens} must be >= 0")
        if self.logprobs is not None \
                and not 0 <= self.logprobs <= TOP_LOGPROBS:
            raise ValueError(f"logprobs={self.logprobs} must be in "
                             f"[0, {TOP_LOGPROBS}]")


def lane_seed(seed: Optional[int], base: int, uid: int) -> int:
    """Resolve a request's PRNG stream seed: the explicit
    ``SamplingParams.seed`` wins; otherwise mix the engine base seed
    with the uid so distinct requests draw distinct streams while the
    same ``(base, uid)`` replays exactly."""
    if seed is not None:
        return int(seed) & 0x7FFFFFFF
    return (int(base) * 1_000_003 + int(uid) * 7919 + 12289) & 0x7FFFFFFF


def sample_tokens(logits: jax.Array, temps: jax.Array, top_ps: jax.Array,
                  top_ks: jax.Array, seeds: jax.Array,
                  idxs: jax.Array) -> jax.Array:
    """Per-lane next-token selection. ``logits`` is (B, V) float32; the
    five lane arrays are (B,). Returns (B,) int32.

    Counter-based keys (``fold_in(PRNGKey(seed), index)``) are derived
    *inside* the jit — no host-side key threading per step — and the
    whole sampling branch is skipped under ``lax.cond`` when every lane
    is greedy, so greedy batches pay only the argmax."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _mixed(_):
        v = logits.shape[-1]
        srt = jnp.sort(logits, axis=-1)[:, ::-1]        # descending
        # top-k: keep logits >= the kth largest (k<=0 → keep all)
        k = jnp.clip(jnp.where(top_ks > 0, top_ks, v), 1, v)
        kth = jnp.take_along_axis(srt, (k - 1)[:, None], axis=-1)
        safe_t = jnp.where(temps > 0, temps, 1.0)[:, None]
        # top-p on the temperature-scaled distribution: a sorted entry
        # survives while the mass *before* it is < top_p (exclusive
        # prefix sum), so the argmax always survives
        probs = jax.nn.softmax(srt / safe_t, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        n_keep = jnp.sum((cum - probs) < top_ps[:, None], axis=-1)
        pth = jnp.take_along_axis(
            srt, jnp.maximum(n_keep - 1, 0)[:, None], axis=-1)
        keep = (logits >= kth) & (logits >= pth)
        filt = jnp.where(keep, logits, -jnp.inf) / safe_t
        keys = jax.vmap(lambda s, i: jax.random.fold_in(
            jax.random.PRNGKey(s), i))(seeds, idxs)
        drawn = jax.vmap(jax.random.categorical)(keys, filt)
        return jnp.where(temps > 0, drawn.astype(jnp.int32), greedy)

    return jax.lax.cond(jnp.any(temps > 0.0), _mixed,
                        lambda _: greedy, operand=None)
