"""Paged KV cache: block-granular page pool + per-slot block tables.

The slot cache (``serve.slots``) backs every request with a contiguous
``max_len`` row: admission copies whole rows, and the cache is sized for
the worst case even when most requests are short. This module replaces
the row substrate with the production layout (vLLM/rtp-llm style):

  * device-side, each attention layer's K/V live in a **page pool** —
    ``(n_pages, KV, page_size, hd)`` (packed4 int4: ``(n_pages, KV,
    page_size/2, hd)`` uint8; int8/int4 scales ``(n_pages, KV,
    page_size)``) — and every slot row carries a **block table**
    ``(B, n_blocks)`` of physical page ids. Decode attention follows the
    indirection (``kernels.ops.decode_attention_op(block_table=...)``);
    admission never copies a row — it just rewrites the slot's table.
  * host-side, :class:`PagePool` is the ref-counted allocator: a free
    list for virgin pages plus an LRU **cold set** of pages whose
    refcount dropped to zero but which still back a radix-tree prefix
    block (``serve.prefix``). Allocation under pressure evicts cold
    pages LRU-first, telling the tree to drop the backing nodes.

Page size must be **even** so the int4 packed container's nibble pairs
(two slots per byte) never straddle a page, and should equal the
flash-decode kernel block (the paged kernel streams exactly one page
per sequence grid step). On real TPU hardware Mosaic additionally wants
the page to meet the sublane tile (32 for int8 codes, 64 for packed4);
interpret mode — and therefore CPU CI — accepts any even size.

Every block-table entry always holds a *valid* physical page id: entries
past a slot's allocation point at the slot's **parked page** (one
permanently-allocated, never-shared page per slot), so the decode step's
unconditional per-row cache write lands somewhere harmless for retired
or still-prefilling rows instead of corrupting a page another request
owns. The engine re-points a row at its parked page on retirement.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.constraints import validate_page_size
from repro.models import init_cache
from repro.serve.slots import KV_DTYPES


# ==========================================================================
# Host-side allocator
# ==========================================================================
class PagePool:
    """Ref-counted physical-page allocator with LRU eviction.

    Page states (disjoint):
      * **free** — on the free list, content garbage;
      * **hot**  — refcount ≥ 1 (owned by ≥ 1 live request, and/or just
        revived by a prefix match);
      * **cold** — refcount 0 but still registered as a radix-tree
        prefix block: content stays valid and a future prefix match can
        revive it (``incref``). Cold pages are the eviction pool, oldest
        first.

    ``evict_hook(page)`` — installed by :class:`~repro.serve.prefix.
    RadixPrefixCache` — is called when a cold page is reclaimed so the
    tree drops the node (and its subtree, whose pages are released back
    here via :meth:`release_cached`).
    """

    def __init__(self, n_pages: int, page_size: int):
        # nibble-pair alignment only — the pool is storage-agnostic;
        # the engine enforces the backend-dependent sublane-tile floor
        validate_page_size(page_size)
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: collections.deque = collections.deque(range(n_pages))
        self._ref = [0] * n_pages
        self._cached = [False] * n_pages      # backs a radix-tree node
        self._cold: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()          # refcount-0 cached, LRU order
        self.evict_hook: Optional[Callable[[int], None]] = None
        self.evictions = 0
        self.watermark_evictions = 0
        self.allocated = 0

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cold(self) -> int:
        return len(self._cold)

    @property
    def n_hot(self) -> int:
        return self.n_pages - self.n_free - self.n_cold

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` fresh pages at refcount 1, evicting cold prefix pages
        LRU-first if the free list runs dry. Returns None (no state
        change) when free + cold cannot cover the request — the caller
        defers admission until live requests retire."""
        if n > len(self._free) + len(self._cold):
            return None
        out: List[int] = []
        while len(out) < n:
            if self._free:
                p = self._free.popleft()
            else:
                # oldest cold page; the tree drops its node + subtree
                # (subtree pages are cold too — a hot descendant would
                # hold refs on every ancestor — and come back via
                # release_cached, growing the free list mid-loop)
                p, _ = self._cold.popitem(last=False)
                self._cached[p] = False
                self.evictions += 1
                if self.evict_hook is not None:
                    self.evict_hook(p)
            self._ref[p] = 1
            out.append(p)
        self.allocated += n
        return out

    def ensure_free(self, min_free: int) -> int:
        """Watermark eviction: reclaim cold prefix pages LRU-first until
        at least ``min_free`` pages sit on the free list (or the cold
        set runs dry). Unlike the on-demand eviction inside
        :meth:`alloc` — which fires only when an allocation would
        otherwise fail — this runs ahead of demand so bursts of
        admissions hit a pre-drained free list instead of paying the
        tree-teardown work inside the admission path. Returns the
        number of pages evicted."""
        n = 0
        while len(self._free) < min_free and self._cold:
            p, _ = self._cold.popitem(last=False)
            self._cached[p] = False
            self.evictions += 1
            self.watermark_evictions += 1
            if self.evict_hook is not None:
                # the hook releases the node's subtree via
                # release_cached (those pages are cold too and join the
                # free list); p itself is already un-cached so the
                # hook's own release of it is a no-op
                self.evict_hook(p)
            self._free.append(p)
            n += 1
        return n

    def incref(self, pages: List[int]) -> None:
        """Revive/share pages (prefix-cache hit): cold pages leave the
        eviction pool."""
        for p in pages:
            if self._ref[p] == 0:
                self._cold.pop(p, None)
            self._ref[p] += 1

    def decref(self, pages: List[int]) -> None:
        """Release one reference per page. A page reaching refcount 0
        goes cold (retained, evictable) if it backs a radix-tree block,
        else straight back to the free list."""
        for p in pages:
            if self._ref[p] <= 0:
                raise RuntimeError(f"double free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                if self._cached[p]:
                    self._cold[p] = None          # MRU end of the LRU
                else:
                    self._free.append(p)

    # ------------------------------------------------------------------
    def mark_cached(self, page: int) -> None:
        """The radix tree took a node over this page (refcount stays the
        owner's; the page just becomes retainable-after-release)."""
        self._cached[page] = True

    def release_cached(self, page: int) -> None:
        """The radix tree dropped this page's node (subtree of an
        eviction): no longer retainable; free it if unreferenced."""
        if not self._cached[page]:
            return
        self._cached[page] = False
        if self._ref[page] == 0:
            self._cold.pop(page, None)
            self._free.append(page)

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def stats(self) -> Dict[str, int]:
        return {"pages_total": self.n_pages, "pages_free": self.n_free,
                "pages_cold": self.n_cold, "pages_hot": self.n_hot,
                "evictions": self.evictions,
                "watermark_evictions": self.watermark_evictions,
                "page_allocs": self.allocated}

    def publish(self, reg) -> None:
        """Publish the page-pool series into a telemetry registry
        (names match the legacy ``stats()`` keys exactly)."""
        reg.gauge("pages_total", "physical pages in the pool"
                  ).set(self.n_pages)
        reg.gauge("pages_free", "virgin pages on the free list"
                  ).set(self.n_free)
        reg.gauge("pages_cold", "refcount-0 prefix-retained pages"
                  ).set(self.n_cold)
        reg.gauge("pages_hot", "pages owned by live requests"
                  ).set(self.n_hot)
        reg.counter("evictions", "cold prefix pages reclaimed under "
                    "pressure").set(self.evictions)
        reg.counter("watermark_evictions", "cold prefix pages reclaimed "
                    "ahead of demand by the free watermark"
                    ).set(self.watermark_evictions)
        reg.counter("page_allocs", "pages handed out").set(self.allocated)

    def reset_stats(self) -> None:
        self.evictions = 0
        self.watermark_evictions = 0
        self.allocated = 0


# ==========================================================================
# Device-side paged cache
# ==========================================================================
def _update_layer_row(layer: Dict, slot, row, pos, stacked: bool) -> Dict:
    """Rewrite one slot's block-table row + pos in a layer cache (leading
    group axis broadcast for scan-stacked layers)."""
    if "block_table" not in layer:
        return layer
    out = dict(layer)
    if stacked:
        out["block_table"] = layer["block_table"].at[:, slot].set(row)
        out["pos"] = layer["pos"].at[:, slot].set(pos)
    else:
        out["block_table"] = layer["block_table"].at[slot].set(row)
        out["pos"] = layer["pos"].at[slot].set(pos)
    return out


def set_block_table_row(cache: Dict, slot: jax.Array, row: jax.Array,
                        pos: jax.Array) -> Dict:
    """Point slot ``slot`` of every attention layer at physical pages
    ``row`` (n_blocks,) with write position ``pos``. Pure pytree
    function — jit once; slot/row/pos are traced, so one compile covers
    every admission and retirement."""
    out = dict(cache)
    out["prefix"] = [_update_layer_row(c, slot, row, pos, False)
                     for c in cache["prefix"]]
    out["suffix"] = [_update_layer_row(c, slot, row, pos, False)
                     for c in cache["suffix"]]
    if cache["groups"]:
        out["groups"] = {k: _update_layer_row(v, slot, row, pos, True)
                         for k, v in cache["groups"].items()}
    return out


class PagedKVCache:
    """Device page pools + block tables for ``n_slots`` decode lanes.

    The pools are allocated by ``models.init_cache(pages=, page_size=)``
    — per attention layer ``(n_pages, KV, page_size, hd)`` (scan-stacked
    groups carry a leading group axis; block tables are replicated
    per layer so the decode pytree stays self-contained). Admission and
    retirement rewrite one slot's table row (:func:`set_block_table_row`)
    — there is no row copy and no per-request prefill cache template.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 kv_dtype: str, page_size: int, n_pages: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.kv_dtype = kv_dtype
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_blocks = -(-max_len // page_size)
        self.cache = init_cache(cfg, n_slots, max_len,
                                dtype=KV_DTYPES[kv_dtype],
                                pages=n_pages, page_size=page_size)
        self._set_row = jax.jit(set_block_table_row)

    def set_row(self, slot: int, pages: List[int], pos: int) -> None:
        """Map a slot's logical blocks onto physical ``pages`` (padded
        to n_blocks by the caller — typically with the slot's parked
        page) and reset its write position."""
        assert len(pages) == self.n_blocks, \
            f"block table row needs {self.n_blocks} entries, got {len(pages)}"
        row = jnp.asarray(np.asarray(pages, np.int32))
        self.cache = self._set_row(self.cache, jnp.int32(slot), row,
                                   jnp.int32(pos))

    def hbm_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.cache))
