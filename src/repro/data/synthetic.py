"""Deterministic synthetic LM data — stateless, per-host sharded.

Fault-tolerance contract: batch contents are a pure function of
``(seed, step, sample-index)``. A restarted (or replacement) host asking
for step ``s`` gets byte-identical data, so checkpoint-resume and
straggler-replacement never need data-loader state. This mirrors how
deterministic data pipelines (e.g. grain with index-based sampling) behave
at cluster scale, with the storage layer replaced by a counter-based PRNG.

The token stream is not uniform noise: a per-sequence Markov-ish structure
(token t+1 depends on token t through a hashed transition) gives the LM a
learnable signal, so example training losses actually descend.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality stubs
    frames: Optional[tuple[int, int]] = None   # (enc_seq, d_frontend)
    vision: Optional[tuple[int, int]] = None   # (n_tokens, d_frontend)


def _fold(*ints: int) -> np.random.Generator:
    seq = np.random.SeedSequence(list(ints))
    return np.random.Generator(np.random.PCG64(seq))


def sample_tokens(cfg: DataConfig, step: int, index: int) -> np.ndarray:
    """One (seq_len + 1,) token sequence for global sample ``index``.

    The affine transition (a, b) is a function of the *seed only* — a
    corpus-global bigram structure every sample shares, so the LM has a
    stationary signal to learn; per-sample noise keeps sequences distinct.
    """
    grng = _fold(cfg.seed, 0xC0FFEE)
    a = int(grng.integers(1, 257))
    b = int(grng.integers(0, cfg.vocab))
    rng = _fold(cfg.seed, step, index)
    v = cfg.vocab
    toks = np.empty(cfg.seq_len + 1, np.int64)
    toks[0] = rng.integers(0, v)
    noise = rng.integers(0, 5, size=cfg.seq_len)
    for t in range(cfg.seq_len):
        toks[t + 1] = (a * toks[t] + b + noise[t]) % v
    return toks


def host_batch(
    cfg: DataConfig,
    step: int,
    host_index: int = 0,
    host_count: int = 1,
) -> Dict[str, jax.Array]:
    """The slice of global batch ``step`` owned by this host.

    Sample ids are ``step·B + i`` for the host's contiguous shard of
    ``i ∈ [0, B)`` — globally deterministic, locally generated.
    """
    if cfg.global_batch % host_count:
        raise ValueError("global batch must divide across hosts")
    per_host = cfg.global_batch // host_count
    lo = host_index * per_host
    seqs = np.stack([sample_tokens(cfg, step, lo + i)
                     for i in range(per_host)])
    batch: Dict[str, jax.Array] = {
        "tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
        "labels": jnp.asarray(seqs[:, 1:], jnp.int32),
    }
    if cfg.frames is not None:
        s, d = cfg.frames
        rng = _fold(cfg.seed, step, 1_000_003 + host_index)
        batch["frames"] = jnp.asarray(
            rng.standard_normal((per_host, s, d)), jnp.float32)
    if cfg.vision is not None:
        t, d = cfg.vision
        rng = _fold(cfg.seed, step, 2_000_003 + host_index)
        batch["vision"] = jnp.asarray(
            rng.standard_normal((per_host, t, d)), jnp.float32)
    return batch


def batches(cfg: DataConfig, start_step: int = 0,
            host_index: int = 0, host_count: int = 1
            ) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield host_batch(cfg, step, host_index, host_count)
        step += 1


def data_config_for(model_cfg, seq_len: int, global_batch: int,
                    seed: int = 0) -> DataConfig:
    """DataConfig matching a ModelConfig's modality stubs."""
    return DataConfig(
        vocab=model_cfg.vocab,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        frames=((model_cfg.enc_seq, model_cfg.d_frontend)
                if model_cfg.is_encoder_decoder else None),
        vision=((model_cfg.n_vision_tokens,
                 model_cfg.d_frontend or model_cfg.d_model)
                if model_cfg.n_vision_tokens else None),
    )
