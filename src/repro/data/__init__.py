"""Data substrate: deterministic synthetic streams + calibration capture."""
from repro.data.calibration import calibration_summary, capture_calibration
from repro.data.synthetic import (
    DataConfig,
    batches,
    data_config_for,
    host_batch,
    sample_tokens,
)

__all__ = [
    "DataConfig", "batches", "data_config_for", "host_batch",
    "sample_tokens", "calibration_summary", "capture_calibration",
]
