"""Calibration capture: run the model eagerly, harvest per-layer input
moments for the scaling matrices (paper §2, App A.2: 256 calibration
samples).

The model zoo's ``linear`` records streaming CalibStats into ``ctx.tap``
whenever it is set — count, Σ|x|, Σx², Σxxᵀ per *named* projection. These
moments are sufficient for every scaling kind (identity / lqer /
qera-approx / qera-exact) without retaining activations, which is what
makes calibrating a 70B-class model feasible (the paper's scaling pass
dominates its pipeline cost; App A.4).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.core.api import CalibStats
from repro.data.synthetic import DataConfig, host_batch
from repro.models.linear import Ctx


def capture_calibration(
    params,
    model_cfg,
    data_cfg: DataConfig,
    forward_fn,
    n_batches: int = 4,
    need_autocorr: bool = True,
) -> Dict[str, CalibStats]:
    """Run ``n_batches`` calibration batches, returning per-layer stats.

    ``forward_fn(ctx, params, batch, cfg)`` is typically
    ``lambda ctx, p, b, c: lm_loss(ctx, p, b, c)`` — anything that routes
    activations through the linears.
    """
    tap: Dict[str, CalibStats] = {}
    ctx = Ctx(tap=tap)
    if not need_autocorr:
        # swap the recorder to skip the m×m moment
        orig_record = ctx.record

        def record(name, x, m):
            if name not in tap:
                tap[name] = CalibStats.init(m, need_autocorr=False)
            tap[name] = tap[name].update(x)
        ctx.record = record  # type: ignore[method-assign]
    for step in range(n_batches):
        batch = host_batch(data_cfg, step)
        forward_fn(ctx, params, batch, model_cfg)
    return tap


def calibration_summary(stats: Dict[str, CalibStats]) -> Dict[str, dict]:
    out = {}
    for name, s in stats.items():
        out[name] = {
            "count": float(s.count),
            "mean_abs": float(jax.numpy.mean(s.sum_abs / s.count)),
            "rms": float(jax.numpy.mean(
                jax.numpy.sqrt(s.sum_sq / s.count))),
            "has_autocorr": s.autocorr is not None,
        }
    return out
