"""Fine-grained Mixture-of-Experts (DeepSeek-MoE style).

``n_shared`` always-on experts + ``n_routed`` experts with top-k routing.
The dispatch is capacity-based scatter/gather (Switch-style) rather than a
dense ``(T, E, C)`` einsum, so dispatch cost is O(T·d) data movement and
expert FLOPs are ``E · C · (3·d·d_e·2)`` with
``C = ceil(T · top_k / E · capacity_factor)`` — the layout that shards
cleanly over the ``model`` axis as expert parallelism.

A load-balancing auxiliary loss (Switch §2.2) is returned alongside.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_mlp, mlp
from repro.models.linear import (Ctx, dp_axes_of, fused_mode, hint,
                                 init_linear, linear)


def init_moe(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, de = cfg.d_model, cfg.d_expert
    k_r, k_sh, k_e = jax.random.split(key, 3)

    def one_expert(k):
        ks = jax.random.split(k, 3)
        return {
            "up": init_linear(ks[0], d, de, dtype=dtype),
            "gate": init_linear(ks[1], d, de, dtype=dtype),
            "down": init_linear(ks[2], de, d, scale=1.0 / de**0.5, dtype=dtype),
        }

    p = {
        "router": init_linear(k_r, d, cfg.n_routed, dtype=dtype),
        "experts": jax.vmap(one_expert)(jax.random.split(k_e, cfg.n_routed)),
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(k_sh, d, cfg.n_shared * de, "swiglu", dtype=dtype)
    return p


def _apply_w(p: Dict, x: jax.Array, dtype) -> jax.Array:
    """Weight apply that honors quantized (Q + LR) expert params under vmap."""
    from repro.models.linear import dequant_weight
    if "w" in p:
        y = x @ p["w"].astype(dtype)
    else:
        y = x @ dequant_weight(p, dtype)
        if p["l"].shape[-1] > 0:
            y = y + (x @ p["l"].astype(dtype)) @ p["r"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def _expert_ffn(wp: Dict, x: jax.Array) -> jax.Array:
    """SwiGLU expert; x: (C, d) for a single expert's capacity slice."""
    dt = x.dtype
    h = jax.nn.silu(_apply_w(wp["gate"], x, dt)) * _apply_w(wp["up"], x, dt)
    return _apply_w(wp["down"], h, dt)


def _apply_w_batched(p: Dict, x: jax.Array, mode: str) -> jax.Array:
    """Stacked-expert weight apply on the fused Q+LR path: one batched
    kernel call over the (E, C, d) dispatch buffer instead of a vmap of
    per-expert dequant-then-matmul. ``p`` leads with the expert dim."""
    from repro.kernels import ops as kops
    codes, l = p["codes"], p["l"]
    pad = codes.shape[-2] - x.shape[-1]
    if pad:  # MXINT row padding on the expert input dim
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
        l = jnp.pad(l, ((0, 0), (0, pad), (0, 0)))
    y = kops.qlr_matmul_batched(x, codes, p["scale"], l, p["r"],
                                kernel=(mode == "kernel"))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)[:, None, :]
    return y


def _expert_ffn_batched(experts: Dict, x: jax.Array, mode: str) -> jax.Array:
    """SwiGLU over the whole expert stack; x: (E, C, d)."""
    dt = x.dtype
    h = jax.nn.silu(_apply_w_batched(experts["gate"], x, mode)) \
        * _apply_w_batched(experts["up"], x, mode)
    return _apply_w_batched(experts["down"], h.astype(dt), mode).astype(dt)


def moe_apply(ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
              prefix: str = "moe") -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). x: (B, S, D)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_routed, cfg.top_k
    # decode / small-T regime: capacity = T makes dispatch dropless (an
    # expert can receive at most T assignments since top-k indices are
    # distinct per token). The extra buffer slots are cheap exactly when
    # T is small, and serving must never drop tokens. Large-T training
    # keeps the standard Switch capacity (drops balanced by the aux loss).
    if t * k <= 2 * e or t <= 64:
        cap = t
    else:
        cap = int(max(1, t * k * cfg.capacity_factor / e))
    xf = x.reshape(t, d)

    logits = linear(ctx, params["router"], xf, f"{prefix}.router")
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)          # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (importance × load) ---------------------
    importance = jnp.mean(probs, axis=0)                       # (E,)
    onehot_top = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (T,k,E)
    load = jnp.mean(jnp.sum(onehot_top, axis=1), axis=0)       # (E,)
    aux = e * jnp.sum(importance * load)

    # --- capacity-based dispatch -----------------------------------------
    flat_expert = expert_idx.reshape(-1)                # (T·k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)
    # position of each assignment within its expert queue
    assign_1h = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)   # (T·k, E)
    pos_in_e = jnp.cumsum(assign_1h, axis=0) - assign_1h
    position = jnp.sum(pos_in_e * assign_1h, axis=-1)             # (T·k,)
    keep = position < cap
    safe_pos = jnp.where(keep, position, 0)

    buf = jnp.zeros((e, cap, d), xf.dtype)
    upd = jnp.where(keep[:, None], xf[flat_token], 0.0)
    buf = buf.at[flat_expert, safe_pos].add(upd)
    # expert parallelism: dispatch buffer sharded over the expert dim —
    # the scatter above becomes an all-to-all instead of a broadcast
    buf = hint(ctx, buf, "model", None, None)

    mode = fused_mode(ctx)
    if mode != "off" and "codes" in params["experts"]["up"]:
        # fused serving path: one batched Q+LR kernel call per projection
        # over the whole expert stack (packed4 experts keep the vmap path)
        out_buf = _expert_ffn_batched(params["experts"], buf, mode)
    else:
        out_buf = jax.vmap(_expert_ffn)(params["experts"], buf)  # (E, C, d)
    out_buf = hint(ctx, out_buf, "model", None, None)

    gathered = out_buf[flat_expert, safe_pos]                    # (T·k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    combined = jnp.zeros((t, d), xf.dtype)
    combined = combined.at[flat_token].add(gathered * flat_gate[:, None].astype(xf.dtype))

    if "shared" in params:
        combined = combined + mlp(ctx, params["shared"], xf, "swiglu",
                                  f"{prefix}.shared")
    return combined.reshape(b, s, d), aux.astype(jnp.float32)
