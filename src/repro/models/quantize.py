"""Model-level PTQ/QPEFT: fp param tree → Q + LR param tree.

This bridges the paper's per-matrix algorithm (repro.core) to the model
zoo's param-dict schema (repro.models.linear):

  {"w": (…, m, n)}  →  {"codes": int8, "scale": f32 (…, m/B, n),
                        "l": (…, m, r), "r": (…, r, n),
                        "gscale": (…, r) [, "b"]}

Stacked weights (scan groups: leading G dim; MoE experts: G, E dims) are
decomposed matrix-by-matrix over the leading indices — each (layer,
expert) gets its own k* split, exactly the paper's per-matrix rank
allocation. ``gscale`` carries the QPEFT per-rank gradient scale (Eq. 7
fixed-γ by default) so the training step needs no extra side state.

Policy: projection linears are quantized; embeddings, the LM head, norms
and modality projectors stay full-precision (matching the paper's
evaluated setting — transformer linears only).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import CalibStats, LayerReport, PTQConfig, quantize_layer
from repro.core.qpeft import fixed_gamma_scale, sgp_scale
from repro.quant import MXIntQuantizer, make_quantizer
from repro.quant.mxint import pack_codes_4bit

EXCLUDE_NAMES = {"embed", "lm_head", "vision_proj", "frontend_proj"}

# tap-name role for each projection key (matches the names the model zoo
# passes to linear()); used to look up calibration stats
_ROLE = {
    "wq": "attn.wq", "wk": "attn.wk", "wv": "attn.wv", "wo": "attn.wo",
    "up": ".up", "gate": ".gate", "down": ".down",
    "router": "moe.router",
}


def _names(path) -> List[str]:
    return [str(getattr(e, "key", getattr(e, "idx", ""))) for e in path]


def _stats_for(stats: Optional[Dict[str, CalibStats]], names: List[str],
               layer_hint: str) -> Optional[CalibStats]:
    """Find calibration stats for a weight path: try the per-layer key
    (L<i>.<role>), then the pooled role key, then suffix match."""
    if not stats:
        return None
    leaf = names[-2] if names[-1] == "w" else names[-1]
    role = _ROLE.get(leaf, leaf)
    for key in (f"{layer_hint}{role}", role):
        if key in stats:
            return stats[key]
    for key in stats:
        if key.endswith(role) or key.endswith("." + leaf):
            return stats[key]
    return None


def _quantize_matrix(name: str, w, stats, cfg: PTQConfig, key,
                     container: str,
                     recorder=None) -> Tuple[Dict[str, jax.Array], LayerReport]:
    dec, rep = quantize_layer(name, w, stats, cfg, key, recorder=recorder)
    qz = MXIntQuantizer(bits=cfg.quantizer.bits,
                        block_size=cfg.quantizer.block_size)
    packed = qz.quantize(dec.q)
    scale = jnp.exp2(packed.exponents.astype(jnp.float32))
    out: Dict[str, jax.Array] = {
        "scale": scale,
        "l": dec.l.astype(jnp.float32),
        "r": dec.r.astype(jnp.float32),
        "gscale": fixed_gamma_scale(dec.rank, dec.k, 0.1),
    }
    if container == "packed4":
        if cfg.quantizer.bits > 4:
            raise ValueError("packed4 container requires bits <= 4")
        out["packed"] = pack_codes_4bit(packed.codes)
    else:
        out["codes"] = packed.codes
    if recorder is not None:
        recorder.attach_container(name, out, container)
    return out, rep


def quantize_model_params(
    params: Any,
    stats: Optional[Dict[str, CalibStats]],
    cfg: PTQConfig,
    container: str = "int8",
    progress: Optional[Callable[[LayerReport], None]] = None,
    recorder=None,
) -> Tuple[Any, List[LayerReport]]:
    """Walk a model param tree, replacing each projection's fp weight with
    its SRR/QER decomposition. Pure host-side (offline calibration pass).

    ``recorder`` (duck-typed, see :mod:`repro.obs.quant`) captures a
    per-matrix quality record plus container byte accounting."""
    reports: List[LayerReport] = []
    root = jax.random.PRNGKey(cfg.seed)
    counter = [0]

    def visit(path, node):
        if not (isinstance(node, dict) and "w" in node
                and hasattr(node["w"], "ndim") and node["w"].ndim >= 2):
            return None  # not a linear params dict
        names = _names(path)
        if any(n in EXCLUDE_NAMES for n in names):
            return node
        w = np.asarray(node["w"], np.float32)
        lead = w.shape[:-2]
        name = "/".join(names)
        st = _stats_for(stats, names + ["w"], "")

        def one(mat, idx):
            counter[0] += 1
            key = jax.random.fold_in(root, counter[0])
            q, rep = _quantize_matrix(f"{name}{list(idx)}", jnp.asarray(mat),
                                      st, cfg, key, container,
                                      recorder=recorder)
            reports.append(rep)
            if progress:
                progress(rep)
            return q

        if not lead:
            new = one(w, ())
        else:
            flat = w.reshape((-1,) + w.shape[-2:])
            qs = [one(flat[i], (i,)) for i in range(flat.shape[0])]
            new = {k: jnp.stack([q[k] for q in qs]).reshape(
                lead + qs[0][k].shape) for k in qs[0]}
        if "b" in node:
            new["b"] = node["b"]
        return new

    def walk(path, node):
        hit = visit(path, node)
        if hit is not None:
            return hit
        if isinstance(node, dict):
            return {k: walk(path + (jax.tree_util.DictKey(k),), v)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(path + (jax.tree_util.SequenceKey(i),), v)
                    for i, v in enumerate(node)]
        if isinstance(node, tuple):
            return tuple(walk(path + (jax.tree_util.SequenceKey(i),), v)
                         for i, v in enumerate(node))
        return node

    return walk((), params), reports


# ==========================================================================
# Abstract (dry-run) variant: shapes only, no decomposition
# ==========================================================================
def quantized_abstract(params: Any, rank: int, block_size: int = 32,
                       container: str = "int8") -> Any:
    """ShapeDtypeStruct mirror of what quantize_model_params produces.

    Used by the dry-run to lower the serving path of a 32B model without
    ever materializing (or SVD-ing) its weights.
    """
    def visit(path, node):
        if not (isinstance(node, dict) and "w" in node
                and hasattr(node["w"], "ndim") and node["w"].ndim >= 2):
            return None
        names = _names(path)
        if any(n in EXCLUDE_NAMES for n in names):
            return node
        w = node["w"]
        lead, (m, n) = w.shape[:-2], w.shape[-2:]
        mpad = -(-m // block_size) * block_size  # MXINT row padding
        r = min(rank, min(m, n) // 2) if min(m, n) < 2 * rank else rank
        S = jax.ShapeDtypeStruct
        new = {
            "scale": S(lead + (mpad // block_size, n), jnp.float32),
            "l": S(lead + (m, r), jnp.float32),
            "r": S(lead + (r, n), jnp.float32),
            "gscale": S(lead + (r,), jnp.float32),
        }
        if container == "packed4":
            new["packed"] = S(lead + (mpad // 2, n), jnp.uint8)
        else:
            new["codes"] = S(lead + (mpad, n), jnp.int8)
        if "b" in node:
            new["b"] = node["b"]
        return new

    def walk(path, node):
        hit = visit(path, node)
        if hit is not None:
            return hit
        if isinstance(node, dict):
            return {k: walk(path + (jax.tree_util.DictKey(k),), v)
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(path + (jax.tree_util.SequenceKey(i),), v)
                    for i, v in enumerate(node)]
        if isinstance(node, tuple):
            return tuple(walk(path + (jax.tree_util.SequenceKey(i),), v)
                         for i, v in enumerate(node))
        return node

    return walk((), params)


# ==========================================================================
# QPEFT split / merge
# ==========================================================================
def _is_qlinear(node: Any) -> bool:
    return isinstance(node, dict) and ("codes" in node or "packed" in node)


def split_qpeft(qparams: Any) -> Tuple[Any, Any]:
    """(trainable, frozen): adapters {"l","r"} train; backbone freezes.

    Both trees keep the full nesting structure; the trainable tree holds
    ``None`` where nothing trains (dropped by jax as empty subtrees)."""
    def walk(node):
        if _is_qlinear(node):
            train = {"l": node["l"], "r": node["r"]}
            frozen = {k: v for k, v in node.items() if k not in ("l", "r")}
            return train, frozen
        if isinstance(node, dict):
            pairs = {k: walk(v) for k, v in node.items()}
            return ({k: t for k, (t, _) in pairs.items() if t is not None},
                    {k: f for k, (_, f) in pairs.items()})
        if isinstance(node, (list, tuple)):
            pairs = [walk(v) for v in node]
            t = type(node)(p[0] for p in pairs)
            f = type(node)(p[1] for p in pairs)
            return (t if any(p[0] is not None for p in pairs) else None), f
        return None, node

    t, f = walk(qparams)
    return t if t is not None else {}, f


def merge_qpeft(trainable: Any, frozen: Any) -> Any:
    """Inverse of split_qpeft."""
    def walk(t, f):
        if _is_qlinear(f):
            out = dict(f)
            if isinstance(t, dict):
                out.update(t)
            return out
        if isinstance(f, dict):
            return {k: walk(t.get(k) if isinstance(t, dict) else None, v)
                    for k, v in f.items()}
        if isinstance(f, (list, tuple)):
            ts = t if isinstance(t, (list, tuple)) else [None] * len(f)
            return type(f)(walk(ti, fi) for ti, fi in zip(ts, f))
        return f
    return walk(trainable, frozen)


def qpeft_grad_scales(trainable: Any, frozen: Any) -> Any:
    """Per-rank gradient-scale tree aligned with the trainable tree."""
    def walk(t, f):
        if isinstance(t, dict) and "l" in t and "r" in t and _is_qlinear(f):
            return {"gscale": f["gscale"]}
        if isinstance(t, dict):
            return {k: walk(v, f.get(k) if isinstance(f, dict) else None)
                    for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            fs = f if isinstance(f, (list, tuple)) else [None] * len(t)
            return type(t)(walk(ti, fi) for ti, fi in zip(t, fs))
        return None
    return walk(trainable, frozen)


def set_qpeft_scaling(qparams: Any, mode: str = "gamma", gamma: float = 0.1,
                      alpha: float = 5.0) -> Any:
    """Rebuild every gscale vector in a quantized tree (γ or SGP).

    Vectorized over leading (scan / expert) dims: the preserved-rank mask
    is recovered from the existing gscale (< 1 ⇔ preserved), so each
    stacked matrix keeps its own k*.
    """
    def walk(node):
        if _is_qlinear(node):
            out = dict(node)
            preserved = node["gscale"] < 1.0
            if mode == "gamma":
                g = jnp.where(preserved, gamma, 1.0)
            elif mode == "sgp":
                # rank-wise SGP (Eq. 8–9): σ_i from the R rows (R = ΣVᵀ)
                sigma = jnp.linalg.norm(node["r"], axis=-1)
                s_pres = jnp.where(preserved, sigma, 0.0)
                sigma1 = jnp.maximum(jnp.max(s_pres, axis=-1, keepdims=True),
                                     1e-12)
                lam = jnp.clip((alpha + 1.0) * sigma
                               / (alpha * sigma + sigma1), 0.0, 1.0)
                g = jnp.where(preserved, 1.0 - lam, 1.0)
            elif mode == "none":
                g = jnp.ones_like(node["gscale"])
            else:
                raise ValueError(mode)
            out["gscale"] = g.astype(jnp.float32)
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node
    return walk(qparams)
