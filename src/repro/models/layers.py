"""Shared neural-net building blocks (pure JAX, no flax)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.linear import Ctx, dp_axes_of, hint, init_linear, linear


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def init_norm(d: int, kind: str = "rmsnorm", dtype=jnp.float32) -> Dict:
    p = {"g": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


def norm(params: Dict, x: jax.Array, kind: str = "rmsnorm",
         eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["g"].astype(jnp.float32)
    if "b" in params:
        y = y + params["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               kind: str = "full") -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,). kind:
    full — rotate all D dims; half — first D/2 dims only (ChatGLM 2d-RoPE
    style); none — passthrough."""
    if kind == "none":
        return x
    d = x.shape[-1]
    rot_d = d if kind == "full" else d // 2
    freqs = rope_frequencies(rot_d, theta)  # (rot_d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, rot_d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot_d].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rotated = jnp.stack([out1, out2], axis=-1).reshape(xr.shape)
    if rot_d < d:
        rotated = jnp.concatenate([rotated, x[..., rot_d:].astype(jnp.float32)],
                                  axis=-1)
    return rotated.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def init_mlp(key: jax.Array, d: int, d_ff: int, act: str,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"up": init_linear(ks[0], d, d_ff, dtype=dtype),
         "down": init_linear(ks[1], d_ff, d, scale=1.0 / (d_ff ** 0.5),
                             dtype=dtype)}
    if act == "swiglu":
        p["gate"] = init_linear(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp(ctx: Ctx, params: Dict, x: jax.Array, act: str,
        prefix: str = "") -> jax.Array:
    dp = dp_axes_of(ctx)
    up = linear(ctx, params["up"], x, f"{prefix}.up")
    if act == "swiglu":
        gate = linear(ctx, params["gate"], x, f"{prefix}.gate")
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = hint(ctx, h, dp, None, "model")      # column-parallel intermediate
    y = linear(ctx, params["down"], h, f"{prefix}.down")
    return hint(ctx, y, dp, None, None)      # row-parallel out (AR folded)


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------
def init_embedding(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Dict:
    return {"w": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
                  ).astype(dtype)}


def embed(params: Dict, tokens: jax.Array, dtype) -> jax.Array:
    return params["w"].astype(dtype)[tokens]


def chunked_softmax_xent(
    x: jax.Array,              # (B, S, D) final hidden states
    head: Dict,                # linear params for D → V
    labels: jax.Array,         # (B, S) int32
    ctx: Ctx,
    chunk: int = 512,
) -> jax.Array:
    """Mean token cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; inside a chunk the (B, c, V) logits live
    only transiently (and V is model-sharded under pjit, so the per-device
    footprint is (B·c·V/tp)). Returns scalar mean loss (f32).
    """
    if ctx.tap is not None:
        # head stays full-precision (not quantized) — no calibration tap,
        # and recording inside the scan body would leak tracers
        ctx = Ctx(compute_dtype=ctx.compute_dtype)
    b, s, d = x.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    nchunks = s // c
    xc = x.reshape(b, nchunks, c, d).swapaxes(0, 1)       # (n, B, c, D)
    lc = labels.reshape(b, nchunks, c).swapaxes(0, 1)     # (n, B, c)

    dp = dp_axes_of(ctx)

    def step(carry, inp):
        xi, li = inp
        logits = linear(ctx, head, xi).astype(jnp.float32)  # (B, c, V)
        logits = hint(ctx, logits, dp, None, "model")       # vocab-parallel
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(li, logits.shape[-1], dtype=jnp.float32)
        lab = jnp.sum(logits * onehot, axis=-1)
        return carry + jnp.sum(lse - lab), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)
