"""RG-LRU recurrent block (Griffin / RecurrentGemma, De et al. 2024).

Block:  x → [gate branch: W_gate → GeLU] ⊙ [W_branch → causal conv1d(w) →
RG-LRU] → W_out.  The RG-LRU recurrence

    r_t = σ(W_a h̃_t + b_a)         (recurrence gate)
    i_t = σ(W_x h̃_t + b_x)         (input gate)
    log a_t = −c · r_t · softplus(Λ)
    y_t = a_t ⊙ y_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ h̃_t)

is a diagonal linear recurrence ⇒ training/prefill uses
``jax.lax.associative_scan`` (O(log S) depth, sub-quadratic — this is why
recurrentgemma runs the 500k-context shape). Decode carries (y, conv
state) with O(1) per-step cost.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.linear import Ctx, dp_axes_of, hint, init_linear, linear

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, dr, cw = cfg.d_model, cfg.d_rnn_, cfg.conv_width
    ks = jax.random.split(key, 6)
    # Λ init so that a = σ(Λ)^c lies in (0.9, 0.999) — Griffin appendix
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus⁻¹(−log(u)/c)
    return {
        "w_gate": init_linear(ks[0], d, dr, dtype=dtype),
        "w_branch": init_linear(ks[1], d, dr, dtype=dtype),
        "w_out": init_linear(ks[2], dr, d, scale=1.0 / dr**0.5, dtype=dtype),
        "w_a": init_linear(ks[3], dr, dr, bias=True, dtype=dtype),
        "w_x": init_linear(ks[4], dr, dr, bias=True, dtype=dtype),
        "conv_w": (jax.random.normal(key, (cw, dr), jnp.float32)
                   / cw**0.5).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "lam": lam.astype(dtype),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    dr = cfg.d_rnn_
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _causal_conv_seq(params: Dict, x: jax.Array,
                     state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over (B, S, dr); returns (y, new_state)."""
    cw = params["conv_w"].shape[0]
    hist = state if state is not None else jnp.zeros(
        (x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([hist.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i].astype(x.dtype)
            for i in range(cw))
    y = y + params["conv_b"].astype(x.dtype)
    new_state = xp[:, -(cw - 1):] if cw > 1 else hist
    return y, new_state


def _gates(ctx: Ctx, params: Dict, h: jax.Array, prefix: str):
    r = jax.nn.sigmoid(linear(ctx, params["w_a"], h, f"{prefix}.w_a")
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(linear(ctx, params["w_x"], h, f"{prefix}.w_x")
                       .astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    return a, beta * i * h.astype(jnp.float32)


def rglru_seq(
    ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
    cache: Optional[Dict] = None, prefix: str = "rglru",
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Full-sequence block apply (training / prefill).

    ``lengths`` (B,): per-row valid prefix for right-padded prompts. Pad
    steps are forced to the identity transition (a=1, b=0), so the scan
    carries each row's state at its last valid position to the end —
    exactly the state decode must resume from."""
    bsz, s, _ = x.shape
    dp = dp_axes_of(ctx)
    gate = jax.nn.gelu(linear(ctx, params["w_gate"], x, f"{prefix}.w_gate"))
    gate = hint(ctx, gate, dp, None, "model")
    branch = linear(ctx, params["w_branch"], x, f"{prefix}.w_branch")
    branch = hint(ctx, branch, dp, None, "model")
    conv_in_state = cache["conv"] if cache is not None else None
    h, conv_state = _causal_conv_seq(params, branch, conv_in_state)
    a, b = _gates(ctx, params, h, prefix)  # (B, S, dr) each, f32

    if lengths is not None:
        valid = (jnp.arange(s)[None, :] < lengths[:, None])[..., None]
        a = jnp.where(valid, a, 1.0)
        b = jnp.where(valid, b, 0.0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, y_scan = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = y_scan.astype(x.dtype) * gate
    out = linear(ctx, params["w_out"], y, f"{prefix}.w_out")
    out = hint(ctx, out, dp, None, None)

    if cache is not None:
        cache = dict(cache)
        cache["h"] = y_scan[:, -1]  # pre-gate recurrent state, f32
        if lengths is None:
            cache["conv"] = conv_state.astype(cache["conv"].dtype)
            cache["pos"] = jnp.full((bsz,), s, jnp.int32)
        else:
            # per-row conv history: the cw-1 branch inputs right before
            # each row's length L (xp index L maps to branch position
            # L - (cw - 1), i.e. the window feeding decode step L)
            cw = params["conv_w"].shape[0]
            xp = jnp.concatenate(
                [cache["conv"].astype(branch.dtype), branch], axis=1)
            ix = (lengths[:, None] + jnp.arange(cw - 1)[None, :])[..., None]
            cache["conv"] = jnp.take_along_axis(
                xp, ix, axis=1).astype(cache["conv"].dtype)
            cache["pos"] = lengths.astype(jnp.int32)
    return out, cache


def rglru_step(
    ctx: Ctx, params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
    prefix: str = "rglru",
) -> Tuple[jax.Array, Dict]:
    """One decode step; x: (B, 1, D)."""
    gate = jax.nn.gelu(linear(ctx, params["w_gate"], x, f"{prefix}.w_gate"))
    branch = linear(ctx, params["w_branch"], x, f"{prefix}.w_branch")
    cw = params["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"].astype(branch.dtype), branch], axis=1)
    h = sum(hist[:, i:i + 1] * params["conv_w"][i].astype(branch.dtype)
            for i in range(cw)) + params["conv_b"].astype(branch.dtype)
    a, b = _gates(ctx, params, h, prefix)  # (B, 1, dr)
    y = a[:, 0] * cache["h"] + b[:, 0]
    out = y[:, None, :].astype(x.dtype) * gate
    out = linear(ctx, params["w_out"], out, f"{prefix}.w_out")
    return out, {"h": y, "conv": hist[:, 1:].astype(cache["conv"].dtype),
                 "pos": cache["pos"] + 1}
