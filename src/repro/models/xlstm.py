"""xLSTM blocks (Beck et al., 2024): mLSTM (parallel) + sLSTM (sequential).

mLSTM — matrix-memory LSTM with exponential gating. Its parallel form is
linear attention with a (t, s) decay matrix
``log D_ts = F_t − F_s + ĩ_s`` (F = cumulative log-sigmoid forget gates),
stabilized by a running max m_t. We compute it *chunked* with an online
max (same memory discipline as blockwise attention: no S×S materialization)
and use the O(d²)-state recurrent form for decode — which is what makes
the 500k-context decode shape run with constant memory.

sLSTM — scalar-memory LSTM with recurrent gate connections (block-diagonal
per head), inherently sequential ⇒ ``lax.scan`` over time.

Block wrappers follow the paper: mLSTM block = up-proj (×2) → mixer →
gated down-proj; sLSTM block = mixer → GeLU FFN (×4/3).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.linear import Ctx, init_linear, linear

NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


# ==========================================================================
# mLSTM
# ==========================================================================
def init_mlstm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    dp = int(d * cfg.mlstm_proj_factor)
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "up": init_linear(ks[0], d, dp, dtype=dtype),
        "up_gate": init_linear(ks[1], d, dp, dtype=dtype),
        "wq": init_linear(ks[2], dp, dp, dtype=dtype),
        "wk": init_linear(ks[3], dp, dp, dtype=dtype),
        "wv": init_linear(ks[4], dp, dp, dtype=dtype),
        "w_if": init_linear(ks[5], dp, 2 * h, bias=True, dtype=dtype),
        "down": init_linear(ks[6], dp, d, scale=1.0 / dp**0.5, dtype=dtype),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    dp = int(cfg.d_model * cfg.mlstm_proj_factor)
    h = cfg.n_heads
    hd = dp // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _mlstm_qkvif(ctx: Ctx, params: Dict, u: jax.Array, h: int, prefix: str):
    b, s, dp = u.shape
    hd = dp // h
    q = linear(ctx, params["wq"], u, f"{prefix}.wq").reshape(b, s, h, hd)
    k = linear(ctx, params["wk"], u, f"{prefix}.wk").reshape(b, s, h, hd)
    v = linear(ctx, params["wv"], u, f"{prefix}.wv").reshape(b, s, h, hd)
    gates = linear(ctx, params["w_if"], u, f"{prefix}.w_if").astype(jnp.float32)
    i_pre, f_pre = gates[..., :h], gates[..., h:]  # (B, S, H)
    return q, k, v, i_pre, f_pre


def _mlstm_parallel(q, k, v, i_pre, f_pre, chunk: int = 256) -> jax.Array:
    """Chunked stabilized parallel mLSTM. q,k,v: (B,S,H,hd); gates (B,S,H)."""
    b, s, h, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    logf = jax.nn.log_sigmoid(f_pre)                    # (B,S,H)
    fcum = jnp.cumsum(logf, axis=1)                     # F_t = Σ_{u≤t} logσ(f_u)
    # log D_ts = Σ_{u=s+1}^{t} logσ(f_u) + ĩ_s = F_t − F_s + ĩ_s (s ≤ t),
    # matching the recurrent form C_t = f_t C_{t−1} + i_t k_t v_tᵀ.
    a_q = fcum                                          # per-query F_t
    a_k = fcum - i_pre                                  # per-key F_s − ĩ_s

    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, zq) for t in (q, k, v))
        a_q = jnp.pad(a_q, ((0, 0), (0, pad), (0, 0)), constant_values=0.0)
        a_k = jnp.pad(a_k, ((0, 0), (0, pad), (0, 0)), constant_values=jnp.inf)
    n_ch = (s + pad) // c
    qs = q.reshape(b, n_ch, c, h, hd).swapaxes(0, 1)
    ks_ = k.reshape(b, n_ch, c, h, hd).swapaxes(0, 1)
    vs = v.reshape(b, n_ch, c, h, hd).swapaxes(0, 1)
    aqs = a_q.reshape(b, n_ch, c, h).swapaxes(0, 1)
    aks = a_k.reshape(b, n_ch, c, h).swapaxes(0, 1)
    pos = jnp.arange(s + pad).reshape(n_ch, c)

    def one_q(args):
        qi, aqi, qp = args  # (B,c,H,hd), (B,c,H), (c,)

        def kv_step(carry, inp):
            m, num, den = carry
            kj, vj, akj, kp = inp
            # log decay (B,H,cq,ck) = aq_t − ak_s ; mask s ≤ t
            ld = aqi.transpose(0, 2, 1)[:, :, :, None] - akj.transpose(0, 2, 1)[:, :, None, :]
            mask = qp[:, None] >= kp[None, :]
            ld = jnp.where(mask[None, None], ld, NEG)
            m_new = jnp.maximum(m, jnp.max(ld, axis=-1))
            dmat = jnp.exp(ld - m_new[..., None])
            sc = jnp.einsum("bqhd,bchd->bhqc", qi, kj,
                            preferred_element_type=jnp.float32) * scale
            w = sc * dmat
            corr = jnp.exp(m - m_new)
            num_new = num * corr[..., None] + jnp.einsum(
                "bhqc,bchd->bhqd", w, vj.astype(jnp.float32))
            den_new = den * corr + jnp.sum(w, axis=-1)
            return (m_new, num_new, den_new), None

        m0 = jnp.full((b, h, c), NEG, jnp.float32)
        num0 = jnp.zeros((b, h, c, hd), jnp.float32)
        den0 = jnp.zeros((b, h, c), jnp.float32)
        (m, num, den), _ = jax.lax.scan(kv_step, (m0, num0, den0),
                                        (ks_, vs, aks, pos))
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        return out.transpose(0, 2, 1, 3)  # (B,c,H,hd)

    out = jax.lax.map(one_q, (qs, aqs, pos))
    out = out.swapaxes(0, 1).reshape(b, s + pad, h, hd)
    return out[:, :s]


def mlstm_seq(
    ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
    cache: Optional[Dict] = None, prefix: str = "mlstm",
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, _ = x.shape
    h = cfg.n_heads
    u = linear(ctx, params["up"], x, f"{prefix}.up")
    g = linear(ctx, params["up_gate"], x, f"{prefix}.up_gate")
    q, k, v, i_pre, f_pre = _mlstm_qkvif(ctx, params, u, h, prefix)
    mixed = _mlstm_parallel(q, k, v, i_pre, f_pre)
    y = mixed.reshape(b, s, -1).astype(x.dtype) * jax.nn.silu(g)
    out = linear(ctx, params["down"], y, f"{prefix}.down")

    if cache is not None:
        # rebuild the recurrent state by scanning the last chunk is O(S);
        # instead fold the full sequence once (prefill cost O(S·d²/h)).
        cache = _mlstm_fold(q, k, v, i_pre, f_pre, cache, lengths)
    return out, cache


def _mlstm_fold(q, k, v, i_pre, f_pre, cache: Dict,
                lengths=None) -> Dict:
    """Sequentially fold a whole sequence into the (C, n, m) state.
    ``lengths`` (B,): rows freeze their state at their own valid length
    (pad steps of a right-padded prompt are skipped per row)."""
    del q
    b, s, h, hd = k.shape

    def step(carry, t):
        C, n, m = carry
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        it, ft = i_pre[:, t], f_pre[:, t]
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(logf + m - m_new)
        C_new = f_s[..., None, None] * C + i_s[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]) / (hd ** 0.5)
        n_new = f_s[..., None] * n + i_s[..., None] * kt / (hd ** 0.5)
        if lengths is not None:
            live = (t < lengths)[:, None]                       # (B, 1)
            C_new = jnp.where(live[..., None, None], C_new, C)
            n_new = jnp.where(live[..., None], n_new, n)
            m_new = jnp.where(live, m_new, m)
        return (C_new, n_new, m_new), None

    (C, n, m), _ = jax.lax.scan(
        step, (cache["C"], cache["n"], cache["m"]), jnp.arange(s))
    add = (jnp.full((b,), s, jnp.int32) if lengths is None
           else lengths.astype(jnp.int32))
    return {"C": C, "n": n, "m": m, "pos": cache["pos"] + add}


def mlstm_step(
    ctx: Ctx, params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
    prefix: str = "mlstm",
) -> Tuple[jax.Array, Dict]:
    """Recurrent decode step; x: (B, 1, D). State is O(H·hd²) — constant in
    sequence length (the 500k shape relies on this)."""
    b = x.shape[0]
    h = cfg.n_heads
    u = linear(ctx, params["up"], x, f"{prefix}.up")
    g = linear(ctx, params["up_gate"], x, f"{prefix}.up_gate")
    q, k, v, i_pre, f_pre = _mlstm_qkvif(ctx, params, u, h, prefix)
    hd = q.shape[-1]
    qt = q[:, 0].astype(jnp.float32)
    kt = k[:, 0].astype(jnp.float32)
    vt = v[:, 0].astype(jnp.float32)
    it, ft = i_pre[:, 0], f_pre[:, 0]

    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + cache["m"], it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + cache["m"] - m_new)
    C = f_s[..., None, None] * cache["C"] + i_s[..., None, None] * (
        kt[..., :, None] * vt[..., None, :]) / (hd ** 0.5)
    n = f_s[..., None] * cache["n"] + i_s[..., None] * kt / (hd ** 0.5)

    num = jnp.einsum("bhde,bhd->bhe", C, qt)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt))
    mixed = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]  # (B,H,hd)
    y = mixed.reshape(b, 1, -1).astype(x.dtype) * jax.nn.silu(g)
    out = linear(ctx, params["down"], y, f"{prefix}.down")
    return out, {"C": C, "n": n, "m": m_new, "pos": cache["pos"] + 1}


# ==========================================================================
# sLSTM
# ==========================================================================
def init_slstm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 7)
    dff = int(d * cfg.slstm_proj_factor)
    return {
        "w_gates": init_linear(ks[0], d, 4 * d, bias=True, dtype=dtype),
        # recurrent block-diagonal weights: (H, hd, 4·hd)
        "r_gates": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32)
                    / hd**0.5).astype(dtype),
        "w_out": init_linear(ks[2], d, d, scale=1.0 / d**0.5, dtype=dtype),
        "ffn_up": init_linear(ks[3], d, dff, dtype=dtype),
        "ffn_down": init_linear(ks[4], dff, d, scale=1.0 / dff**0.5, dtype=dtype),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z,
            "pos": jnp.zeros((batch,), jnp.int32)}


def _slstm_scan(params: Dict, gates_x: jax.Array, state: Dict, h_heads: int,
                lengths=None):
    """Run the sequential sLSTM over (B, S, 4d) precomputed input gates.
    ``lengths`` (B,): rows stop updating state past their valid length."""
    b, s, d4 = gates_x.shape
    d = d4 // 4
    hd = d // h_heads
    r_g = params["r_gates"].astype(jnp.float32)  # (H, hd, 4hd)

    def step(carry, t):
        c, n, hh, m = carry
        gx = gates_x[:, t].astype(jnp.float32)
        hr = hh.reshape(b, h_heads, hd)
        gr = jnp.einsum("bhd,hde->bhe", hr, r_g).reshape(b, 4 * d)
        g = gx + gr
        z_pre, i_pre, f_pre, o_pre = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        if lengths is not None:
            live = (t < lengths)[:, None]                       # (B, 1)
            c_new = jnp.where(live, c_new, c)
            n_new = jnp.where(live, n_new, n)
            h_new = jnp.where(live, h_new, hh)
            m_new = jnp.where(live, m_new, m)
        return (c_new, n_new, h_new, m_new), h_new

    init = (state["c"], state["n"], state["h"], state["m"])
    (c, n, hh, m), hs = jax.lax.scan(step, init, jnp.arange(s))
    return hs.swapaxes(0, 1), {"c": c, "n": n, "h": hh, "m": m}  # (B,S,d)


def slstm_seq(
    ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
    cache: Optional[Dict] = None, prefix: str = "slstm",
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    state = cache if cache is not None else init_slstm_cache(cfg, x.shape[0])
    gates_x = linear(ctx, params["w_gates"], x, f"{prefix}.w_gates")
    hs, new_state = _slstm_scan(params, gates_x, state, cfg.n_heads, lengths)
    y = linear(ctx, params["w_out"], hs.astype(x.dtype), f"{prefix}.w_out")
    y = y + linear(ctx, params["ffn_down"],
                   jax.nn.gelu(linear(ctx, params["ffn_up"], y,
                                      f"{prefix}.ffn_up")),
                   f"{prefix}.ffn_down")
    if cache is not None:
        new_state["pos"] = cache["pos"] + (
            x.shape[1] if lengths is None else lengths.astype(jnp.int32))
        return y, new_state
    return y, None


def slstm_step(
    ctx: Ctx, params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
    prefix: str = "slstm",
) -> Tuple[jax.Array, Dict]:
    y, new_state = slstm_seq(ctx, params, x, cfg, cache=cache, prefix=prefix)
    return y, new_state
