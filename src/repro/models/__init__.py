"""Model zoo: pure-JAX transformer families routed through QuantizedLinear."""
from repro.models.linear import Ctx, dequant_weight, init_linear, is_linear_params, linear
from repro.models.transformer import (
    apply_block,
    decode_step,
    forward,
    init_cache,
    init_lm,
    layer_layout,
    lm_loss,
    prefill,
    prefill_chunk,
    verify_chunk,
)

__all__ = [
    "Ctx", "dequant_weight", "init_linear", "is_linear_params", "linear",
    "apply_block", "decode_step", "forward", "init_cache", "init_lm",
    "layer_layout", "lm_loss", "prefill", "prefill_chunk", "verify_chunk",
]
