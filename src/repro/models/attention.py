"""Attention variants: GQA (full/local/sliding-window), MLA (DeepSeek-V2).

Sequence-parallel memory safety: training/prefill attention is *blockwise*
(two-level chunking with online softmax, Rabe–Staats style) so the S×S
score matrix never materializes — mandatory for the 32k prefill shapes.
Decode (Sq = 1) uses direct attention over the cache.

Caches (slot-based, continuous-batching ready, **head-major**):
  full attn : {"k": (B, KV, S_max, hd), "v": …, "pos": (B,)} append-at-pos
  local attn: ring buffer of ``window`` slots + per-(row, slot) absolute
              positions
  MLA       : compressed {"ckv": (B, S_max, r_kv), "kpe": (B, S_max, pe)}
              with the *absorbed* decode formulation (q folded through the
              up-projections, so the per-step cost scales with r_kv, not
              H·hd·S).

K/V pages are stored head-major — (B, KV, S, hd), int8/int4 scales
(B, KV, S); int4 packs two slots per uint8 byte along the slot axis,
(B, KV, S/2, hd) — because decode reads them thousands of times per prefill
write: the score/value GEMMs batch over (B, KV), so head-major streams
contiguous (S, hd) tiles with **no cache relayout** (the old
sequence-major layout made XLA transpose the whole cache every step,
the single largest decode HBM term), and it is the layout the Pallas
flash-decode kernel (``kernels.decode_attention``) tiles over. Decode
attention dispatches through ``kernels.ops.decode_attention_op`` under
``ctx.fused`` (kernel on TPU, fused-XLA elsewhere — int8 codes feed the
matmuls directly, scales fold into the score/probability planes);
``fused="off"`` keeps the legacy dequantize-then-einsum lowering.

Every batch row carries its *own* write position (``pos``: (B,)) and its
own per-slot validity/position map (``slot_pos``: (B, slots), -1 ⇒ empty
slot). Rows therefore decode independently: one row can be at position 7
of a fresh prompt while its neighbour is 300 tokens into generation —
the substrate the serving engine's continuous batching builds on. Seq
(prefill) entry points take an optional ``lengths`` (B,) so right-padded
prompts populate exactly their valid prefix.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope
from repro.models.linear import (Ctx, dp_axes_of, fused_mode, hint,
                                 init_linear, linear, weight_of)

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ==========================================================================
# Blockwise attention core
# ==========================================================================
def blockwise_attention(
    q: jax.Array,              # (B, Sq, KV, G, hd)
    k: jax.Array,              # (B, Sk, KV, hd)
    v: jax.Array,              # (B, Sk, KV, hd)
    q_pos: jax.Array,          # (Sq,) absolute positions
    k_pos: jax.Array,          # (Sk,) absolute positions; -1 ⇒ invalid slot
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    ctx: Optional[Ctx] = None,
    shard_chunks: bool = False,
) -> jax.Array:
    """Online-softmax chunked attention. Returns (B, Sq, KV, G, hd).

    The query-chunk dimension is *vmapped* (one batched kv-scan, not a
    sequential per-chunk loop), so it can carry a sharding: with
    ``shard_chunks`` the chunk dim is constrained to the ``model`` axis —
    the TP strategy when KV heads don't divide the axis (sharding head_dim
    instead would all-reduce every score chunk; sharding query chunks
    keeps attention compute model-parallel with zero per-step collectives
    at the cost of one K/V gather per layer). q_chunk shrinks as needed so
    the chunk count divides the axis.
    """
    b, sq, kv_h, g, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / (hd ** 0.5)

    qc = min(q_chunk, sq)
    tp = ctx.mesh.shape.get("model", 1) if (
        ctx is not None and ctx.mesh is not None) else 1
    if shard_chunks and tp > 1:
        # make the chunk count a multiple of the model axis
        while qc > 16 and ((sq + (-sq) % qc) // qc) % tp:
            qc //= 2
    kc = min(kv_chunk, sk)
    pad_q = (-sq) % qc
    pad_k = (-sk) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=0)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=-1)
    nq, nk = (sq + pad_q) // qc, (sk + pad_k) // kc

    # (nk, B, kc, KV, hd) — scan operand layout
    ks = k.reshape(b, nk, kc, kv_h, hd).swapaxes(0, 1)
    vs = v.reshape(b, nk, kc, kv_h, hd).swapaxes(0, 1)
    kps = k_pos.reshape(nk, kc)
    qs = q.reshape(b, nq, qc, kv_h, g, hd).swapaxes(0, 1)  # (nq, B, qc, KV, G, hd)
    qps = q_pos.reshape(nq, qc)
    if shard_chunks and ctx is not None and nq % max(tp, 1) == 0:
        qs = hint(ctx, qs, "model", None, None, None, None, None)
        qps = hint(ctx, qps, "model", None)

    def one_q_chunk(qi, qp):
        # qi: (B, qc, KV, G, hd), qp: (qc,)
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, vi, kp = inp
            s = jnp.einsum("bqkgd,bckd->bkgqc", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            mask = kp[None, :] >= 0
            if causal:
                mask = mask & (qp[:, None] >= kp[None, :])
            if window is not None:
                mask = mask & (qp[:, None] - kp[None, :] < window)
            s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vi.dtype), vi,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv_h, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_h, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv_h, g, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (B, qc, KV, G, hd)

    out = jax.vmap(one_q_chunk)(qs, qps)  # (nq, B, qc, KV, G, hd)
    out = out.swapaxes(0, 1).reshape(b, sq + pad_q, kv_h, g, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,              # (B, 1, KV, G, hd)
    k: jax.Array,              # (B, KV, S, hd) head-major cache pages
    v: jax.Array,
    q_pos: jax.Array,          # (B,) per-row absolute positions
    k_pos: jax.Array,          # (B, S) per-(row, slot) positions; -1 invalid
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token attention over a dequantized cache (the legacy
    ``fused="off"`` lowering; ``kernels.ops.decode_attention_op`` is the
    deployment path). Each batch row masks against its own slot map, so
    co-batched rows may sit at arbitrary, unrelated positions
    (continuous batching)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqkgd,bksd->bkgqs", q, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    mask = (k_pos >= 0) & (k_pos <= q_pos[:, None])      # (B, S)
    if window is not None:
        mask = mask & (q_pos[:, None] - k_pos < window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # a row with no valid slot emits zeros (matching the fused paths),
    # not the uniform V-mean an all-NEG_INF softmax would produce
    p = jnp.where(jnp.any(mask, -1)[:, None, None, None, None], p, 0.0)
    out = jnp.einsum("bkgqs,bksd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ==========================================================================
# GQA attention layer (full or sliding-window)
# ==========================================================================
def init_attention(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d,
                          scale=1.0 / ((cfg.n_heads * hd) ** 0.5 * (2 * cfg.n_layers) ** 0.5),
                          dtype=dtype),
    }


INT4 = "int4"   # kv-cache dtype sentinel: packed4 nibble container


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, local: bool,
                    dtype=jnp.float32, pages: Optional[int] = None,
                    page_size: Optional[int] = None) -> Dict:
    """Head-major K/V pages: (B, KV, slots, hd) — see the module
    docstring for why decode wants this layout.

    ``dtype=jnp.int8`` enables quantized KV: codes + per-(b, head, slot)
    f32 scales. Halves (vs bf16) the dominant decode HBM footprint — the
    quantization-native serving option that lets e.g. qwen-32B's 32k×128
    MHA cache fit a single v5e pod. ``dtype="int4"`` (:data:`INT4`)
    halves it again: uint8 pages (B, KV, slots/2, hd) hold two 4-bit
    codes per byte packed along the *slot* axis (slot 2j = low nibble,
    the ``pack_codes_4bit`` layout), scales stay per-(b, head, slot) —
    at fixed HBM that doubles the servable slots or context vs int8.
    The slot count is rounded up to even so byte pairs never straddle
    the ring boundary; the extra slot is masked (slot_pos = -1) until
    written. Dequantization fuses into the decode-attention kernel / XLA
    score matmuls (``kernels.ops.decode_attention_op``).

    ``pages``/``page_size`` select the **paged** layout instead (full
    attention only): K/V become a physical page *pool* shared by every
    batch row — ``(pages, KV, page_size, hd)``, packed4 ``(pages, KV,
    page_size/2, hd)``, scales ``(pages, KV, page_size)`` — and each
    row addresses it through a ``block_table`` (B, ceil(max_len /
    page_size)) of page ids (``serve.pages`` owns the allocator and
    guarantees every entry is a valid page). There is no ``slot_pos``
    map: logical slot j of a row always holds position j, so the decode
    mask is just ``j <= pos``. ``page_size`` must be even (int4 nibble
    pairs never straddle a page)."""
    packed4 = dtype == INT4
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    if pages is not None:
        if local:
            raise ValueError(
                "paged KV supports full attention only (a sliding-window "
                "ring buffer wraps inside blocks, breaking block sharing)")
        if page_size is None or page_size % 2:
            raise ValueError(f"paged KV needs an even page_size, got "
                             f"{page_size}")
        n_blocks = -(-max_len // page_size)
        pshape = ((pages, kv, page_size // 2, hd), jnp.uint8) if packed4 \
            else ((pages, kv, page_size, hd), dtype)
        cache = {
            "k": jnp.zeros(*pshape),
            "v": jnp.zeros(*pshape),
            "block_table": jnp.zeros((batch, n_blocks), jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
        if dtype == jnp.int8 or packed4:
            cache["k_scale"] = jnp.zeros((pages, kv, page_size), jnp.float32)
            cache["v_scale"] = jnp.zeros((pages, kv, page_size), jnp.float32)
        return cache
    slots = min(cfg.window, max_len) if local else max_len
    if packed4:
        slots += slots % 2
    pshape = ((batch, kv, slots // 2, hd), jnp.uint8) if packed4 \
        else ((batch, kv, slots, hd), dtype)
    cache = {
        "k": jnp.zeros(*pshape),
        "v": jnp.zeros(*pshape),
        "slot_pos": jnp.full((batch, slots), -1, jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if dtype == jnp.int8 or packed4:
        cache["k_scale"] = jnp.zeros((batch, kv, slots), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, kv, slots), jnp.float32)
    return cache


def kv_quantize(x: jax.Array, qmax: int = 127) -> Tuple[jax.Array, jax.Array]:
    """(B, S, KV, hd) → int codes in [-qmax, qmax] + per-(B, S, KV) f32
    scale. ``qmax=127`` is the int8 cache; ``qmax=7`` the int4 one
    (symmetric, matching the int8 convention — the packed container
    could carry -8, but an asymmetric grid buys < 7% range for a
    scale-zero-point asymmetry the fused score planes don't model)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -qmax, qmax).astype(jnp.int8)
    return codes, scale


def kv_dequantize(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return codes.astype(dtype) * scale[..., None].astype(dtype)


def _cache_kv(cache: Dict, dtype) -> Tuple[jax.Array, jax.Array]:
    """Read the cache's K/V in compute dtype (dequantizing int8 codes /
    unpacking + dequantizing packed4 int4 pages)."""
    if "k_scale" in cache:
        k, v = cache["k"], cache["v"]
        if k.dtype == jnp.uint8:    # packed4: slots on axis -2
            from repro.quant.mxint import unpack_codes_4bit
            k, v = unpack_codes_4bit(k), unpack_codes_4bit(v)
        return (kv_dequantize(k, cache["k_scale"], dtype),
                kv_dequantize(v, cache["v_scale"], dtype))
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def attn_strategy(ctx: Ctx, cfg: ModelConfig) -> str:
    """TP placement inside attention.

    "heads"  — KV heads divide the model axis: classic Megatron head
               sharding, no attention-internal collectives.
    "chunks" — they don't (e.g. qwen 40H, chatglm kv=2 on 16-way TP):
               shard the *query-chunk* dim in seq attention and the cache
               *sequence* dim at decode (flash-decode: softmax-stat psums
               only). Sharding head_dim instead would all-reduce every
               (B,H,Sq,Sk) score block — measured 10-100× more collective
               bytes on the 32k shapes.
    "none"   — no mesh / no model axis.
    """
    if ctx.mesh is None or ctx.mesh.shape.get("model", 1) <= 1:
        return "none"
    return "heads" if cfg.n_kv_heads % ctx.mesh.shape["model"] == 0 \
        else "chunks"


def _qkv(ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
         positions: jax.Array, prefix: str):
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = linear(ctx, params["wq"], x, f"{prefix}.wq").reshape(b, s, cfg.n_heads, hd)
    k = linear(ctx, params["wk"], x, f"{prefix}.wk").reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(ctx, params["wv"], x, f"{prefix}.wv").reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_kind)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_kind)
    g = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(b, s, cfg.n_kv_heads, g, hd)
    dp = dp_axes_of(ctx)
    strat = attn_strategy(ctx, cfg)
    h_ax = "model" if strat == "heads" else None
    q = hint(ctx, q, dp, None, h_ax, None, None)
    k = hint(ctx, k, dp, None, h_ax, None)
    v = hint(ctx, v, dp, None, h_ax, None)
    return q, k, v


def _populate_kv_cache(cache: Dict, k: jax.Array, v: jax.Array,
                       lengths: jax.Array) -> Dict:
    """Scatter freshly-prefilled K/V prefixes into a slot cache, per row.

    For every row r (valid prefix length L_r) and cache slot j, the slot
    holds the *latest* position p ≡ j (mod slots) with p < L_r — the
    ring-buffer invariant (for full attention slots ≥ S, so p = j) — or
    is empty (slot_pos = -1). Rows may have different lengths, which is
    what lets the serving engine right-pad prompts to one compiled
    prefill shape.

    ``k``/``v`` arrive sequence-major from the projection (B, S, KV, hd);
    the gather runs in that layout and one transpose lands them in the
    cache's head-major pages — paid once per prefill, never at decode.
    int4 caches additionally pack slot pairs two-per-byte after the
    transpose (the slot axis is then axis -2, the pack axis).
    """
    b, s = k.shape[:2]
    slots = cache["slot_pos"].shape[1]     # logical count (packed4 pages
    j = jnp.arange(slots)[None, :]         # hold two slots per byte row)
    last = lengths[:, None] - 1                         # (B, 1)
    p = j + slots * jnp.floor_divide(last - j, slots)   # (B, slots)
    valid = p >= 0
    idx = jnp.clip(p, 0, s - 1)

    def gather(src):  # (B, S, ...) → (B, slots, ...)
        ix = idx.reshape(idx.shape + (1,) * (src.ndim - 2))
        return jnp.take_along_axis(src, ix, axis=1)

    cache = dict(cache)
    packed4 = cache["k"].dtype == jnp.uint8
    if "k_scale" in cache:  # int8 / packed4-int4 KV
        kc, ksc = kv_quantize(k, 7 if packed4 else 127)
        vc, vsc = kv_quantize(v, 7 if packed4 else 127)
        m3 = valid[..., None]
        cache["k_scale"] = jnp.where(m3, gather(ksc), 0.0).transpose(0, 2, 1)
        cache["v_scale"] = jnp.where(m3, gather(vsc), 0.0).transpose(0, 2, 1)
        k, v = kc, vc

    def to_pages(src, page_dtype):  # (B, S, KV, hd) → head-major pages
        m4 = valid[..., None, None]
        hm = jnp.where(m4, gather(src), jnp.zeros((), src.dtype)
                       ).transpose(0, 2, 1, 3)          # (B, KV, slots, hd)
        if packed4:
            from repro.quant.mxint import pack_codes_4bit
            return pack_codes_4bit(hm)                  # (B, KV, slots/2, hd)
        return hm.astype(page_dtype)

    cache["k"] = to_pages(k, cache["k"].dtype)
    cache["v"] = to_pages(v, cache["v"].dtype)
    cache["slot_pos"] = jnp.where(valid, p, -1).astype(jnp.int32)
    cache["pos"] = lengths.astype(jnp.int32)
    return cache


def attention_seq(
    ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
    local: bool = False, causal: bool = True,
    cache: Optional[Dict] = None, prefix: str = "attn",
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Training / prefill attention over a full sequence.

    ``lengths`` (B,): per-row valid prefix (right-padded prompts). Only
    cache population depends on it — causality already keeps positions
    < L from attending to pad keys at positions ≥ L."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _qkv(ctx, params, x, cfg, positions, prefix)
    window = cfg.window if local else None
    strat = attn_strategy(ctx, cfg)
    if ctx.use_pallas or (cache is not None and ctx.fused == "on"):
        # serving path: VMEM-resident flash kernel (no HBM score traffic).
        # Only explicit opt-ins route here — ``use_pallas`` (set by the
        # serving engine when its fused mode resolves to the kernel) or
        # ``fused="on"`` on a cache-populating prefill — so training and
        # dry-run lowerings keep the configured blockwise strategy, and
        # ``fused="on"`` validates the full kernel serving path off-TPU.
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, positions, positions,
                              causal=causal, window=window or 0)
    else:
        out = blockwise_attention(q, k, v, positions, positions,
                                  causal=causal, window=window, ctx=ctx,
                                  q_chunk=ctx.attn_q_chunk,
                                  kv_chunk=ctx.attn_kv_chunk,
                                  shard_chunks=(strat == "chunks"))
    h_ax = "model" if strat == "heads" else None
    out = hint(ctx, out, dp_axes_of(ctx), None, h_ax, None, None)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim_)
    y = linear(ctx, params["wo"], out, f"{prefix}.wo")
    y = hint(ctx, y, dp_axes_of(ctx), None, None)

    if cache is not None:  # prefill: populate per-row valid prefixes
        if lengths is None:
            lengths = jnp.full((b,), s, jnp.int32)
        cache = _populate_kv_cache(cache, k, v, lengths)
    return y, cache


def _write_nibble(pages: jax.Array, codes: jax.Array, rows: jax.Array,
                  slot: jax.Array) -> jax.Array:
    """Write one token's int4 codes into the packed4 pages, per row.

    ``pages`` (B, KV, S/2, hd) uint8, ``codes`` (B, KV, hd) int8 in
    [-7, 7], ``slot`` (B,) logical slot per row. Only the addressed
    nibble of the byte at slot//2 changes; its pair nibble is preserved
    — the read-modify-write stays a single-byte-row scatter, the same
    shape as the int8 single-slot write."""
    byte = pages[rows, :, slot // 2]                     # (B, KV, hd)
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo = (slot % 2 == 0)[:, None, None]
    new = jnp.where(lo, (byte & 0xF0) | u, (byte & 0x0F) | (u << 4))
    return pages.at[rows, :, slot // 2].set(new.astype(jnp.uint8))


def _paged_page_size(cache: Dict) -> int:
    """Logical slots per physical page (uint8 pool rows hold two)."""
    rows = cache["k"].shape[2]
    return rows * 2 if cache["k"].dtype == jnp.uint8 else rows


def attention_step(
    ctx: Ctx, params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
    local: bool = False, prefix: str = "attn",
) -> Tuple[jax.Array, Dict]:
    """One decode step; x: (B, 1, D). Rows advance independently: each
    writes at its own slot and masks against its own slot map. A paged
    cache (``block_table`` present) routes the write and the attention
    read through the slot's page-table indirection instead."""
    b = x.shape[0]
    hd = cfg.head_dim_
    pos = cache["pos"]                        # (B,)
    positions = pos[:, None].astype(jnp.int32)  # (B, 1) per-row RoPE phase
    q, k, v = _qkv(ctx, params, x, cfg, positions, prefix)

    paged = "block_table" in cache
    rows = jnp.arange(b)
    new_cache = dict(cache)
    packed4 = cache["k"].dtype == jnp.uint8
    if paged:
        # logical slot j always holds position j (full attention only),
        # so the slot map is implicit: mask is just j <= pos. The write
        # goes to (page = block_table[row, j // ps], offset = j % ps);
        # every table entry is a valid page (retired rows point at their
        # private parked page), so the unconditional write of a dead row
        # can never corrupt a page another request owns.
        if local:
            raise ValueError("paged KV cache supports full attention only")
        bt = cache["block_table"]             # (B, nb)
        ps = _paged_page_size(cache)
        nslots = bt.shape[1] * ps
        slot = jnp.minimum(pos, nslots - 1)
        page = jnp.take_along_axis(bt, (slot // ps)[:, None], 1)[:, 0]
        off = slot % ps
        wrow, wslot = page, off               # scatter coords in the pool
    else:
        slots = cache["slot_pos"].shape[1]    # logical count (≠ page rows
        # for packed4, whose uint8 pages hold two slots per byte)
        slot = jnp.mod(pos, slots) if local else jnp.minimum(pos, slots - 1)
        wrow, wslot = rows, slot
    if "k_scale" in cache:  # int8/int4 KV: quantize the appended token
        kc, ksc = kv_quantize(k, 7 if packed4 else 127)
        vc, vsc = kv_quantize(v, 7 if packed4 else 127)
        new_cache["k_scale"] = cache["k_scale"].at[wrow, :, wslot].set(ksc[:, 0])
        new_cache["v_scale"] = cache["v_scale"].at[wrow, :, wslot].set(vsc[:, 0])
        k, v = kc, vc
    if packed4:
        knew = _write_nibble(cache["k"], k[:, 0], wrow, wslot)
        vnew = _write_nibble(cache["v"], v[:, 0], wrow, wslot)
    else:
        knew = cache["k"].at[wrow, :, wslot].set(k[:, 0].astype(cache["k"].dtype))
        vnew = cache["v"].at[wrow, :, wslot].set(v[:, 0].astype(cache["v"].dtype))
    new_cache.update(k=knew, v=vnew, pos=pos + 1)
    if paged:
        spos = jnp.broadcast_to(jnp.arange(nslots, dtype=jnp.int32)[None],
                                (b, nslots))
        block_table = cache["block_table"]
    else:
        spos = cache["slot_pos"].at[rows, slot].set(pos)
        new_cache["slot_pos"] = spos
        block_table = None

    window = cfg.window if local else None
    mode = fused_mode(ctx)
    if mode == "off":
        # legacy lowering: dequantize the whole cache, dense softmax
        if paged:
            from repro.kernels.ops import gather_pages
            flat = dict(new_cache,
                        k=gather_pages(knew, block_table),
                        v=gather_pages(vnew, block_table))
            if "k_scale" in cache:
                flat["k_scale"] = gather_pages(new_cache["k_scale"],
                                               block_table)
                flat["v_scale"] = gather_pages(new_cache["v_scale"],
                                               block_table)
            kd, vd = _cache_kv(flat, x.dtype)
        else:
            kd, vd = _cache_kv(new_cache, x.dtype)
        out = decode_attention(q, kd, vd, pos, spos, window=window)
    else:
        # deployment path: flash-decode kernel (TPU / interpret under
        # ``fused="on"``) or the fused-XLA lowering — the cache is read
        # once, in its storage dtype, straight from the head-major pages
        # (paged: the kernel follows the block-table indirection per
        # sequence grid step; XLA gathers the pages once)
        from repro.kernels.ops import decode_attention_op
        out = decode_attention_op(
            q[:, 0], new_cache["k"], new_cache["v"], pos, spos,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"),
            window=window or 0, kernel=(mode == "kernel"),
            block_table=block_table)[:, None]
        out = out.astype(x.dtype)
    h_ax = "model" if attn_strategy(ctx, cfg) == "heads" else None
    out = hint(ctx, out, dp_axes_of(ctx), None, h_ax, None, None)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    y = linear(ctx, params["wo"], out, f"{prefix}.wo")
    y = hint(ctx, y, dp_axes_of(ctx), None, None)
    return y, new_cache


def _chunk_nibble_rmw(old_gather, scatter, codes, start, length, c):
    """General packed4 chunk write: merge the chunk's int4 codes into the
    byte planes at **arbitrary** ``start`` parity and ``length`` via a
    per-byte read-modify-write, one lane per touched byte.

    ``codes``: (C, KV, hd) int8 in [-7, 7]; ``old_gather(byte_idx)``
    returns the current bytes (NB, KV, hd) for absolute byte indices
    (NB,); ``scatter(rows, merged)`` writes them back under
    ``mode="drop"`` with ``rows`` already steered to ``row_count`` (the
    OOB drop sentinel) on lanes where neither nibble comes from the
    chunk. Unlike the old block-aligned byte-pair pack (which required
    even, block-aligned chunk starts), this subsumes prefill *and*
    speculative-verify chunks: boundary bytes keep their out-of-chunk
    nibble from the old value, so a verify chunk starting mid-byte never
    clobbers the accepted token stored in its partner nibble."""
    nby = c // 2 + 1                           # byte lanes covering the chunk
    bi = jnp.arange(nby)
    byte_idx = start // 2 + bi                 # absolute byte index per lane
    ol = 2 * byte_idx - start                  # chunk offset of the low slot
    oh = ol + 1
    lo_in = (ol >= 0) & (ol < length)
    hi_in = (oh >= 0) & (oh < length)
    any_in = lo_in | hi_in
    old = old_gather(byte_idx)                 # (NB, KV, hd) uint8
    cl = codes[jnp.clip(ol, 0, c - 1)]
    ch = codes[jnp.clip(oh, 0, c - 1)]
    lo_u = (cl.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    hi_u = ((ch.astype(jnp.int32) & 0xF) << 4).astype(jnp.uint8)
    merged = (jnp.where(lo_in[:, None, None], lo_u, old & 0x0F)
              | jnp.where(hi_in[:, None, None], hi_u, old & 0xF0))
    return scatter(any_in, byte_idx, merged.astype(jnp.uint8))


def attention_chunk(
    ctx: Ctx, params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
    row: jax.Array, start: jax.Array, length: jax.Array,
    prefix: str = "attn",
) -> Tuple[jax.Array, Dict]:
    """Multi-token chunk attention for one cache row: process ``length``
    tokens at positions ``[start, start+length)`` for slot ``row``,
    attending to everything already stored below ``start`` (earlier
    chunks, prefix-cache blocks, decoded context) plus the chunk itself,
    causally. Serves both **chunked prefill** and the **speculative
    verify** pass (k drafted tokens scored in one dispatch — there
    ``start`` is the row's live decode position, arbitrary parity).

    Works over the paged layout (``block_table`` present: writes through
    the row's page table) and the unpaged slot layout (writes straight
    into row ``row`` of the (B, KV, S, hd) pages).

    ``x``: (1, C, D) — the chunk, right-padded to the compiled chunk
    length C; ``start``: absolute position of its first token;
    ``length``: valid tokens (≤ C). row/start/length are traced, so one
    compiled shape serves every chunk of every admission.

    The chunk's K/V is written into the row's storage *in the storage
    container* (quantized / packed) at slots ``[start, start+C)`` —
    unless ``ctx.chunk_store`` is off (speculative verify), which skips
    every storage write and leaves the draft steps' step-graph entries
    in place. Attention reads the chunk **fresh** (compute dtype) and
    only the *context* from storage — so a single-chunk prompt with no
    cached prefix runs numerically identical ops to the unpaged
    one-shot prefill, a verify chunk scores drafted tokens with exactly
    the full-model numerics a per-token decode would use, and
    multi-chunk context pays exactly the storage-dtype round trip
    decode would pay anyway. The context mask is ``slot < start`` —
    anything at or above
    ``start`` (pad garbage, stale speculative writes from a rejected
    tail) is invisible. Pad-lane writes (``length < C``) are
    **dropped**: their row/page index is steered out of bounds and the
    scatter runs with ``mode="drop"``. Clamping them into the row's tail
    block instead would collide with valid slots whenever the final
    chunk overhangs the table (``start + C > nslots``) — the
    duplicate-index scatter is unordered, so pad garbage could replace
    real prompt KV.

    Packed4 note: the write is a per-byte nibble read-modify-write
    (:func:`_chunk_nibble_rmw`), valid at any chunk start/length —
    boundary bytes preserve their out-of-chunk partner nibble, so a
    verify chunk starting at an odd position cannot clobber the last
    accepted token's stored codes."""
    b, c, _ = x.shape
    hd = cfg.head_dim_
    positions = start + jnp.arange(c)
    q, k, v = _qkv(ctx, params, x, cfg, positions, prefix)

    paged = "block_table" in cache
    packed4 = cache["k"].dtype == jnp.uint8
    quant = "k_scale" in cache
    new_cache = dict(cache)

    # ---- write the chunk into the row's storage container ------------
    slots = start + jnp.arange(c)
    valid = jnp.arange(c) < length            # pad lanes write nowhere
    if paged:
        bt_row = cache["block_table"][row]    # (nb,)
        ps = _paged_page_size(cache)
        nb = bt_row.shape[0]
        nslots = nb * ps
        n_pool = cache["k"].shape[0]          # OOB sentinel for pad drops
        woffs = slots % ps
        wrows = jnp.where(valid, bt_row[jnp.minimum(slots // ps, nb - 1)],
                          n_pool)             # (C,)
    else:
        nslots = cache["slot_pos"].shape[1]
        n_rows = cache["pos"].shape[0]        # OOB row sentinel for drops
        woffs = slots                         # OOB offsets drop themselves
        wrows = jnp.where(valid, row, n_rows)
    kw, vw = k[0], v[0]                       # (C, KV, hd)
    if quant:
        kc, ksc = kv_quantize(k, 7 if packed4 else 127)
        vc, vsc = kv_quantize(v, 7 if packed4 else 127)
        if ctx.chunk_store:
            new_cache["k_scale"] = cache["k_scale"].at[wrows, :, woffs].set(
                ksc[0], mode="drop")
            new_cache["v_scale"] = cache["v_scale"].at[wrows, :, woffs].set(
                vsc[0], mode="drop")
        kw, vw = kc[0], vc[0]
        if ctx.step_parity:
            # speculative verify: a per-token decode reads its *own*
            # just-written K/V back through the storage quantizer
            # (attention_step writes first, then attends over new_cache).
            # Round-trip the chunk here so verify logits are bit-identical
            # to the decode steps they stand in for — int4's coarse grid
            # otherwise flips argmaxes and breaks token parity.
            k = kv_dequantize(kc, ksc, jnp.float32).astype(k.dtype)
            v = kv_dequantize(vc, vsc, jnp.float32).astype(v.dtype)
    if packed4 and ctx.chunk_store:
        if paged:
            def gather_old(plane):
                def g(byte_idx):
                    pg = bt_row[jnp.minimum((2 * byte_idx) // ps, nb - 1)]
                    return plane[pg, :, (2 * byte_idx % ps) // 2]
                return g

            def scatter_to(plane):
                def s(any_in, byte_idx, merged):
                    pg = bt_row[jnp.minimum((2 * byte_idx) // ps, nb - 1)]
                    pg = jnp.where(any_in, pg, n_pool)
                    return plane.at[pg, :, (2 * byte_idx % ps) // 2].set(
                        merged, mode="drop")
                return s
        else:
            nbytes = cache["k"].shape[2]

            def gather_old(plane):
                def g(byte_idx):
                    bp = plane[row]                       # (KV, S/2, hd)
                    sel = jnp.clip(byte_idx, 0, nbytes - 1)
                    return bp[:, sel].transpose(1, 0, 2)  # (NB, KV, hd)
                return g

            def scatter_to(plane):
                def s(any_in, byte_idx, merged):
                    rr = jnp.where(any_in, row, n_rows)
                    return plane.at[rr, :, byte_idx].set(merged, mode="drop")
                return s
        knew = _chunk_nibble_rmw(gather_old(cache["k"]),
                                 scatter_to(cache["k"]), kw, start, length, c)
        vnew = _chunk_nibble_rmw(gather_old(cache["v"]),
                                 scatter_to(cache["v"]), vw, start, length, c)
    elif ctx.chunk_store:
        knew = cache["k"].at[wrows, :, woffs].set(kw.astype(cache["k"].dtype),
                                                  mode="drop")
        vnew = cache["v"].at[wrows, :, woffs].set(vw.astype(cache["v"].dtype),
                                                  mode="drop")
    if ctx.chunk_store:
        new_cache.update(k=knew, v=vnew,
                         pos=cache["pos"].at[row].set(start + length))
        if not paged:
            new_cache["slot_pos"] = cache["slot_pos"].at[wrows, woffs].set(
                slots.astype(jnp.int32), mode="drop")
    # else: read-only chunk (speculative verify). The draft steps
    # already persisted step-graph K/V at these slots, and leaving
    # storage untouched keeps the cache bitwise identical to what
    # non-speculative decode would have written — verify numerics can
    # only ever gate acceptance, never leak into future tokens.

    # ---- attention: [stored context ‖ fresh chunk], causal -----------
    if paged:
        from repro.kernels.ops import gather_pages
        ctxk = gather_pages(cache["k"], bt_row[None])    # pre-chunk pages
        ctxv = gather_pages(cache["v"], bt_row[None])    # (1, KV, S', hd)
        ksg = vsg = None
        if quant:
            ksg = gather_pages(cache["k_scale"], bt_row[None])  # (1, KV, S)
            vsg = gather_pages(cache["v_scale"], bt_row[None])
    else:
        ctxk, ctxv = cache["k"][row][None], cache["v"][row][None]
        ksg = vsg = None
        if quant:
            ksg = cache["k_scale"][row][None]
            vsg = cache["v_scale"][row][None]
    if packed4:
        from repro.quant.mxint import unpack_codes_4bit
        ctxk, ctxv = unpack_codes_4bit(ctxk), unpack_codes_4bit(ctxv)
    if quant:
        ctxk = kv_dequantize(ctxk, ksg, jnp.float32)
        ctxv = kv_dequantize(ctxv, vsg, jnp.float32)
    ctxk = ctxk.astype(k.dtype).transpose(0, 2, 1, 3)    # (1, S, KV, hd)
    ctxv = ctxv.astype(v.dtype).transpose(0, 2, 1, 3)
    sctx = jnp.arange(nslots)
    ctx_pos = jnp.where(sctx < start, sctx, -1)          # only < start valid
    kk = jnp.concatenate([ctxk, k], axis=1)
    vv = jnp.concatenate([ctxv, v], axis=1)
    k_pos = jnp.concatenate([ctx_pos, positions])
    if ctx.use_pallas or ctx.fused == "on":
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, kk, vv, positions, k_pos, causal=True,
                              window=0)
    else:
        out = blockwise_attention(q, kk, vv, positions, k_pos, causal=True,
                                  ctx=ctx, q_chunk=ctx.attn_q_chunk,
                                  kv_chunk=ctx.attn_kv_chunk)
    out = out.reshape(b, c, cfg.n_heads * hd)
    y = linear(ctx, params["wo"], out, f"{prefix}.wo")
    return y, new_cache


# ==========================================================================
# Cross attention (whisper decoder)
# ==========================================================================
def cross_attention(
    ctx: Ctx, params: Dict, x: jax.Array, memory_kv: Tuple[jax.Array, jax.Array],
    cfg: ModelConfig, prefix: str = "xattn",
) -> jax.Array:
    """Decoder-side cross attention; memory K/V precomputed at prefill."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = linear(ctx, params["wq"], x, f"{prefix}.wq").reshape(b, s, cfg.n_heads, hd)
    k, v = memory_kv  # (B, Sm, KV, hd)
    g = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(b, s, cfg.n_kv_heads, g, hd)
    sm = k.shape[1]
    mpos = jnp.arange(sm)
    strat = attn_strategy(ctx, cfg)
    out = blockwise_attention(q, k, v, jnp.arange(s), mpos, causal=False,
                              ctx=ctx, shard_chunks=(strat == "chunks"))
    out = out.reshape(b, s, cfg.n_heads * hd)
    return linear(ctx, params["wo"], out, f"{prefix}.wo")


def cross_memory(ctx: Ctx, params: Dict, memory: jax.Array, cfg: ModelConfig,
                 prefix: str = "xattn") -> Tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output."""
    b, sm, _ = memory.shape
    hd = cfg.head_dim_
    k = linear(ctx, params["wk"], memory, f"{prefix}.wk").reshape(b, sm, cfg.n_kv_heads, hd)
    v = linear(ctx, params["wv"], memory, f"{prefix}.wv").reshape(b, sm, cfg.n_kv_heads, hd)
    return k, v


# ==========================================================================
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ==========================================================================
def init_mla(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim_
    r, pe, h = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.n_heads
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": init_linear(ks[0], d, r, dtype=dtype),
        "w_kpe": init_linear(ks[1], d, pe, dtype=dtype),
        "w_uk": init_linear(ks[2], r, h * hd, dtype=dtype),
        "w_uv": init_linear(ks[3], r, h * hd, dtype=dtype),
        "wo": init_linear(ks[4], h * hd, d,
                          scale=1.0 / ((h * hd) ** 0.5 * (2 * cfg.n_layers) ** 0.5),
                          dtype=dtype),
        "ckv_norm": {"g": jnp.ones((r,), dtype)},
    }
    if cfg.q_lora_rank:
        p["w_dq"] = init_linear(ks[5], d, cfg.q_lora_rank, dtype=dtype)
        p["w_uq"] = init_linear(ks[6], cfg.q_lora_rank, h * (hd + pe), dtype=dtype)
        p["q_norm"] = {"g": jnp.ones((cfg.q_lora_rank,), dtype)}
    else:
        p["w_q"] = init_linear(ks[7], d, h * (hd + pe), dtype=dtype)
    return p


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.float32) -> Dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _mla_q(ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
           positions: jax.Array, prefix: str):
    from repro.models.layers import norm
    b, s, _ = x.shape
    hd, pe, h = cfg.head_dim_, cfg.rope_head_dim, cfg.n_heads
    if cfg.q_lora_rank:
        cq = linear(ctx, params["w_dq"], x, f"{prefix}.w_dq")
        cq = norm(params["q_norm"], cq, "rmsnorm")
        q = linear(ctx, params["w_uq"], cq, f"{prefix}.w_uq")
    else:
        q = linear(ctx, params["w_q"], x, f"{prefix}.w_q")
    q = q.reshape(b, s, h, hd + pe)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta, "full")
    return q_nope, q_pe


def _mla_compress(ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, prefix: str):
    from repro.models.layers import norm
    ckv = linear(ctx, params["w_dkv"], x, f"{prefix}.w_dkv")
    ckv = norm(params["ckv_norm"], ckv, "rmsnorm")
    kpe = linear(ctx, params["w_kpe"], x, f"{prefix}.w_kpe")
    kpe = apply_rope(kpe[:, :, None, :], positions, cfg.rope_theta, "full")
    return ckv, kpe[:, :, 0, :]


def mla_seq(
    ctx: Ctx, params: Dict, x: jax.Array, cfg: ModelConfig,
    cache: Optional[Dict] = None, prefix: str = "attn",
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Prefill/train MLA: expand K/V per head, blockwise attention."""
    b, s, _ = x.shape
    hd, pe, h = cfg.head_dim_, cfg.rope_head_dim, cfg.n_heads
    positions = jnp.arange(s)
    q_nope, q_pe = _mla_q(ctx, params, x, cfg, positions, prefix)
    ckv, kpe = _mla_compress(ctx, params, x, cfg, positions, prefix)

    dp = dp_axes_of(ctx)
    k_nope = linear(ctx, params["w_uk"], ckv, f"{prefix}.w_uk").reshape(b, s, h, hd)
    k_nope = hint(ctx, k_nope, dp, None, "model", None)
    v = linear(ctx, params["w_uv"], ckv, f"{prefix}.w_uv").reshape(b, s, h, hd)
    v = hint(ctx, v, dp, None, "model", None)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kpe[:, :, None, :], (b, s, h, pe))], -1)
    q = jnp.concatenate([q_nope, q_pe], -1)
    # treat as MHA (KV = H, G = 1); pad V's head_dim up to hd+pe for the
    # shared kernel, then slice back
    qg = q.reshape(b, s, h, 1, hd + pe)
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pe)))
    strat = "chunks" if (ctx.mesh is not None and
                         h % ctx.mesh.shape.get("model", 1)) else "none"
    out = blockwise_attention(qg, k, v_pad, positions, positions, causal=True,
                              ctx=ctx, shard_chunks=(strat == "chunks"))
    out = out.reshape(b, s, h, hd + pe)[..., :hd].reshape(b, s, h * hd)
    y = linear(ctx, params["wo"], out, f"{prefix}.wo")

    if cache is not None:
        # latent rows beyond a row's length hold pad garbage; the decode
        # mask (k_pos ≤ pos) keeps them invisible until overwritten
        cache = dict(cache)
        cache["ckv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1)
        cache["kpe"] = jax.lax.dynamic_update_slice_in_dim(
            cache["kpe"], kpe.astype(cache["kpe"].dtype), 0, axis=1)
        cache["pos"] = (jnp.full((b,), s, jnp.int32) if lengths is None
                        else lengths.astype(jnp.int32))
    return y, cache


def absorb_mla_weights(mixer: Dict, dtype=jnp.float32) -> Dict:
    """Precompute the dense up-projections for absorbed MLA decode.

    ``mla_step`` folds q through W_uk and the attention output through
    W_uv every token; with a quantized mixer, materializing those via
    ``weight_of`` *inside* the compiled step re-runs dequant + the dense
    L·R product per decode step. The serving engine calls this once per
    (params, engine) session and threads the result through the params
    tree — ``mla_step`` picks up the ``w_uk_dense``/``w_uv_dense`` keys
    and skips the per-step materialization. Works on scan-stacked mixers
    too (leading group dims pass through ``weight_of``)."""
    out = dict(mixer)
    out["w_uk_dense"] = weight_of(mixer["w_uk"], dtype)
    out["w_uv_dense"] = weight_of(mixer["w_uv"], dtype)
    return out


def mla_step(
    ctx: Ctx, params: Dict, x: jax.Array, cache: Dict, cfg: ModelConfig,
    prefix: str = "attn",
) -> Tuple[jax.Array, Dict]:
    """Absorbed-formulation decode: score/value in the r_kv latent space.
    Per-row positions: each row appends at its own ``pos``.

    The dense absorbed projections come from ``w_uk_dense``/``w_uv_dense``
    when the engine pre-absorbed them (:func:`absorb_mla_weights`);
    otherwise they materialize in-step (training-grade fallback). When
    ``ctx.fused`` resolves to the kernel, the latent score/value
    attention routes through ``kernels.ops.decode_attention_op``
    (KV = 1, G = H, the latent dim as head_dim) — the flash-decode
    kernel on TPU; the XLA modes keep the in-place two-einsum latent
    formulation (the latent cache is float, so there is no dequant to
    fuse off-kernel)."""
    b = x.shape[0]
    hd, pe, h, r = cfg.head_dim_, cfg.rope_head_dim, cfg.n_heads, cfg.kv_lora_rank
    pos = cache["pos"]                        # (B,)
    positions = pos[:, None]
    q_nope, q_pe = _mla_q(ctx, params, x, cfg, positions, prefix)  # (B,1,H,hd/pe)
    ckv_t, kpe_t = _mla_compress(ctx, params, x, cfg, positions, prefix)

    rows = jnp.arange(b)
    ckv = cache["ckv"].at[rows, pos].set(ckv_t[:, 0].astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[rows, pos].set(kpe_t[:, 0].astype(cache["kpe"].dtype))
    smax = ckv.shape[1]

    # absorb: q' = q_nope @ W_uk per head → latent space
    w_uk = params.get("w_uk_dense")
    if w_uk is None:
        w_uk = weight_of(params["w_uk"], jnp.float32)
    w_uk = w_uk.astype(jnp.float32).reshape(r, h, hd)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk)  # (B,1,H,r)
    q_lat = hint(ctx, q_lat, dp_axes_of(ctx), None, "model", None)
    scale = 1.0 / ((hd + pe) ** 0.5)
    if fused_mode(ctx) == "kernel":
        # latent-space flash decode: one fused score over [ckv ‖ kpe]
        # (KV = 1, G = H); V is ckv padded to the score width and sliced
        # back. The concat/pad copies cost one cache pass, bought back
        # by the (B, H, S) probability plane never leaving VMEM — a win
        # only on the kernel path, so the XLA modes keep the two-einsum
        # form below, which reads ckv/kpe in place with no copies.
        from repro.kernels.ops import decode_attention_op
        q_cat = jnp.concatenate(
            [q_lat, q_pe.astype(jnp.float32)], -1)[:, 0][:, None]  # (B,1,H,r+pe)
        k_cat = jnp.concatenate(
            [ckv, kpe], -1).astype(jnp.float32)[:, None]           # (B,1,S,r+pe)
        v_cat = jnp.pad(ckv.astype(jnp.float32),
                        ((0, 0), (0, 0), (0, pe)))[:, None]
        k_pos = jnp.broadcast_to(jnp.arange(smax)[None], (b, smax))
        out_lat = decode_attention_op(
            q_cat, k_cat, v_cat, pos, k_pos, scale=scale,
            kernel=True)[:, 0][:, None, :, :r]
    else:
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv.astype(jnp.float32))
                  + jnp.einsum("bqhp,bsp->bhqs", q_pe.astype(jnp.float32),
                               kpe.astype(jnp.float32)))
        scores = scores * scale
        k_pos = jnp.arange(smax)
        mask = k_pos[None, :] <= pos[:, None]     # (B, smax) per-row causality
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhqs,bsr->bqhr", p, ckv.astype(jnp.float32))
    w_uv = params.get("w_uv_dense")
    if w_uv is None:
        w_uv = weight_of(params["w_uv"], jnp.float32)
    w_uv = w_uv.astype(jnp.float32).reshape(r, h, hd)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_uv)
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    y = linear(ctx, params["wo"], out, f"{prefix}.wo")
    return y, {"ckv": ckv, "kpe": kpe, "pos": pos + 1}
