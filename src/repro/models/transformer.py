"""Model composition: config → init / forward / loss / prefill / decode.

Depth is organized as  [prefix | scanned groups | suffix]:

  * ``prefix``  — the leading ``first_dense`` layers (DeepSeek's dense-MLP
    head layers), unrolled;
  * ``groups``  — the remaining depth folded into ``lax.scan`` over stacks
    of one *pattern period* (e.g. recurrentgemma's (rglru, rglru, local)),
    so compile time is O(period), not O(depth) — essential for lowering
    64-layer 32B configs;
  * ``suffix``  — the remainder when depth isn't divisible by the period.

Caches mirror this structure exactly, so decode scans layer-stacked caches
alongside layer-stacked params.

Serving note: every projection in prefill/decode routes through
``repro.models.linear.linear``, so a quantized (Q + LR) param tree
executes the fused Pallas matmul whenever ``ctx.fused`` resolves to the
kernel path (see ``linear.fused_mode``) — including inside the
``lax.scan`` decode body, where the per-layer slice of a stacked group
feeds the kernel directly. Embeddings and the LM head stay
full-precision by PTQ policy and keep the dense path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    chunked_softmax_xent,
    embed,
    init_embedding,
    init_mlp,
    init_norm,
    mlp,
    norm,
)
from repro.models.linear import Ctx, dp_axes_of, hint, init_linear, linear


def _hint_act(ctx: Ctx, x):
    return hint(ctx, x, dp_axes_of(ctx), None, None)

AUX_WEIGHT = 0.01  # MoE load-balance loss coefficient


# ==========================================================================
# Layer layout
# ==========================================================================
def layer_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_prefix, n_groups, n_suffix) — see module docstring."""
    period = len(cfg.block_pattern)
    n_prefix = cfg.first_dense
    n_main = cfg.n_layers - n_prefix
    n_groups = n_main // period
    n_suffix = n_main - n_groups * period
    return n_prefix, n_groups, n_suffix


def _kind_at(cfg: ModelConfig, i: int) -> str:
    return cfg.block_pattern[(i - cfg.first_dense) % len(cfg.block_pattern)] \
        if i >= cfg.first_dense else cfg.block_pattern[0]


# ==========================================================================
# Single block
# ==========================================================================
def init_block(key: jax.Array, cfg: ModelConfig, kind: str, use_moe: bool,
               dtype=jnp.float32, decoder_cross: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype)}
    if kind in ("attn", "local"):
        if cfg.attn_kind == "mla" and kind == "attn":
            p["mixer"] = attn.init_mla(ks[0], cfg, dtype)
        else:
            p["mixer"] = attn.init_attention(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = xlstm_mod.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")

    if decoder_cross:
        p["norm_x"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["cross"] = attn.init_attention(ks[2], cfg, dtype)

    if kind not in ("slstm", "mlstm"):
        if use_moe:
            p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        elif cfg.d_ff > 0:
            p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype, cross: bool = False, pages: Optional[int] = None,
                     page_size: Optional[int] = None) -> Dict:
    # int8 / packed4-int4 ("int4") applies to the (dominant) GQA KV cache
    # only; recurrent states, MLA latents and cross-attention memories
    # stay in a float dtype
    fdtype = jnp.bfloat16 if dtype in (jnp.int8, "int4") else dtype
    if pages is not None and not (kind == "attn" and cfg.attn_kind != "mla"):
        raise ValueError(
            f"paged KV cache supports full GQA attention layers only, "
            f"got kind={kind!r} (attn_kind={cfg.attn_kind!r}) — recurrent "
            f"states and MLA latents have no block-granular sharing story")
    if kind in ("attn", "local"):
        if cfg.attn_kind == "mla" and kind == "attn":
            c = attn.init_mla_cache(cfg, batch, max_len, fdtype)
        else:
            c = attn.init_attn_cache(cfg, batch, max_len, kind == "local",
                                     dtype, pages=pages, page_size=page_size)
    elif kind == "rglru":
        c = rglru_mod.init_rglru_cache(cfg, batch, fdtype)
    elif kind == "mlstm":
        c = xlstm_mod.init_mlstm_cache(cfg, batch)
    elif kind == "slstm":
        c = xlstm_mod.init_slstm_cache(cfg, batch)
    else:
        raise ValueError(kind)
    if cross:
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        c["cross_k"] = jnp.zeros((batch, cfg.enc_seq, kv, hd), fdtype)
        c["cross_v"] = jnp.zeros((batch, cfg.enc_seq, kv, hd), fdtype)
    return c


def apply_block(
    ctx: Ctx,
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    mode: str,          # "seq" (train/prefill) | "step" (decode) |
                        # "chunk" (paged chunked prefill)
    cache: Optional[Dict] = None,
    memory: Optional[jax.Array] = None,  # encoder output (whisper prefill)
    causal: bool = True,
    lengths: Optional[jax.Array] = None,  # (B,) per-row valid prefix (seq)
    chunk_info: Optional[Tuple] = None,   # (row, start, length) for "chunk"
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """Returns (x_out, aux_loss, cache_out)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm(p["norm1"], x, cfg.norm)
    inner_cache = None
    if cache is not None:
        inner_cache = {k: v for k, v in cache.items()
                       if not k.startswith("cross_")}

    if mode == "chunk" and kind != "attn":
        raise ValueError(f"chunked prefill needs full-attention layers, "
                         f"got kind={kind!r}")
    if kind in ("attn", "local"):
        is_mla = cfg.attn_kind == "mla" and kind == "attn"
        if mode == "chunk":
            y, inner_cache = attn.attention_chunk(
                ctx, p["mixer"], h, inner_cache, cfg, *chunk_info)
        elif mode == "seq":
            if is_mla:
                y, inner_cache = attn.mla_seq(ctx, p["mixer"], h, cfg,
                                              cache=inner_cache,
                                              lengths=lengths)
            else:
                y, inner_cache = attn.attention_seq(
                    ctx, p["mixer"], h, cfg, local=(kind == "local"),
                    causal=causal, cache=inner_cache, lengths=lengths)
        else:
            if is_mla:
                y, inner_cache = attn.mla_step(ctx, p["mixer"], h,
                                               inner_cache, cfg)
            else:
                y, inner_cache = attn.attention_step(
                    ctx, p["mixer"], h, inner_cache, cfg,
                    local=(kind == "local"))
    elif kind == "rglru":
        if mode == "seq":
            y, inner_cache = rglru_mod.rglru_seq(ctx, p["mixer"], h, cfg,
                                                 cache=inner_cache,
                                                 lengths=lengths)
        else:
            y, inner_cache = rglru_mod.rglru_step(ctx, p["mixer"], h,
                                                  inner_cache, cfg)
    elif kind == "mlstm":
        if mode == "seq":
            y, inner_cache = xlstm_mod.mlstm_seq(ctx, p["mixer"], h, cfg,
                                                 cache=inner_cache,
                                                 lengths=lengths)
        else:
            y, inner_cache = xlstm_mod.mlstm_step(ctx, p["mixer"], h,
                                                  inner_cache, cfg)
    elif kind == "slstm":
        if mode == "seq":
            y, inner_cache = xlstm_mod.slstm_seq(ctx, p["mixer"], h, cfg,
                                                 cache=inner_cache,
                                                 lengths=lengths)
        else:
            y, inner_cache = xlstm_mod.slstm_step(ctx, p["mixer"], h,
                                                  inner_cache, cfg)
    else:
        raise ValueError(kind)
    x = x + y

    # cross attention (whisper decoder)
    if "cross" in p:
        hx = norm(p["norm_x"], x, cfg.norm)
        if memory is not None:  # prefill/train: build cross K/V from memory
            mem_kv = attn.cross_memory(ctx, p["cross"], memory, cfg)
        else:                   # decode: read from cache
            mem_kv = (cache["cross_k"], cache["cross_v"])
        x = x + attn.cross_attention(ctx, p["cross"], hx, mem_kv, cfg)
        if cache is not None and memory is not None:
            assert inner_cache is not None
            inner_cache = dict(inner_cache)
            inner_cache["cross_k"] = mem_kv[0].astype(cache["cross_k"].dtype)
            inner_cache["cross_v"] = mem_kv[1].astype(cache["cross_v"].dtype)
        elif cache is not None:
            inner_cache = dict(inner_cache)
            inner_cache["cross_k"] = cache["cross_k"]
            inner_cache["cross_v"] = cache["cross_v"]

    if "moe" in p:
        h2 = norm(p["norm2"], x, cfg.norm)
        y2, aux = moe_mod.moe_apply(ctx, p["moe"], h2, cfg)
        x = x + y2
    elif "mlp" in p:
        h2 = norm(p["norm2"], x, cfg.norm)
        x = x + mlp(ctx, p["mlp"], h2, cfg.act)
    return x, aux, inner_cache


# ==========================================================================
# Full model init
# ==========================================================================
def init_lm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    n_prefix, n_groups, n_suffix = layer_layout(cfg)
    period = len(cfg.block_pattern)
    keys = jax.random.split(key, 8)
    cross = cfg.is_encoder_decoder

    params: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[1], cfg.d_model, cfg.vocab,
                                        scale=1.0 / cfg.d_model**0.5,
                                        dtype=dtype)

    # prefix (dense-MLP lead-in layers)
    params["prefix"] = [
        init_block(jax.random.fold_in(keys[2], i), cfg, _kind_at(cfg, i),
                   use_moe=False, dtype=dtype, decoder_cross=cross)
        for i in range(n_prefix)
    ]

    # scanned groups: one stacked param tree per period position
    def group_at(pos: int):
        kind = cfg.block_pattern[pos]
        use_moe = cfg.moe  # main layers past first_dense
        def one(k):
            return init_block(k, cfg, kind, use_moe=use_moe, dtype=dtype,
                              decoder_cross=cross)
        gkeys = jax.random.split(jax.random.fold_in(keys[3], pos), max(n_groups, 1))
        return jax.vmap(one)(gkeys) if n_groups > 0 else None

    params["groups"] = {f"p{pos}": group_at(pos) for pos in range(period)} \
        if n_groups > 0 else {}

    params["suffix"] = [
        init_block(jax.random.fold_in(keys[4], i), cfg,
                   cfg.block_pattern[i % period], use_moe=cfg.moe,
                   dtype=dtype, decoder_cross=cross)
        for i in range(n_suffix)
    ]

    # encoder (whisper)
    if cfg.is_encoder_decoder:
        def enc_block(k):
            return init_block(k, cfg, "attn", use_moe=False, dtype=dtype)
        ekeys = jax.random.split(keys[5], cfg.enc_layers)
        params["encoder"] = {
            "blocks": jax.vmap(enc_block)(ekeys),
            "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        }
        if cfg.d_frontend and cfg.d_frontend != cfg.d_model:
            params["frontend_proj"] = init_linear(keys[6], cfg.d_frontend,
                                                  cfg.d_model, dtype=dtype)

    # vision projector (vlm)
    if cfg.n_vision_tokens:
        params["vision_proj"] = init_linear(keys[7], cfg.d_frontend or cfg.d_model,
                                            cfg.d_model, dtype=dtype)
    return params


# ==========================================================================
# Encoder (whisper): bidirectional transformer over frame embeddings
# ==========================================================================
def _sinusoid(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(ctx: Ctx, params: Dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = frames.astype(ctx.compute_dtype)
    if "frontend_proj" in params:
        x = linear(ctx, params["frontend_proj"], x)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(xc, blk):
        y, _, _ = apply_block(ctx, blk, xc, cfg, "attn", "seq", causal=False)
        return y, None

    if ctx.tap is not None:  # unroll for calibration (see forward())
        for e in range(cfg.enc_layers):
            blk = jax.tree_util.tree_map(lambda a: a[e],
                                         params["encoder"]["blocks"])
            ctx.prefix = f"E{e}."
            x, _ = body(x, blk)
        ctx.prefix = ""
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return norm(params["encoder"]["final_norm"], x, cfg.norm)


# ==========================================================================
# Forward (train / prefill)
# ==========================================================================
def forward(
    ctx: Ctx,
    params: Dict,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    cache: Optional[Dict] = None,
    remat: str = "none",
    lengths: Optional[jax.Array] = None,  # (B,) valid prefix per row
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """Returns (hidden (B,S,D), aux_loss, cache)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, ctx.compute_dtype)
    x = _hint_act(ctx, x)

    memory = None
    if cfg.is_encoder_decoder:
        # decoder positions come from RoPE (config deviation from whisper's
        # learned embeddings — keeps decode caches position-free)
        memory = encode(ctx, params, batch["frames"], cfg)
    if cfg.n_vision_tokens and "vision" in batch:
        vis = linear(ctx, params["vision_proj"],
                     batch["vision"].astype(ctx.compute_dtype))
        x = jnp.concatenate([vis, x], axis=1)

    period = len(cfg.block_pattern)
    aux_total = jnp.zeros((), jnp.float32)

    def run_block(xc, blk, kind, blk_cache):
        return apply_block(ctx, blk, xc, cfg, kind, "seq", cache=blk_cache,
                           memory=memory, lengths=lengths)

    # prefix
    new_prefix_caches = []
    for i, blk in enumerate(params["prefix"]):
        if ctx.tap is not None:
            ctx.prefix = f"L{i}."
        c = cache["prefix"][i] if cache is not None else None
        x, aux, c_out = run_block(x, blk, _kind_at(cfg, i), c)
        aux_total += aux
        new_prefix_caches.append(c_out)
    ctx.prefix = ""

    # scanned groups — unrolled when calibrating (ctx.tap records per-layer
    # input moments eagerly; tracers from a lax.scan body would leak into
    # the tap dict, so calibration walks the stacked params in Python)
    new_group_caches = None
    if params["groups"] and ctx.tap is not None:
        assert cache is None, "calibration runs without decode caches"
        n_groups = layer_layout(cfg)[1]
        n_prefix = layer_layout(cfg)[0]
        for g in range(n_groups):
            for pos in range(period):
                blk = jax.tree_util.tree_map(lambda a: a[g],
                                             params["groups"][f"p{pos}"])
                ctx.prefix = f"L{n_prefix + g * period + pos}."
                x, aux, _ = run_block(x, blk, cfg.block_pattern[pos], None)
                aux_total += aux
        ctx.prefix = ""
    elif params["groups"]:
        def group_body(carry, xs):
            xc, aux_c = carry
            gp, gc = xs
            new_gc = {}
            for pos in range(period):
                kind = cfg.block_pattern[pos]
                c = gc[f"p{pos}"] if gc is not None else None
                xc, aux, c_out = run_block(xc, gp[f"p{pos}"], kind, c)
                aux_c = aux_c + aux
                new_gc[f"p{pos}"] = c_out
            ys = new_gc if gc is not None else 0
            return (xc, aux_c), ys

        if remat == "full":
            group_body = jax.checkpoint(group_body)
        gcaches = cache["groups"] if cache is not None else None
        xs = (params["groups"], gcaches)
        if gcaches is None:
            n_groups = layer_layout(cfg)[1]
            xs = (params["groups"],
                  {f"p{p}": None for p in range(period)})
            # scan needs a scannable xs: replace None caches by dummy zeros
            xs = (params["groups"], jnp.zeros((n_groups,), jnp.float32))
            def group_body_nc(carry, xs_):
                xc, aux_c = carry
                gp, _ = xs_
                for pos in range(period):
                    xc, aux, _ = run_block(xc, gp[f"p{pos}"],
                                           cfg.block_pattern[pos], None)
                    aux_c = aux_c + aux
                return (xc, aux_c), 0
            body = jax.checkpoint(group_body_nc) if remat == "full" else group_body_nc
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), xs)
        else:
            (x, aux_total), new_group_caches = jax.lax.scan(
                group_body, (x, aux_total), xs)

    # suffix
    new_suffix_caches = []
    n_pre, n_grp, _ = layer_layout(cfg)
    for i, blk in enumerate(params["suffix"]):
        if ctx.tap is not None:
            ctx.prefix = f"L{n_pre + n_grp * period + i}."
        c = cache["suffix"][i] if cache is not None else None
        x, aux, c_out = run_block(x, blk, cfg.block_pattern[i % period], c)
        aux_total += aux
        new_suffix_caches.append(c_out)
    ctx.prefix = ""

    x = norm(params["final_norm"], x, cfg.norm)
    new_cache = None
    if cache is not None:
        new_cache = {"prefix": new_prefix_caches,
                     "groups": new_group_caches,
                     "suffix": new_suffix_caches}
    return x, aux_total, new_cache


# ==========================================================================
# Loss (training step objective)
# ==========================================================================
def lm_loss(ctx: Ctx, params: Dict, batch: Dict[str, jax.Array],
            cfg: ModelConfig, remat: str = "none") -> jax.Array:
    hidden, aux, _ = forward(ctx, params, batch, cfg, remat=remat)
    if cfg.n_vision_tokens and "vision" in batch:
        hidden = hidden[:, cfg.n_vision_tokens:]
    head = params.get("lm_head") or {"w": params["embed"]["w"].T}
    xent = chunked_softmax_xent(hidden, head, batch["labels"], ctx)
    return xent + AUX_WEIGHT * aux


# ==========================================================================
# Cache init / prefill / decode
# ==========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32, pages: Optional[int] = None,
               page_size: Optional[int] = None) -> Dict:
    """``pages``/``page_size`` switch the attention layers to the paged
    layout: per-layer physical page pools + per-slot block tables (see
    ``models.attention.init_attn_cache`` and ``serve.pages``). Only
    all-GQA-attention stacks support it."""
    n_prefix, n_groups, n_suffix = layer_layout(cfg)
    period = len(cfg.block_pattern)
    cross = cfg.is_encoder_decoder

    def blockc(kind):
        return init_block_cache(cfg, kind, batch, max_len, dtype, cross,
                                pages=pages, page_size=page_size)

    def stacked(kind):
        one = blockc(kind)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape).copy(), one)

    return {
        "prefix": [blockc(_kind_at(cfg, i)) for i in range(n_prefix)],
        "groups": ({f"p{p}": stacked(cfg.block_pattern[p]) for p in range(period)}
                   if n_groups > 0 else None),
        "suffix": [blockc(cfg.block_pattern[i % period]) for i in range(n_suffix)],
    }


def prefill(ctx: Ctx, params: Dict, batch: Dict[str, jax.Array],
            cfg: ModelConfig, cache: Dict,
            lengths: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Process the prompt; returns (last-token logits, populated cache).

    ``lengths`` (B,): valid prefix per row *including* any prepended
    vision tokens. Prompts right-padded to a fixed compiled shape then
    read their logits at position lengths-1 (the serving engine's
    one-prefill-compile contract); caches populate only the valid prefix.
    """
    hidden, _, cache = forward(ctx, params, batch, cfg, cache=cache,
                               lengths=lengths)
    head = params.get("lm_head") or {"w": params["embed"]["w"].T}
    if lengths is None:
        last = hidden[:, -1:, :]
    else:
        ix = (lengths - 1).astype(jnp.int32)[:, None, None]
        last = jnp.take_along_axis(hidden, ix, axis=1)
    logits = linear(ctx, head, last)
    return logits, cache


def _chunk_stack(ctx: Ctx, params: Dict, tokens: jax.Array, cfg: ModelConfig,
                 cache: Dict, info: Tuple) -> Tuple[jax.Array, Dict]:
    """Shared chunk-mode stack walk for ``prefill_chunk`` /
    ``verify_chunk``: run ``tokens`` (1, C) through every layer in
    "chunk" attention mode (append K/V for the chunk positions, attend
    over [stored context ‖ chunk]). Returns the final-normed hidden
    states (1, C, D) and the updated cache — the callers differ only in
    which positions they push through the LM head."""
    x = embed(params["embed"], tokens, ctx.compute_dtype)
    x = _hint_act(ctx, x)
    period = len(cfg.block_pattern)

    new_prefix = []
    for i, blk in enumerate(params["prefix"]):
        x, _, c = apply_block(ctx, blk, x, cfg, _kind_at(cfg, i), "chunk",
                              cache=cache["prefix"][i], chunk_info=info)
        new_prefix.append(c)

    new_groups = None
    if params["groups"]:
        def body(xc, xs):
            gp, gc = xs
            new_gc = {}
            for pos in range(period):
                xc, _, c = apply_block(ctx, gp[f"p{pos}"], xc, cfg,
                                       cfg.block_pattern[pos], "chunk",
                                       cache=gc[f"p{pos}"], chunk_info=info)
                new_gc[f"p{pos}"] = c
            return xc, new_gc

        x, new_groups = jax.lax.scan(body, x, (params["groups"],
                                               cache["groups"]))

    new_suffix = []
    for i, blk in enumerate(params["suffix"]):
        x, _, c = apply_block(ctx, blk, x, cfg, cfg.block_pattern[i % period],
                              "chunk", cache=cache["suffix"][i],
                              chunk_info=info)
        new_suffix.append(c)

    x = norm(params["final_norm"], x, cfg.norm)
    return x, {"prefix": new_prefix, "groups": new_groups,
               "suffix": new_suffix}


def prefill_chunk(ctx: Ctx, params: Dict, tokens: jax.Array, cfg: ModelConfig,
                  cache: Dict, row: jax.Array, start: jax.Array,
                  length: jax.Array) -> Tuple[jax.Array, Dict]:
    """One chunk of a chunked prefill: run ``tokens`` (1, C) —
    positions ``[start, start+length)``, right-padded to the compiled
    chunk width C — through the stack, appending K/V into slot ``row``'s
    pages and attending over everything already there (earlier chunks
    and prefix-cache blocks). Returns (logits at position length-1 of
    the chunk, updated cache) — the logits only matter on the prompt's
    final chunk, where they seed the first sampled token.

    row/start/length are traced scalars: one compiled shape covers every
    chunk of every admission, which is what lets the serving engine
    interleave long-prompt prefills with live decode steps."""
    x, new_cache = _chunk_stack(ctx, params, tokens, cfg, cache,
                                (row, start, length))
    ix = (length - 1).astype(jnp.int32).reshape(1, 1, 1)
    last = jnp.take_along_axis(x, ix, axis=1)
    head = params.get("lm_head") or {"w": params["embed"]["w"].T}
    logits = linear(ctx, head, last)
    return logits, new_cache


def verify_chunk(ctx: Ctx, params: Dict, tokens: jax.Array, cfg: ModelConfig,
                 cache: Dict, row: jax.Array, start: jax.Array,
                 length: jax.Array, store: bool = False,
                 ) -> Tuple[jax.Array, Dict]:
    """Speculative-decoding verify: score a chunk of k drafted tokens in
    one dispatch. Identical stack walk to :func:`prefill_chunk` (same
    chunk-mode attention over [stored context ‖ chunk]), but the LM
    head is applied at **every** chunk position — logits (1, C, V) —
    because acceptance needs the full-model next-token distribution
    after each drafted token, not just the last one.

    ``store`` decides what happens to the chunk's K/V, and the right
    setting depends on whether the draft graph IS the target graph:

    * ``store=False`` (draft ≡ target — no low-rank correction in the
      params, so ``Ctx.draft`` slices nothing): the verify pass is
      **read-only**. The draft steps already persisted bit-exact
      step-graph K/V at these slots; overwriting them with
      chunk-computed values (a different float reduction order) would
      leak chunk numerics into every future decode step's attention.
      With storage untouched, verify can only gate acceptance, and
      greedy speculative output is *exactly* the non-speculative output.
    * ``store=True`` (the params carry LR slivers): the drafts wrote
      Q-only K/V, which materially differs from the full Q+LR entries
      non-speculative decode would store — the chunk must upgrade the
      slots to full-model K/V. Chunk-vs-step reduction order then
      leaves ulp-level residue in the cache, so parity is near-exact
      rather than structural (flips need logit ties of that width).

    The caller rewinds ``pos`` past any rejected tail; the stale KV
    those positions hold is masked by the ``slot >= pos`` read horizon
    until the next write lands there.

    ``step_parity`` makes chunk attention read its own K/V through the
    storage-dtype round trip, matching the per-token decode it replaces
    (a decode step writes quantized codes first, then attends over the
    updated cache — its own token included)."""
    ctx = dataclasses.replace(ctx, step_parity=True, chunk_store=store)
    x, new_cache = _chunk_stack(ctx, params, tokens, cfg, cache,
                                (row, start, length))
    head = params.get("lm_head") or {"w": params["embed"]["w"].T}
    logits = linear(ctx, head, x)
    return logits, new_cache


def decode_step(ctx: Ctx, params: Dict, token: jax.Array, cache: Dict,
                cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    """One token for every sequence in the batch. token: (B, 1) int32."""
    x = embed(params["embed"], token, ctx.compute_dtype)
    x = _hint_act(ctx, x)
    period = len(cfg.block_pattern)

    new_prefix = []
    for i, blk in enumerate(params["prefix"]):
        x, _, c = apply_block(ctx, blk, x, cfg, _kind_at(cfg, i), "step",
                              cache=cache["prefix"][i])
        new_prefix.append(c)

    new_groups = None
    if params["groups"]:
        def body(xc, xs):
            gp, gc = xs
            new_gc = {}
            for pos in range(period):
                xc, _, c = apply_block(ctx, gp[f"p{pos}"], xc, cfg,
                                       cfg.block_pattern[pos], "step",
                                       cache=gc[f"p{pos}"])
                new_gc[f"p{pos}"] = c
            return xc, new_gc

        x, new_groups = jax.lax.scan(body, x, (params["groups"],
                                               cache["groups"]))

    new_suffix = []
    for i, blk in enumerate(params["suffix"]):
        x, _, c = apply_block(ctx, blk, x, cfg, cfg.block_pattern[i % period],
                              "step", cache=cache["suffix"][i])
        new_suffix.append(c)

    x = norm(params["final_norm"], x, cfg.norm)
    head = params.get("lm_head") or {"w": params["embed"]["w"].T}
    logits = linear(ctx, head, x)
    return logits, {"prefix": new_prefix, "groups": new_groups,
                    "suffix": new_suffix}
