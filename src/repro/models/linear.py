"""QuantizedLinear: every projection in the model zoo routes through here.

The *params dict* encodes the execution mode (so one apply function works
under jit for all modes, and PTQ is a pure pytree transformation):

  fp      : {"w": (m, n) [, "b": (n,)]}
  quant   : {"codes": int8 (m, n), "scale": (m/B, n),
             "l": (m, r), "r": (r, n) [, "b"]}          — Q + LR serving
  packed4 : {"packed": uint8 (m/2, n), "scale": (m/B, n), "l", "r" [, "b"]}
  qpeft   : quant/packed4 where (l, r) live in the *trainable* tree and the
            backbone stays in the frozen tree (split by repro.train).

Quantized projections execute through the **fused Q + LR matmul**
(``repro.kernels.ops.qlr_matmul``) controlled by ``ctx.fused``:
``"auto"`` (default) runs the Pallas kernel on TPU and the fused-XLA
lowering elsewhere; ``"on"`` forces the kernel (interpret mode off-TPU —
numerics validation); ``"off"`` keeps the legacy dequant-then-matmul
fallback. The dense dequantized weight never round-trips HBM on the
kernel path.

``calib`` taps are threaded through a tiny context object: when
``ctx.tap`` is set, the layer records streaming input moments (eager mode
only — calibration never runs under jit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.api import CalibStats
from repro.quant.mxint import unpack_codes_4bit


@dataclasses.dataclass
class Ctx:
    """Per-call model context (not a pytree — static under jit)."""

    compute_dtype: Any = jnp.float32
    tap: Optional[Dict[str, CalibStats]] = None   # calibration capture
    use_pallas: bool = False                      # TPU kernel path (serving)
    fused: str = "auto"                           # Q+LR matmul: auto|on|off
    draft: bool = False                           # Q-only (skip the LR sliver)
    step_parity: bool = False                     # chunk attn reads its own
    # K/V through the storage-dtype round trip, exactly as a per-token
    # decode would (speculative verify needs bit-identical numerics)
    chunk_store: bool = True                      # False = chunk attention
    # leaves KV storage untouched (speculative verify scores drafts
    # without overwriting the step-graph K/V the draft steps wrote)
    prefix: str = ""                              # per-layer tap namespace
    autocorr: bool = True                         # capture Σxxᵀ moments
    mesh: Optional[Any] = None                    # enables sharding hints
    attn_q_chunk: int = 512                       # blockwise attn tiling
    attn_kv_chunk: int = 1024

    def record(self, name: str, x: jax.Array, m: int) -> None:
        if self.tap is None:
            return
        name = self.prefix + name
        if name not in self.tap:
            self.tap[name] = CalibStats.init(m, need_autocorr=self.autocorr)
        self.tap[name] = self.tap[name].update(x)


def hint(ctx: Ctx, x: jax.Array, *axes) -> jax.Array:
    """Megatron-style activation sharding constraint (no-op without mesh).

    Without explicit constraints GSPMD happily *replicates* whole
    attention/MoE subgraphs across the model axis (observed: ~16× FLOP
    and collective inflation on the 16-way-TP dry-run). Each ``axes``
    entry names a mesh axis (or tuple of axes, or None) for that dim;
    entries whose mesh axes don't divide the dim are dropped so the same
    model code lowers on any mesh without padding.
    """
    mesh = ctx.mesh
    if mesh is None or x.ndim != len(axes):
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    clean = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            clean.append(None)
            continue
        group = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        ok = True
        for a in group:
            if a not in mesh.shape:
                ok = False
                break
            n *= mesh.shape[a]
        clean.append(ax if ok and n > 1 and dim % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*clean)))


def dp_axes_of(ctx: Ctx):
    """The data-parallel axes present on the ctx mesh ('pod','data')."""
    if ctx.mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in ctx.mesh.shape)
    return axes if axes else None


def weight_of(p: Dict[str, jax.Array], dtype) -> jax.Array:
    """Materialize W ≈ dequant(Q) + L·R from any linear-params schema
    (used where the algorithm needs the matrix itself, e.g. MLA's
    absorbed decode)."""
    if "w" in p:
        return p["w"].astype(dtype)
    w = dequant_weight(p, dtype)
    if p["l"].shape[-1] > 0:
        w = w + p["l"].astype(dtype) @ p["r"].astype(dtype)
    return w


def dequant_weight(p: Dict[str, jax.Array], dtype) -> jax.Array:
    """Materialize the quantized backbone (jnp fallback path; the Pallas
    kernel fuses this into the matmul on TPU).

    Dequantizes blockwise via reshape-multiply — ``jnp.repeat`` of the
    scale plane would materialize a second full (K, N) array before the
    product even forms.

    Codes may carry MXINT padding rows (input dims that aren't multiples
    of the block, e.g. xLSTM's 4/3·d FFN); the adapter ``l`` always has
    the true row count, so slice back to it."""
    from repro.kernels.ops import dequant_blockwise  # lazy: no import cycle
    if "packed" in p:
        codes = unpack_codes_4bit(p["packed"])
    else:
        codes = p["codes"]
    w = dequant_blockwise(codes, p["scale"], dtype)
    m = p["l"].shape[-2] if "l" in p else w.shape[-2]
    return w[..., :m, :]


def fused_mode(ctx: Ctx) -> str:
    """Resolve ``ctx.fused`` to the Q+LR execution path.

    Returns one of:
      "kernel" — the fused Pallas kernel (interpret mode off-TPU);
      "xla"    — the fused-XLA lowering (blockwise dequant + activation
                 sliver, no dense L·R materialization);
      "off"    — the legacy dequant-then-matmul fallback.

    ``fused="auto"`` picks the kernel on TPU (or under ``use_pallas``,
    the off-TPU kernel-validation switch) and the XLA form elsewhere, so
    the same model code serves fast on any backend.
    """
    if ctx.fused == "off":
        return "off"
    if ctx.fused == "on":
        return "kernel"
    if ctx.fused != "auto":
        raise ValueError(f"ctx.fused must be auto|on|off, got {ctx.fused!r}")
    if ctx.use_pallas or jax.default_backend() == "tpu":
        return "kernel"
    return "xla"


def _fused_qlr(params: Dict[str, jax.Array], x: jax.Array,
               mode: str) -> jax.Array:
    """Route one quantized projection through the fused Q+LR matmul.
    Handles the packed4 container and MXINT row padding (codes may carry
    padding rows when the input dim isn't a block multiple).

    On the kernel path the packed4 container is passed through *as
    packed uint8* — the Pallas kernel unpacks nibbles in VMEM, so the
    codes stream HBM at 0.5 byte/code. The XLA path pre-expands to int8
    (no sub-byte dot in XLA)."""
    from repro.kernels import ops as kops  # lazy: keeps import cycles out
    if "packed" in params:
        if mode == "kernel":
            codes = params["packed"]
            rows = codes.shape[-2] * 2
        else:
            codes = unpack_codes_4bit(params["packed"])
            rows = codes.shape[-2]
    else:
        codes = params["codes"]
        rows = codes.shape[-2]
    l = params["l"]
    pad = rows - x.shape[-1]
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        l = jnp.pad(l, [(0, pad), (0, 0)])
    return kops.qlr_matmul(x, codes, params["scale"], l, params["r"],
                           kernel=(mode == "kernel"))


def linear(ctx: Ctx, params: Dict[str, jax.Array], x: jax.Array,
           name: str = "") -> jax.Array:
    """y = x @ W (+ b), dispatching on the params-dict schema."""
    dt = ctx.compute_dtype
    if ctx.tap is not None and "w" in params:
        ctx.record(name, x, params["w"].shape[0])

    if "w" in params:
        y = x.astype(dt) @ params["w"].astype(dt)
    else:
        mode = fused_mode(ctx)
        if ctx.draft:
            # Q-only draft: slice the low-rank factors to rank 0. Every
            # downstream path (fused kernel, fused-XLA, off) already
            # no-ops a rank-0 sliver, so the draft rides the exact same
            # dequant code on the same resident weights — strictly less
            # work per token, zero extra HBM.
            params = dict(params, l=params["l"][:, :0], r=params["r"][:0])
        if mode != "off":
            y = _fused_qlr(params, x.astype(dt), mode)
        else:
            w = dequant_weight(params, dt)
            y = x.astype(dt) @ w
            if params["l"].shape[1] > 0:
                y = y + (x.astype(dt) @ params["l"].astype(dt)) @ params["r"].astype(dt)
    if "b" in params:
        y = y + params["b"].astype(dt)
    return y


def init_linear(key: jax.Array, m: int, n: int, *, bias: bool = False,
                scale: Optional[float] = None, dtype=jnp.float32) -> Dict[str, jax.Array]:
    std = scale if scale is not None else (1.0 / (m ** 0.5))
    p = {"w": (jax.random.normal(key, (m, n), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n,), dtype)
    return p


def is_linear_params(p: Any) -> bool:
    return isinstance(p, dict) and ("w" in p or "codes" in p or "packed" in p)
