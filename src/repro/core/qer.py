"""Baseline QER methods: W ≈ Q + LR with the full rank budget on the
residual (ZeroQuant-V2 / LQER / QERA-approx / QERA-exact — the baseline
family of the paper, §2).

All variants share the same construction (Eq. 1):

    Q  = 𝒬(W)
    LR = S⁻¹ · SVD_r( S (W − Q) )

and differ only in S (see :mod:`repro.core.scaling`).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.scaling import Scaling
from repro.core.svd import exact_svd, randomized_svd


class Decomposition(NamedTuple):
    """W ≈ q + l @ r with ``k`` leading adapter ranks marked "preserved".

    ``q`` is the *simulated* (fake-quantized) backbone in weight space;
    packing for deployment happens downstream (serve/kernels).
    """

    q: jax.Array   # (m, n)
    l: jax.Array   # (m, rank)  — l[:, :k] spans the preserved subspace
    r: jax.Array   # (rank, n)
    k: int         # preserved rank (0 for plain QER)

    @property
    def rank(self) -> int:
        return self.l.shape[1]

    def reconstruct(self) -> jax.Array:
        return self.q + self.l @ self.r


def scaled_error(w: jax.Array, dec: Decomposition, scaling: Scaling) -> jax.Array:
    """‖S(W − Q − LR)‖_F — the paper's reconstruction objective."""
    return jnp.linalg.norm(scaling.apply(w.astype(jnp.float32) - dec.reconstruct()))


def weight_error(w: jax.Array, dec: Decomposition) -> jax.Array:
    """‖W − Q − LR‖_F (Fig. 7 metric, S = I)."""
    return jnp.linalg.norm(w.astype(jnp.float32) - dec.reconstruct())


def _svd_factors(a: jax.Array, rank: int, key: Optional[jax.Array],
                 exact: bool) -> tuple[jax.Array, jax.Array]:
    """L = U_r, R = Σ_r V_rᵀ of a rank-``rank`` truncation of ``a``."""
    if rank <= 0:
        m, n = a.shape
        return (jnp.zeros((m, 0), jnp.float32), jnp.zeros((0, n), jnp.float32))
    if exact or key is None:
        dec = exact_svd(a, rank)
    else:
        dec = randomized_svd(a, rank, key)
    return dec.factors()


def qer_decompose(
    w: jax.Array,
    scaling: Scaling,
    quantizer,
    rank: int,
    key: Optional[jax.Array] = None,
    exact: bool = True,
) -> Decomposition:
    """Activation-aware QER (Eq. 1). k = 0 by construction."""
    w = w.astype(jnp.float32)
    q = quantizer.fake_quant(w)
    residual = scaling.apply(w - q)
    lu, rv = _svd_factors(residual, rank, key, exact)
    return Decomposition(q=q, l=scaling.apply_inv(lu), r=rv, k=0)


def w_only(w: jax.Array, quantizer, rank: int) -> Decomposition:
    """Quantization-only baseline: zero-width adapter."""
    w = w.astype(jnp.float32)
    m, n = w.shape
    return Decomposition(
        q=quantizer.fake_quant(w),
        l=jnp.zeros((m, rank), jnp.float32),
        r=jnp.zeros((rank, n), jnp.float32),
        k=0,
    )
