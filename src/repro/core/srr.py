"""Structured Residual Reconstruction — Algorithm 1 of the paper.

Preserve-then-quantize with an explicit rank split:

  1. k* ← argmin_k ρ_k(SW) ρ_{r−k}(SE)          (one-shot random probe)
  2. L⁽¹⁾R⁽¹⁾ ← S⁻¹ SVD_{k*}(SW)                 (preserve)
  3. Q ← 𝒬(W − L⁽¹⁾R⁽¹⁾)                         (quantize the residual)
  4. E ← W − L⁽¹⁾R⁽¹⁾ − Q                        (induced quantization error)
  5. L⁽²⁾R⁽²⁾ ← S⁻¹ SVD_{r−k*}(SE)               (reconstruct)
  6. L ← [L⁽¹⁾ L⁽²⁾],  R ← [R⁽¹⁾; R⁽²⁾]

``variant="joint"`` implements the paper's Eq. 6 alternative: after the
preserve-quantize step, a *single* rank-r SVD of S(W − Q) replaces steps
5–6 (optimal for fixed Q by Eckart–Young; the leading components recover
the preserved structure).

The split point k* requires a concrete Python int (it sets array shapes),
so decomposition is a host-driven offline routine — exactly how the paper
runs it (a calibration-time pipeline, not a training-step op).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.qer import Decomposition, _svd_factors
from repro.core.rank_alloc import RankSelection, select_rank
from repro.core.scaling import Scaling


class SRRResult(NamedTuple):
    decomposition: Decomposition
    selection: Optional[RankSelection]  # None when k was forced


def srr_decompose(
    w: jax.Array,
    scaling: Scaling,
    quantizer,
    rank: int,
    key: jax.Array,
    k: Optional[int] = None,
    exact: bool = True,
    variant: str = "split",
) -> SRRResult:
    """Full SRR pipeline for one weight matrix.

    Args:
      w: (m, n) weight, used as ``y = x @ w``.
      scaling: activation-aware S.
      quantizer: object with ``fake_quant``.
      rank: total budget r.
      key: PRNG key — drives the probe and randomized SVD sketches.
      k: force a split (benchmarks); None selects k* via Eq. 5.
      exact: exact SVDs (oracle) vs randomized (paper's production path).
      variant: "split" (Algorithm 1) or "joint" (Eq. 6).
    """
    if variant not in ("split", "joint"):
        raise ValueError(f"unknown SRR variant {variant!r}")
    w = w.astype(jnp.float32)
    k_sel, k_probe, k_svd1, k_svd2 = jax.random.split(key, 4)

    selection = None
    if k is None:
        selection = select_rank(w, scaling, rank, k_sel, exact=exact)
        k = int(selection.k_star)
    if not 0 <= k <= rank:
        raise ValueError(f"k={k} outside budget r={rank}")

    # --- preserve: top-k of SW, mapped back to weight space -------------
    sw = scaling.apply(w)
    l1s, r1 = _svd_factors(sw, k, k_svd1, exact)
    l1 = scaling.apply_inv(l1s)
    preserved = l1 @ r1 if k > 0 else jnp.zeros_like(w)

    # --- quantize the residual ------------------------------------------
    q = quantizer.fake_quant(w - preserved)
    e = w - preserved - q

    if variant == "split":
        # --- reconstruct the induced error with the remaining budget ----
        l2s, r2 = _svd_factors(scaling.apply(e), rank - k, k_svd2, exact)
        l2 = scaling.apply_inv(l2s)
        l = jnp.concatenate([l1, l2], axis=1)
        r = jnp.concatenate([r1, r2], axis=0)
    else:
        # Eq. 6: single rank-r reconstruction of W − Q (= preserved + E)
        ls, r = _svd_factors(scaling.apply(w - q), rank, k_svd2, exact)
        l = scaling.apply_inv(ls)

    return SRRResult(Decomposition(q=q, l=l, r=r, k=k), selection)


def preserved_singular_values(dec: Decomposition) -> jax.Array:
    """σ_i of the adapter rows (paper stores R = Σ Vᵀ, so row norms of R
    are the component singular values — used by SGP gradient scaling)."""
    return jnp.linalg.norm(dec.r, axis=1)
