"""Activation-aware scaling matrices S for QER/SRR.

Each QER variant is defined by its choice of S (§2 of the paper):

  * ``identity``    — ZeroQuant-V2:     S = I
  * ``lqer``        — LQER:             S = diag(mean |x_j|)        (heuristic)
  * ``qera-approx`` — QERA-approx:      S = diag(sqrt(E[x_j²]))     (heuristic)
  * ``qera-exact``  — QERA-exact:       S = (E[x xᵀ])^{1/2}         (exact)

The exact variant minimizes the true output-space error
``E‖x(W − Ŵ)‖²`` since ``E‖xΔ‖² = ‖S Δ‖_F²`` with S the symmetric square
root of the input autocorrelation.

A :class:`Scaling` object exposes cheap ``apply``/``apply_inv`` so diagonal
scalings never materialize an m×m matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

SCALING_KINDS = ("identity", "lqer", "qera-approx", "qera-exact")


@dataclasses.dataclass(frozen=True)
class Scaling:
    """S as either a diagonal vector or a dense symmetric matrix."""

    diag: Optional[jax.Array] = None       # (m,) — used when dense is None
    dense: Optional[jax.Array] = None      # (m, m)
    dense_inv: Optional[jax.Array] = None  # (m, m)

    @property
    def is_identity(self) -> bool:
        return self.diag is None and self.dense is None

    def apply(self, w: jax.Array) -> jax.Array:
        """S @ w."""
        if self.dense is not None:
            return self.dense @ w
        if self.diag is not None:
            return self.diag[:, None] * w
        return w

    def apply_inv(self, w: jax.Array) -> jax.Array:
        """S⁻¹ @ w."""
        if self.dense is not None:
            return self.dense_inv @ w
        if self.diag is not None:
            return w / self.diag[:, None]
        return w


def identity_scaling() -> Scaling:
    return Scaling()


def lqer_scaling(x: jax.Array, eps: float = 1e-6) -> Scaling:
    """diag of mean absolute activation per input channel. x: (N, m)."""
    d = jnp.mean(jnp.abs(x.astype(jnp.float32)), axis=0)
    return Scaling(diag=jnp.maximum(d, eps))


def qera_approx_scaling(x: jax.Array, eps: float = 1e-6) -> Scaling:
    """diag of root-mean-square activation per input channel."""
    d = jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), axis=0))
    return Scaling(diag=jnp.maximum(d, eps))


def qera_exact_scaling(x: jax.Array, eps: float = 1e-4) -> Scaling:
    """Symmetric square root of the input autocorrelation E[x xᵀ].

    Computed via eigendecomposition so S and S⁻¹ share one factorization;
    eigenvalues are floored at ``eps·λ_max`` to keep S invertible (the
    paper requires invertible S).
    """
    x = x.astype(jnp.float32)
    r = (x.T @ x) / x.shape[0]
    r = 0.5 * (r + r.T)
    evals, evecs = jnp.linalg.eigh(r)
    floor = eps * jnp.maximum(evals[-1], 1e-12)
    evals = jnp.maximum(evals, floor)
    half = jnp.sqrt(evals)
    s = (evecs * half) @ evecs.T
    s_inv = (evecs / half) @ evecs.T
    return Scaling(dense=s, dense_inv=s_inv)


def autocorr_scaling_from_moments(r: jax.Array, eps: float = 1e-4) -> Scaling:
    """qera-exact from a pre-accumulated autocorrelation matrix R = E[xxᵀ].

    This is the streaming-calibration entry point: the data pipeline
    accumulates ``Σ xxᵀ`` per layer across calibration batches (constant
    memory), then builds S once.
    """
    r = 0.5 * (r + r.T)
    evals, evecs = jnp.linalg.eigh(r.astype(jnp.float32))
    floor = eps * jnp.maximum(evals[-1], 1e-12)
    evals = jnp.maximum(evals, floor)
    half = jnp.sqrt(evals)
    s = (evecs * half) @ evecs.T
    s_inv = (evecs / half) @ evecs.T
    return Scaling(dense=s, dense_inv=s_inv)


def make_scaling(kind: str, x: Optional[jax.Array] = None) -> Scaling:
    """Factory. ``x`` is the (N, m) calibration activation sample."""
    if kind == "identity":
        return identity_scaling()
    if x is None:
        raise ValueError(f"scaling kind {kind!r} needs calibration activations")
    if kind == "lqer":
        return lqer_scaling(x)
    if kind == "qera-approx":
        return qera_approx_scaling(x)
    if kind == "qera-exact":
        return qera_exact_scaling(x)
    raise ValueError(f"unknown scaling kind {kind!r}; options: {SCALING_KINDS}")
