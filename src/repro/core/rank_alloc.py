"""Rank-allocation criterion (paper §4.2, Eq. 5).

``k* = argmin_{0≤k≤r}  ρ_k(SW) · ρ_{r−k}(SE)`` where

  ρ_p(A) = 1 − Σ_{j≤p} σ_j(A)² / ‖A‖_F²   (rank-p unrecoverable energy)

and E is a **one-shot** U[−1,1] random probe standing in for the
normalized quantization-error spectrum (Assumptions 4.1 + 4.2). Only the
top-r singular values of SW and SE are needed; ‖·‖_F² is computed exactly,
so ρ is exact even with a truncated spectrum.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.scaling import Scaling
from repro.core.svd import randomized_svd, singular_values


class RankSelection(NamedTuple):
    k_star: jax.Array        # scalar int32
    objective: jax.Array     # (r+1,) surrogate values over k
    rho_w: jax.Array         # (r+1,) ρ_k(SW), k = 0..r
    rho_e: jax.Array         # (r+1,) ρ_p(SE), p = 0..r


def rho_prefix(top_sv: jax.Array, frob_sq: jax.Array, r: int) -> jax.Array:
    """ρ_p for p = 0..r from the top-r singular values + exact ‖A‖_F².

    ρ_0 = 1; ρ_p = 1 − Σ_{j≤p} σ_j² / ‖A‖²_F. Clipped to [0, 1] against
    floating-point drift (randomized σ estimates can slightly overshoot).
    """
    sv = top_sv[:r]
    energy = jnp.concatenate([jnp.zeros((1,), top_sv.dtype), jnp.cumsum(sv**2)])
    return jnp.clip(1.0 - energy / jnp.maximum(frob_sq, 1e-30), 0.0, 1.0)


def sample_probe(key: jax.Array, shape: tuple[int, int]) -> jax.Array:
    """E_ij ~ U[-1, 1] — Algorithm 1 line 1."""
    return jax.random.uniform(key, shape, minval=-1.0, maxval=1.0,
                              dtype=jnp.float32)


def select_rank(
    w: jax.Array,
    scaling: Scaling,
    r: int,
    key: jax.Array,
    exact: bool = False,
    n_iter: int = 4,
) -> RankSelection:
    """Layer-wise k* selection (Algorithm 1 lines 1–2).

    ``exact=True`` uses full SVDs (oracle / small benchmark matrices);
    otherwise randomized top-r sketches per App. A.4.
    """
    kp, ks = jax.random.split(key)
    sw = scaling.apply(w.astype(jnp.float32))
    probe = sample_probe(kp, w.shape)
    se = scaling.apply(probe)

    if exact:
        sv_w = singular_values(sw)
        sv_e = singular_values(se)
    else:
        k1, k2 = jax.random.split(ks)
        sv_w = randomized_svd(sw, r, k1, n_iter=n_iter).s
        sv_e = randomized_svd(se, r, k2, n_iter=n_iter).s

    rho_w = rho_prefix(sv_w, jnp.sum(sw**2), r)
    rho_e = rho_prefix(sv_e, jnp.sum(se**2), r)
    # objective over k: ρ_k(SW) · ρ_{r−k}(SE)
    objective = rho_w * rho_e[::-1]
    k_star = jnp.argmin(objective).astype(jnp.int32)
    return RankSelection(k_star, objective, rho_w, rho_e)


def true_reconstruction_error(
    w: jax.Array,
    scaling: Scaling,
    quantizer,
    r: int,
    k: int,
) -> jax.Array:
    """Brute-force L(k) = ‖SE_k − SVD_{r−k}(SE_k)‖_F (Eq. 3, oracle).

    Used by benchmarks (Fig. 2) to validate the surrogate; O(full SVD + one
    quantization) per k, exactly the cost the surrogate avoids.
    """
    w = w.astype(jnp.float32)
    sw = scaling.apply(w)
    if k > 0:
        u, s, vt = jnp.linalg.svd(sw, full_matrices=False)
        preserved = scaling.apply_inv((u[:, :k] * s[:k]) @ vt[:k])
    else:
        preserved = jnp.zeros_like(w)
    q = quantizer.fake_quant(w - preserved)
    e_k = w - preserved - q
    se_k = scaling.apply(e_k)
    sv = jnp.linalg.svd(se_k, compute_uv=False)
    tail = jnp.sum(sv[r - k:] ** 2)
    return jnp.sqrt(tail)
