"""QPEFT: SRR-initialized adapters + decoupled gradient scaling (§4.4).

The quantized backbone Q is frozen; the adapter (L, R) is trainable and
initialized from the SRR decomposition. The two component groups get
different treatment during fine-tuning:

  * preserved directions (columns L[:, :k], rows R[:k, :]) — gradients
    attenuated by γ ∈ (0, 1)                       (Eq. 7), or rank-wise
    by SGP's (1 − λ_i), λ_i = (α+1)σ_i / (ασ_i + σ_1)   (Eq. 8–9);
  * residual-reconstruction directions — unscaled.

Implemented as a *gradient transform* so it composes with any optimizer
(`repro.optim` applies it before the Adam update). All ops are jittable:
``k`` is static per layer (baked at init), masks are precomputed.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.qer import Decomposition
from repro.core.srr import preserved_singular_values


class AdapterParams(NamedTuple):
    """Trainable adapter factors."""

    l: jax.Array  # (m, rank)
    r: jax.Array  # (rank, n)


class AdapterStatic(NamedTuple):
    """Frozen per-layer state: backbone + scaling coefficients.

    ``grad_scale`` is a per-rank vector g ∈ (0,1]^rank applied to the
    gradient columns/rows; built once at init for either fixed-γ or SGP.
    """

    q: jax.Array           # (m, n) frozen fake-quantized backbone
    grad_scale: jax.Array  # (rank,)
    k: int


def fixed_gamma_scale(rank: int, k: int, gamma: float) -> jax.Array:
    """g_i = γ for i < k (preserved), 1 otherwise (Eq. 7)."""
    idx = jnp.arange(rank)
    return jnp.where(idx < k, gamma, 1.0).astype(jnp.float32)


def sgp_scale(dec: Decomposition, alpha: float = 5.0) -> jax.Array:
    """Rank-wise SGP scaling on the preserved block (Eq. 8–9).

    λ_i = (α+1)σ_i / (ασ_i + σ_1) over the *preserved* singular values;
    g_i = 1 − λ_i for i < k, 1 for the residual block.
    """
    rank, k = dec.rank, dec.k
    if k == 0:
        return jnp.ones((rank,), jnp.float32)
    sigma = preserved_singular_values(dec)[:k]
    sigma1 = jnp.maximum(sigma[0], 1e-12)
    lam = (alpha + 1.0) * sigma / (alpha * sigma + sigma1)
    lam = jnp.clip(lam, 0.0, 1.0)
    g = jnp.ones((rank,), jnp.float32)
    return g.at[:k].set(1.0 - lam)


def init_adapter(
    dec: Decomposition,
    mode: str = "gamma",
    gamma: float = 0.1,
    alpha: float = 5.0,
) -> tuple[AdapterParams, AdapterStatic]:
    """Build the trainable/frozen split from an SRR (or QER) decomposition."""
    if mode == "gamma":
        g = fixed_gamma_scale(dec.rank, dec.k, gamma)
    elif mode == "sgp":
        g = sgp_scale(dec, alpha)
    elif mode == "none":
        g = jnp.ones((dec.rank,), jnp.float32)
    else:
        raise ValueError(f"unknown grad-scaling mode {mode!r}")
    return (
        AdapterParams(l=dec.l, r=dec.r),
        AdapterStatic(q=dec.q, grad_scale=g, k=dec.k),
    )


def scale_adapter_grads(
    grads: AdapterParams, static: AdapterStatic
) -> AdapterParams:
    """Apply the per-rank gradient scaling (jittable, no data-dependent
    shapes)."""
    g = static.grad_scale
    return AdapterParams(l=grads.l * g[None, :], r=grads.r * g[:, None])


def adapter_matmul(
    x: jax.Array, params: AdapterParams, static: AdapterStatic
) -> jax.Array:
    """y = x Q + (x L) R — the QPEFT forward. Backbone receives no grads
    because ``static.q`` is held outside the differentiated pytree."""
    y = x @ jax.lax.stop_gradient(static.q)
    return y + (x @ params.l) @ params.r


def tree_scale_grads(grads, statics):
    """Map :func:`scale_adapter_grads` over matching pytrees of adapters."""
    return jax.tree_util.tree_map(
        scale_adapter_grads,
        grads,
        statics,
        is_leaf=lambda x: isinstance(x, AdapterParams),
    )
