"""Core: the paper's contribution (SRR) + QER baselines + QPEFT."""
from repro.core.api import (
    CalibStats,
    LayerReport,
    PTQConfig,
    quantize_layer,
    quantize_tree,
    report_summary,
)
from repro.core.qer import (
    Decomposition,
    qer_decompose,
    scaled_error,
    w_only,
    weight_error,
)
from repro.core.qpeft import (
    AdapterParams,
    AdapterStatic,
    adapter_matmul,
    fixed_gamma_scale,
    init_adapter,
    scale_adapter_grads,
    sgp_scale,
    tree_scale_grads,
)
from repro.core.rank_alloc import (
    RankSelection,
    rho_prefix,
    sample_probe,
    select_rank,
    true_reconstruction_error,
)
from repro.core.scaling import (
    SCALING_KINDS,
    Scaling,
    identity_scaling,
    lqer_scaling,
    make_scaling,
    qera_approx_scaling,
    qera_exact_scaling,
)
from repro.core.srr import SRRResult, preserved_singular_values, srr_decompose
from repro.core.svd import (
    TruncatedSVD,
    exact_svd,
    randomized_svd,
    singular_values,
    topk_singular_values,
)
