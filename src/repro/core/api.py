"""Model-level PTQ/QPEFT pipeline: walk named weights, apply SRR/QER.

This is the integration surface between the paper's per-matrix algorithm
and the framework: the trainer/server hand in a flat dict of named 2-D
weights plus per-layer calibration statistics; this module returns
decompositions + a report (k* per layer, errors, timings).

Calibration statistics are *streaming moments* (constant memory per layer):
count, Σ|x|, Σx², and optionally Σxxᵀ — enough to build every scaling kind
without retaining activations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.qer import Decomposition, qer_decompose, scaled_error, w_only
from repro.core.scaling import (
    Scaling,
    autocorr_scaling_from_moments,
    identity_scaling,
)
from repro.core.srr import srr_decompose
from repro.quant import QuantizerConfig, make_quantizer


class CalibStats(NamedTuple):
    """Streaming per-layer input statistics. All in float32."""

    count: jax.Array    # scalar
    sum_abs: jax.Array  # (m,)
    sum_sq: jax.Array   # (m,)
    autocorr: Optional[jax.Array] = None  # (m, m) Σ xxᵀ

    @staticmethod
    def init(m: int, need_autocorr: bool = True) -> "CalibStats":
        return CalibStats(
            count=jnp.zeros((), jnp.float32),
            sum_abs=jnp.zeros((m,), jnp.float32),
            sum_sq=jnp.zeros((m,), jnp.float32),
            autocorr=jnp.zeros((m, m), jnp.float32) if need_autocorr else None,
        )

    def update(self, x: jax.Array) -> "CalibStats":
        """Accumulate a batch of activations x (..., m)."""
        x = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        ac = self.autocorr
        if ac is not None:
            ac = ac + x.T @ x
        return CalibStats(
            count=self.count + x.shape[0],
            sum_abs=self.sum_abs + jnp.sum(jnp.abs(x), axis=0),
            sum_sq=self.sum_sq + jnp.sum(x * x, axis=0),
            autocorr=ac,
        )

    def scaling(self, kind: str) -> Scaling:
        n = jnp.maximum(self.count, 1.0)
        if kind == "identity":
            return identity_scaling()
        if kind == "lqer":
            return Scaling(diag=jnp.maximum(self.sum_abs / n, 1e-6))
        if kind == "qera-approx":
            return Scaling(diag=jnp.maximum(jnp.sqrt(self.sum_sq / n), 1e-6))
        if kind == "qera-exact":
            if self.autocorr is None:
                raise ValueError("qera-exact needs autocorrelation moments")
            return autocorr_scaling_from_moments(self.autocorr / n)
        raise ValueError(f"unknown scaling kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    """One knob object for the whole offline pipeline."""

    method: str = "srr"             # srr | srr-joint | qer | w-only | none
    scaling: str = "qera-exact"     # see repro.core.scaling
    quantizer: QuantizerConfig = QuantizerConfig(kind="mxint", bits=3, block_size=32)
    rank: int = 64
    exact_svd: bool = False         # randomized SVD by default (paper A.4)
    seed: int = 0
    forced_k: Optional[int] = None  # override k* (ablations)

    def rank_for(self, shape: tuple[int, int]) -> int:
        """Effective budget for narrow matrices (e.g. MoE experts)."""
        return max(1, min(self.rank, min(shape) // 2))


class LayerReport(NamedTuple):
    name: str
    shape: tuple[int, int]
    rank: int
    k_star: int
    scaled_err: float
    weight_err: float
    seconds: float


def quantize_layer(
    name: str,
    w: jax.Array,
    stats: Optional[CalibStats],
    cfg: PTQConfig,
    key: jax.Array,
    quantizer=None,
    recorder=None,
) -> tuple[Decomposition, LayerReport]:
    """Apply the configured method to one weight matrix.

    ``recorder`` is an optional duck-typed observer (see
    :mod:`repro.obs.quant`) whose ``record_layer`` hook receives the
    inputs and results of every pass; this module never imports the
    observability package.
    """
    t0 = time.perf_counter()
    scaling = (stats.scaling(cfg.scaling) if stats is not None
               else identity_scaling())
    if quantizer is None:
        quantizer = make_quantizer(cfg.quantizer)
    rank = cfg.rank_for(w.shape)

    if cfg.method == "w-only":
        dec = w_only(w, quantizer, rank)
    elif cfg.method == "qer":
        dec = qer_decompose(w, scaling, quantizer, rank, key=key,
                            exact=cfg.exact_svd)
    elif cfg.method in ("srr", "srr-joint"):
        variant = "joint" if cfg.method == "srr-joint" else "split"
        res = srr_decompose(w, scaling, quantizer, rank, key,
                            k=cfg.forced_k, exact=cfg.exact_svd,
                            variant=variant)
        dec = res.decomposition
    elif cfg.method == "none":
        dec = Decomposition(q=w.astype(jnp.float32),
                            l=jnp.zeros((w.shape[0], rank), jnp.float32),
                            r=jnp.zeros((rank, w.shape[1]), jnp.float32), k=0)
    else:
        raise ValueError(f"unknown PTQ method {cfg.method!r}")

    serr = float(scaled_error(w, dec, scaling))
    werr = float(jnp.linalg.norm(w.astype(jnp.float32) - dec.reconstruct()))
    report = LayerReport(
        name=name, shape=tuple(w.shape), rank=rank, k_star=dec.k,
        scaled_err=serr, weight_err=werr,
        seconds=time.perf_counter() - t0,
    )
    if recorder is not None:
        recorder.record_layer(name, w, dec, scaling, cfg, quantizer, report)
    return dec, report


def quantize_tree(
    weights: Dict[str, jax.Array],
    stats: Dict[str, CalibStats],
    cfg: PTQConfig,
    progress: Optional[Callable[[LayerReport], None]] = None,
    recorder=None,
) -> tuple[Dict[str, Decomposition], list[LayerReport]]:
    """Quantize every named weight; deterministic per-layer PRNG streams."""
    root = jax.random.PRNGKey(cfg.seed)
    decs: Dict[str, Decomposition] = {}
    reports: list[LayerReport] = []
    for i, name in enumerate(sorted(weights)):
        key = jax.random.fold_in(root, i)
        dec, rep = quantize_layer(name, weights[name], stats.get(name), cfg, key,
                                  recorder=recorder)
        decs[name] = dec
        reports.append(rep)
        if progress is not None:
            progress(rep)
    return decs, reports


def report_summary(reports: list[LayerReport]) -> Dict[str, Any]:
    if not reports:
        return {}
    return {
        "layers": len(reports),
        "mean_scaled_err": float(jnp.mean(jnp.array([r.scaled_err for r in reports]))),
        "mean_weight_err": float(jnp.mean(jnp.array([r.weight_err for r in reports]))),
        "mean_k_star": float(jnp.mean(jnp.array([float(r.k_star) for r in reports]))),
        "total_seconds": sum(r.seconds for r in reports),
    }
