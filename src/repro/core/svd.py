"""Truncated + randomized SVD (Halko et al., 2011).

The paper computes only the top-r singular components of SW and SE, using
randomized SVD with ``n_iter = 4`` power iterations and oversampling of
twice the target rank (App. A.4). We implement exactly that, with QR
re-orthonormalization between power iterations for numerical stability,
plus an exact ``lax.linalg.svd`` fallback used by the oracle paths.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class TruncatedSVD(NamedTuple):
    u: jax.Array   # (m, r)
    s: jax.Array   # (r,)
    vt: jax.Array  # (r, n)

    def lowrank(self) -> jax.Array:
        return (self.u * self.s) @ self.vt

    def factors(self) -> tuple[jax.Array, jax.Array]:
        """Paper's factorization: L = U_r (orthonormal), R = Σ_r V_rᵀ."""
        return self.u, self.s[:, None] * self.vt


def exact_svd(a: jax.Array, rank: int) -> TruncatedSVD:
    """Exact truncated SVD via full decomposition (oracle path)."""
    u, s, vt = jnp.linalg.svd(a.astype(jnp.float32), full_matrices=False)
    return TruncatedSVD(u[:, :rank], s[:rank], vt[:rank])


def singular_values(a: jax.Array) -> jax.Array:
    """All singular values (for ρ-curves at benchmark scale)."""
    return jnp.linalg.svd(a.astype(jnp.float32), compute_uv=False)


def randomized_svd(
    a: jax.Array,
    rank: int,
    key: jax.Array,
    n_iter: int = 4,
    oversample: Optional[int] = None,
) -> TruncatedSVD:
    """Randomized range-finder SVD; sketch width = rank + oversample.

    Defaults follow the paper: n_iter=4, oversample=2·rank (App A.4).
    """
    m, n = a.shape
    a = a.astype(jnp.float32)
    if oversample is None:
        oversample = 2 * rank
    width = min(rank + oversample, min(m, n))
    omega = jax.random.normal(key, (n, width), dtype=jnp.float32)
    y = a @ omega  # (m, width)
    # subspace (power) iterations with QR stabilization
    for _ in range(n_iter):
        q, _ = jnp.linalg.qr(y)
        z, _ = jnp.linalg.qr(a.T @ q)
        y = a @ z
    q, _ = jnp.linalg.qr(y)  # (m, width)
    b = q.T @ a  # (width, n)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return TruncatedSVD(u[:, :rank], s[:rank], vt[:rank])


def topk_singular_values(
    a: jax.Array, k: int, key: jax.Array, n_iter: int = 4
) -> jax.Array:
    """Top-k singular values via the randomized sketch (no U/V needed)."""
    return randomized_svd(a, k, key, n_iter=n_iter).s
