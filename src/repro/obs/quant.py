"""Quantize-time introspection: per-layer SRR quality records.

The paper's k-selection criterion balances *preserved* subspace energy
against *quantization-exposed* energy of the activation-scaled weight
``SW``; this module records exactly that balance layer-by-layer while a
quantizer pass runs, so report readers (and the ROADMAP's future auto
rank/bit allocation search) can see where error reconstruction pays off.

A :class:`QuantRecorder` is threaded — duck-typed, optional — through
:func:`repro.core.api.quantize_layer` and
:func:`repro.models.quantize.quantize_model_params`. For each layer it
captures a :class:`LayerQuantRecord`:

* singular-spectrum head of ``SW`` plus preserved rank ``k`` and the
  captured energy fraction ``Σσ²[:k] / Σσ²`` (its complement is the
  quantization-exposed energy the paper's criterion trades against);
* raw and activation-scaled residual norms ``‖W − Q − LR‖_F`` and
  ``‖S(W − Q − LR)‖_F``, absolute and relative;
* bits/rank budgets and — once the serving containers are packed —
  actual container bytes split into quantized vs low-rank storage.

``rec.build_report()`` returns a JSON-serializable dict pinned by
``tools/quant_report_schema.json`` (validated with the existing
``tools/validate_metrics.py`` engine); ``rec.write(path)`` also drops a
sibling ``*.trace.json`` Chrome trace with one span per layer pass via
the serving :class:`~repro.serve.telemetry.Tracer`.

Everything is a null object when recording is off:
:data:`NULL_QUANT_RECORDER` swallows every call so the quantizer hot
path never branches on configuration.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List

import jax.numpy as jnp
import numpy as np

from repro.serve.telemetry import Tracer

# Chrome-trace process lane for quantizer passes (the serving Tracer
# reserves 1 for request lanes and 2 for the engine timeline).
PID_QUANT = 3

# how many leading singular values of SW each record keeps
SPECTRUM_HEAD = 8

REPORT_VERSION = 1


@dataclasses.dataclass
class LayerQuantRecord:
    """Everything the report knows about one quantized matrix."""

    name: str
    shape: List[int]                  # [out_features, in_features] as stored
    method: str                       # srr | srr-joint | qer | w-only | none
    scaling: str                      # identity | lqer | qera-approx | ...
    rank: int                         # low-rank budget r
    k: int                            # preserved rank k* (<= rank)
    bits: float                       # effective bits/weight incl. side info
    singular_head: List[float]        # leading sigma_i of SW, descending
    preserved_energy_fraction: float  # sum sigma^2[:k] / sum sigma^2
    quant_exposed_energy_fraction: float  # 1 - preserved fraction
    scaled_err: float                 # ||S(W - Q - LR)||_F
    scaled_rel_err: float             # scaled_err / ||SW||_F
    weight_err: float                 # ||W - Q - LR||_F
    weight_rel_err: float             # weight_err / ||W||_F
    seconds: float                    # wall time of the quantizer pass
    quant_bytes: int = 0              # packed Q container (codes + scales)
    lowrank_bytes: int = 0            # L, R (+ gscale) container
    total_bytes: int = 0
    container: str = ""               # serving container kind, if packed


def _nbytes(x: Any) -> int:
    return int(getattr(x, "nbytes", 0))


class QuantRecorder:
    """Accumulates :class:`LayerQuantRecord` objects during a pass.

    The recorder is handed to the pipeline as an opaque object (core
    modules never import this package); it derives every spectral
    quantity itself from ``(w, dec, scaling)`` so quantizer internals
    stay untouched.
    """

    def __init__(self, spectrum_head: int = SPECTRUM_HEAD):
        self.spectrum_head = spectrum_head
        self.records: Dict[str, LayerQuantRecord] = {}
        self._config: Dict[str, Any] = {}
        self.tracer = Tracer()
        self.tracer.events.append({
            "ph": "M", "pid": PID_QUANT, "tid": 0, "ts": 0,
            "name": "process_name", "args": {"name": "quantize"}})

    # ------------------------------------------------------------------
    def record_layer(self, name: str, w, dec, scaling, cfg, quantizer,
                     layer_report) -> None:
        """Capture one quantized matrix (called by ``quantize_layer``)."""
        if not self._config:
            self._config = {
                "method": cfg.method,
                "scaling": cfg.scaling,
                "quantizer": cfg.quantizer.kind,
                "bits": int(cfg.quantizer.bits),
                "block_size": int(cfg.quantizer.block_size),
                "rank": int(cfg.rank),
                "exact_svd": bool(cfg.exact_svd),
            }
        sw = np.asarray(scaling.apply(w.astype(jnp.float32)))
        sigma = np.linalg.svd(sw, compute_uv=False)
        energy = sigma.astype(np.float64) ** 2
        total = float(energy.sum()) or 1.0
        k = int(dec.k)
        preserved = float(energy[:k].sum() / total)
        sw_norm = float(np.sqrt(total))
        w_norm = float(np.linalg.norm(np.asarray(w, dtype=np.float32))) or 1.0
        bits = float(getattr(quantizer, "effective_bits",
                             cfg.quantizer.bits))
        self.records[name] = LayerQuantRecord(
            name=name,
            shape=[int(s) for s in w.shape],
            method=cfg.method,
            scaling=cfg.scaling,
            rank=int(layer_report.rank),
            k=k,
            bits=bits,
            singular_head=[float(s) for s in
                           sigma[:self.spectrum_head]],
            preserved_energy_fraction=preserved,
            quant_exposed_energy_fraction=1.0 - preserved,
            scaled_err=float(layer_report.scaled_err),
            scaled_rel_err=float(layer_report.scaled_err) / (sw_norm or 1.0),
            weight_err=float(layer_report.weight_err),
            weight_rel_err=float(layer_report.weight_err) / w_norm,
            seconds=float(layer_report.seconds),
        )
        dur = float(layer_report.seconds) * 1e6
        self.tracer.complete(
            name, self.tracer.now_us() - dur, dur, PID_QUANT, 0,
            args={"k": k, "rank": int(layer_report.rank),
                  "scaled_err": float(layer_report.scaled_err)})

    def attach_container(self, name: str, packed: Dict[str, Any],
                         container: str) -> None:
        """Add serving-container byte accounting to an existing record.

        ``packed`` is the per-matrix dict built by
        ``models/quantize._quantize_matrix``: the quantized body lives in
        ``codes``/``packed`` + ``scale``, the reconstruction in ``l``,
        ``r`` (+ ``gscale``).
        """
        rec = self.records.get(name)
        if rec is None:
            return
        rec.quant_bytes = sum(_nbytes(packed.get(key))
                              for key in ("codes", "packed", "scale"))
        rec.lowrank_bytes = sum(_nbytes(packed.get(key))
                                for key in ("l", "r", "gscale"))
        rec.total_bytes = rec.quant_bytes + rec.lowrank_bytes
        rec.container = container

    # ------------------------------------------------------------------
    def build_report(self) -> Dict[str, Any]:
        recs = list(self.records.values())
        summary: Dict[str, Any] = {
            "layers": len(recs),
            "total_bytes": sum(r.total_bytes for r in recs),
            "quant_bytes": sum(r.quant_bytes for r in recs),
            "lowrank_bytes": sum(r.lowrank_bytes for r in recs),
            "total_seconds": sum(r.seconds for r in recs),
        }
        if recs:
            summary.update(
                mean_scaled_rel_err=float(np.mean(
                    [r.scaled_rel_err for r in recs])),
                max_scaled_rel_err=float(np.max(
                    [r.scaled_rel_err for r in recs])),
                mean_preserved_energy_fraction=float(np.mean(
                    [r.preserved_energy_fraction for r in recs])),
                mean_k=float(np.mean([r.k for r in recs])),
                mean_bits=float(np.mean([r.bits for r in recs])),
            )
        return {
            "version": REPORT_VERSION,
            "config": dict(self._config),
            "summary": summary,
            "layers": {r.name: dataclasses.asdict(r) for r in recs},
        }

    def write(self, path: str) -> str:
        """Write the JSON report; drop a sibling ``*.trace.json``."""
        with open(path, "w") as f:
            json.dump(self.build_report(), f, indent=1, sort_keys=True)
            f.write("\n")
        trace = (path[:-len(".json")] if path.endswith(".json")
                 else path) + ".trace.json"
        self.tracer.write_chrome(trace)
        return path


class NullQuantRecorder:
    """No-op stand-in so call sites never branch on configuration."""

    def record_layer(self, *a, **k) -> None:
        pass

    def attach_container(self, *a, **k) -> None:
        pass

    def build_report(self) -> Dict[str, Any]:
        return {"version": REPORT_VERSION, "config": {}, "summary":
                {"layers": 0, "total_bytes": 0, "quant_bytes": 0,
                 "lowrank_bytes": 0, "total_seconds": 0.0}, "layers": {}}


NULL_QUANT_RECORDER = NullQuantRecorder()
