"""``repro.obs`` — the thin observability export surface.

Serving-side primitives live in :mod:`repro.serve.telemetry`;
quantize-time introspection lives in :mod:`repro.obs.quant`. This
package is the stable import point for consumers outside the serving
stack (benchmarks, launch drivers, notebooks)::

    from repro import obs
    p95 = obs.percentile(latencies, 0.95)
    reg = obs.MetricsRegistry()
    rec = obs.QuantRecorder()
"""
from repro.obs.quant import (
    NULL_QUANT_RECORDER,
    LayerQuantRecord,
    NullQuantRecorder,
    QuantRecorder,
)
from repro.serve.telemetry import (
    NULL_TELEMETRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullTelemetry,
    Telemetry,
    Tracer,
    latency_summary,
    log_buckets,
    percentile,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "LayerQuantRecord", "MetricsRegistry",
    "NULL_QUANT_RECORDER", "NULL_TELEMETRY", "NullQuantRecorder",
    "NullTelemetry", "QuantRecorder", "Telemetry", "Tracer",
    "latency_summary", "log_buckets", "percentile",
]
