"""DeepSeek-V2-Lite-16B [moe]. 27L d_model=2048 16H vocab=102400 — MLA
with kv_lora_rank=512, MoE 2 shared + 64 routed top-6, expert d_ff=1408,
first layer dense. [arXiv:2405.04434; hf].

The assignment header says "64e top-6" (matching the released model);
its trailing comment's "160 routed" does not match the HF config and is
ignored. V2-Lite has no q-LoRA (q_lora_rank null) — queries project
directly. qk_nope_head_dim=128, rope head dim 64, v_head_dim=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,           # MLA: logical heads (cache is latent, shared)
    head_dim=128,            # qk_nope / v head dim
    d_ff=10944,              # dense prefix layer (hf intermediate_size)
    vocab=102_400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=0,
    rope_head_dim=64,
    moe=True,
    n_routed=64,
    n_shared=2,
    top_k=6,
    d_expert=1408,
    first_dense=1,
    rope_kind="full",
    act="swiglu",
    norm="rmsnorm",
)
