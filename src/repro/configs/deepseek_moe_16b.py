"""DeepSeek-MoE-16B [moe]. 28L d_model=2048 16H (MHA kv=16) vocab=102400,
fine-grained MoE: 2 shared + 64 routed experts, top-6, expert d_ff=1408
(the assignment's d_ff), first layer dense. [arXiv:2401.06066; hf].

The dense lead-in layer uses the HF config's intermediate_size (10944);
the assignment's d_ff=1408 is the *expert* width (moe_intermediate_size).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,              # dense prefix layer MLP (hf intermediate_size)
    vocab=102_400,
    moe=True,
    n_routed=64,
    n_shared=2,
    top_k=6,
    d_expert=1408,           # assignment d_ff (moe_intermediate_size)
    first_dense=1,
    rope_kind="full",
    act="swiglu",
    norm="rmsnorm",
)
