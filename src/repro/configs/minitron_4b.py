"""Minitron-4B [dense]. 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned Nemotron. [arXiv:2407.14679; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256_000,
    rope_kind="full",
    act="swiglu",            # nemotron uses squared-relu; swiglu stand-in
    norm="rmsnorm",
)
