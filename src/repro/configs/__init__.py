"""Architecture registry: ``--arch <id>`` resolution."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.chatglm3_6b import CONFIG as _chatglm3
from repro.configs.deepseek_moe_16b import CONFIG as _dsmoe
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2
from repro.configs.internvl2_2b import CONFIG as _internvl
from repro.configs.minitron_4b import CONFIG as _minitron
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.qwen1_5_32b import CONFIG as _qwen
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.xlstm_125m import CONFIG as _xlstm

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (_qwen, _phi3, _chatglm3, _minitron, _whisper, _rgemma,
              _dsmoe, _dsv2, _internvl, _xlstm)
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)


__all__ = ["ARCHS", "get_config", "list_archs", "ModelConfig", "ShapeConfig",
           "SHAPES", "shape_applicable"]
