"""xLSTM-125M [ssm]. 12L d_model=768 4H vocab=50304 d_ff=0 — alternating
mLSTM (parallel, matrix memory) and sLSTM (sequential, scalar memory)
blocks; each block carries its own internal projections (mLSTM: 2× up /
gated down; sLSTM: post-FFN 4/3), hence d_ff=0. [arXiv:2405.04517;
unverified].

Fully recurrent ⇒ runs the ``long_500k`` shape with O(1)-in-S state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    rope_kind="none",
    act="gelu",
    norm="layernorm",
    slstm_proj_factor=4.0 / 3.0,
    mlstm_proj_factor=2.0,
)
