"""Architecture + shape configuration.

One :class:`ModelConfig` describes every supported family (dense GQA /
MLA / MoE / RG-LRU hybrid / xLSTM / enc-dec / VLM). A per-layer *block
pattern* cycles through the depth (e.g. recurrentgemma's
``(rglru, rglru, local)``), so heterogeneous stacks scan efficiently.

``reduced()`` shrinks any config to smoke-test size while preserving the
family structure (pattern, GQA ratio, MoE top-k, MLA ranks, ...).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | audio | hybrid | moe | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0               # 0 → d_model // n_heads
    block_pattern: Tuple[str, ...] = ("attn",)  # attn|local|rglru|slstm|mlstm
    attn_kind: str = "gqa"          # gqa | mla
    qkv_bias: bool = False
    rope_kind: str = "full"         # full | half | none
    rope_theta: float = 10_000.0
    act: str = "swiglu"             # swiglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm

    # --- MoE ---
    moe: bool = False
    n_routed: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_dense: int = 0            # leading dense-MLP layers (deepseek style)
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64

    # --- local attention / RG-LRU (recurrentgemma) ---
    window: int = 2048
    d_rnn: int = 0                  # 0 → d_model
    conv_width: int = 4

    # --- xLSTM ---
    slstm_proj_factor: float = 4.0 / 3.0
    mlstm_proj_factor: float = 2.0

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0                # fixed encoder length (whisper: 1500)
    cross_attn: bool = False
    d_frontend: int = 0             # frontend embedding dim (stub input)

    # --- VLM ---
    n_vision_tokens: int = 0        # prepended stub patch embeddings

    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_rnn_(self) -> int:
        return self.d_rnn or self.d_model

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.n_layers))

    def uses_moe_at(self, i: int) -> bool:
        return self.moe and i >= self.first_dense

    @property
    def is_encoder_decoder(self) -> bool:
        return self.enc_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True iff every sequence mixer is sub-quadratic (no global attn)."""
        return all(k != "attn" for k in set(self.block_pattern))

    @property
    def has_decode(self) -> bool:
        return True  # every assigned arch has a decoder (whisper: its decoder)

    # ------------------------------------------------------------------
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), used for
        MODEL_FLOPS accounting in the roofline."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab * d  # embed
        if not self.tie_embeddings:
            total += self.vocab * d
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind in ("attn", "local"):
                if self.attn_kind == "mla" and kind == "attn":
                    r, pe = self.kv_lora_rank, self.rope_head_dim
                    qdim = self.n_heads * (hd + pe)
                    total += d * (r + pe)                 # kv down + k_pe
                    total += r * self.n_heads * (hd + hd)  # k_up, v_up
                    total += (d * self.q_lora_rank + self.q_lora_rank * qdim
                              if self.q_lora_rank else d * qdim)
                    total += self.n_heads * hd * d         # o proj
                else:
                    total += d * self.n_heads * hd
                    total += 2 * d * self.n_kv_heads * hd
                    total += self.n_heads * hd * d
            elif kind == "rglru":
                dr = self.d_rnn_
                total += 2 * d * dr + dr * d  # branch, gate, out
                total += dr * self.conv_width + 3 * dr  # conv + lru gates-ish
                total += 2 * dr * dr  # gate projections W_a, W_x
            elif kind in ("slstm", "mlstm"):
                pf = (self.slstm_proj_factor if kind == "slstm"
                      else self.mlstm_proj_factor)
                dp = int(d * pf)
                total += 2 * d * dp + dp * d + 4 * dp * dp // self.n_heads
            # MLP
            if self.uses_moe_at(i):
                e_params = 3 * d * self.d_expert
                total += (self.n_routed + self.n_shared) * e_params
                total += d * self.n_routed  # router
            elif self.d_ff > 0:
                nmat = 3 if self.act == "swiglu" else 2
                total += nmat * d * self.d_ff
        if self.enc_layers:
            enc = self.enc_layers * (4 * d * self.n_heads * hd
                                     + 2 * d * self.d_ff)
            dec_cross = self.n_layers * 4 * d * self.n_heads * hd
            total += enc + dec_cross
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if not self.moe:
            return self.n_params()
        total = self.n_params()
        e_params = 3 * self.d_model * self.d_expert
        moe_layers = sum(1 for i in range(self.n_layers) if self.uses_moe_at(i))
        inactive = moe_layers * (self.n_routed - self.top_k) * e_params
        return total - inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        period = len(self.block_pattern)
        heads = 4
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            n_layers=max(2 * period, 2),
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            moe=self.moe,
            n_routed=8 if self.moe else 0,
            n_shared=min(self.n_shared, 1),
            top_k=2 if self.moe else 0,
            d_expert=32 if self.moe else 0,
            first_dense=min(self.first_dense, 1),
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            q_lora_rank=16 if self.q_lora_rank else 0,
            rope_head_dim=8 if self.attn_kind == "mla" else self.rope_head_dim,
            window=16,
            d_rnn=64 if self.d_rnn_ else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=8 if self.enc_seq else 0,
            d_frontend=64 if self.d_frontend else 0,
            n_vision_tokens=4 if self.n_vision_tokens else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One dry-run cell: what to lower and at which sizes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k context needs sub-quadratic mixing"
    return True, ""
