"""ChatGLM3-6B [dense]. 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2d-RoPE (rotary on half the head dims), multi-query GQA.
[arXiv:2406.12793; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=65024,
    qkv_bias=True,           # chatglm applies bias on QKV
    rope_kind="half",        # 2d rope: rotate first half of head dims
    act="swiglu",
    norm="rmsnorm",
)
