"""Whisper-large-v3 [audio]. 32L d_model=1280 20H (MHA) d_ff=5120
vocab=51866 — encoder-decoder; conv frontend is a STUB (``input_specs``
provides precomputed 1500-frame embeddings). [arXiv:2212.04356; unverified].

Deviations (documented in DESIGN.md §3): decoder uses RoPE instead of
learned positional embeddings (keeps decode caches position-free); encoder
keeps sinusoidal embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,             # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    rope_kind="full",
    act="gelu",
    norm="layernorm",
    enc_layers=32,
    enc_seq=1500,            # 30 s of audio at 50 Hz post-conv
    cross_attn=True,
    d_frontend=1280,
)
