"""InternVL2-2B [vlm]. Backbone: InternLM2-1.8B — 24L d_model=2048 16H
(GQA kv=8) d_ff=8192 vocab=92553. The InternViT-300M frontend is a STUB:
``input_specs`` provides precomputed patch embeddings (256 tokens, d=1024)
which a trainable projector maps into the LM. [arXiv:2404.16821; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    rope_kind="full",
    act="swiglu",
    norm="rmsnorm",
    n_vision_tokens=256,
    d_frontend=1024,         # InternViT-300M hidden size
)
