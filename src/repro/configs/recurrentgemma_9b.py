"""RecurrentGemma-9B [hybrid]. 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention in a 2:1 pattern (two recurrent
blocks, then one sliding-window block). [arXiv:2402.19427; unverified].

Sub-quadratic throughout ⇒ runs the ``long_500k`` shape (local attention
uses a 2048-slot ring buffer; RG-LRU state is O(d)).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,             # 12 × (rglru, rglru, local) + 2 remainder
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    d_rnn=4096,
    conv_width=4,
    rope_kind="full",
    act="swiglu",            # geglu in the paper; swiglu stand-in
    norm="rmsnorm",
)
