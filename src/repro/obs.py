"""``repro.obs`` — the thin observability export surface.

Everything lives in :mod:`repro.serve.telemetry`; this module is the
stable import point for consumers outside the serving stack (benchmarks,
launch drivers, notebooks)::

    from repro import obs
    p95 = obs.percentile(latencies, 0.95)
    reg = obs.MetricsRegistry()
"""
from repro.serve.telemetry import (
    NULL_TELEMETRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullTelemetry,
    Telemetry,
    Tracer,
    latency_summary,
    log_buckets,
    percentile,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_TELEMETRY",
    "NullTelemetry", "Telemetry", "Tracer", "latency_summary",
    "log_buckets", "percentile",
]
