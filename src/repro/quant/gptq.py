"""GPTQ-style Hessian-ordered quantizer (Frantar et al., 2023), JAX port.

For a linear layer ``y = x @ W`` with input autocorrelation
``H = E[x xᵀ] ∈ R^{m×m}``, GPTQ quantizes the rows of ``W`` (input
channels) sequentially, propagating the rounding error of row ``i`` into
the not-yet-quantized rows through the upper Cholesky factor ``U`` of
``H⁻¹`` (``H⁻¹ = Uᵀ U``): after rounding row ``i``,
``W[j,:] -= U[i,j]/U[i,i] · (W[i,:] − q_i)`` for ``j > i``.

This is the second "real" quantizer family used by the paper's
quantizer-agnostic study (Table 5). Group scales are fixed from the
original weights (standard practice); :class:`UniformQuantizer` provides
the rounding primitive.

The sequential loop is a ``lax.fori_loop`` over rows with full-row rank-1
updates — O(m²n), fine at calibration time and for benchmark dims.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.quant.uniform import UniformQuantizer


def _cholesky_inv_upper(h: jax.Array, damping: float) -> jax.Array:
    """Upper-triangular U with H⁻¹ = Uᵀ U (dampened)."""
    m = h.shape[0]
    d = damping * jnp.mean(jnp.diag(h))
    hd = h + (d + 1e-8) * jnp.eye(m, dtype=h.dtype)
    hinv = jnp.linalg.inv(hd)
    # symmetrize against numerical drift before the Cholesky
    hinv = 0.5 * (hinv + hinv.T)
    ell = jnp.linalg.cholesky(hinv)  # lower, H⁻¹ = L Lᵀ
    return ell.T  # upper U, H⁻¹ = Uᵀ U


@dataclasses.dataclass(frozen=True)
class GPTQQuantizer:
    """Hessian-aware sequential quantizer. Bind a Hessian with
    :meth:`make_bound` to obtain a ``Quantizer``-protocol object."""

    bits: int = 3
    group_size: int = 128
    symmetric: bool = False
    damping: float = 0.01

    @property
    def effective_bits(self) -> float:
        side = 16.0 if self.symmetric else 32.0
        return self.bits + side / self.group_size

    def _rounder(self) -> UniformQuantizer:
        return UniformQuantizer(bits=self.bits, group_size=self.group_size,
                                symmetric=self.symmetric)

    @functools.partial(jax.jit, static_argnums=0)
    def fake_quant_with_hessian(self, w: jax.Array, h: jax.Array) -> jax.Array:
        """Quantize ``w`` (m, n) given input autocorrelation ``h`` (m, m)."""
        m, n = w.shape
        g = self.group_size
        rounder = self._rounder()
        # fixed group scales from the original weights
        base = rounder.quantize(w)
        scales, zeros = base.scales, base.zeros

        uinv = _cholesky_inv_upper(h.astype(jnp.float32), self.damping)
        diag = jnp.clip(jnp.diag(uinv), 1e-8, None)

        def row_quant(i, wcur):
            row = jax.lax.dynamic_slice_in_dim(wcur, i, 1, axis=0)  # (1, n)
            gidx = i // g
            s = jax.lax.dynamic_slice_in_dim(scales, gidx, 1, axis=0)
            z = jax.lax.dynamic_slice_in_dim(zeros, gidx, 1, axis=0)
            if self.symmetric:
                qmax = 2 ** (self.bits - 1) - 1
                q = jnp.clip(jnp.round(row / s), -qmax - 1, qmax) * s
            else:
                levels = 2**self.bits - 1
                half = 2 ** (self.bits - 1)
                c = jnp.clip(jnp.round((row - z) / s) + half, 0, levels) - half
                q = c * s + z
            err = (row - q) / diag[i]  # (1, n)
            # propagate along row i of the upper factor into rows > i
            u_row = jax.lax.dynamic_slice_in_dim(uinv, i, 1, axis=0)  # (1, m)
            mask = (jnp.arange(m) > i).astype(wcur.dtype)[:, None]
            wnew = wcur - mask * (u_row.T * err)
            wnew = jax.lax.dynamic_update_slice_in_dim(wnew, q, i, axis=0)
            return wnew

        wq = jax.lax.fori_loop(0, m, row_quant, w.astype(jnp.float32))
        return wq.astype(w.dtype)

    def make_bound(self, h: jax.Array) -> "BoundGPTQ":
        return BoundGPTQ(self, h)


@dataclasses.dataclass(frozen=True)
class BoundGPTQ:
    """GPTQ with a baked-in Hessian, satisfying the Quantizer protocol."""

    inner: GPTQQuantizer
    hessian: jax.Array

    @property
    def effective_bits(self) -> float:
        return self.inner.effective_bits

    def fake_quant(self, w: jax.Array) -> jax.Array:
        return self.inner.fake_quant_with_hessian(w, self.hessian)

    def quantize(self, w: jax.Array):
        return self._rounder().quantize(self.fake_quant(w))

    def dequantize(self, packed):
        return self._rounder().dequantize(packed)

    def _rounder(self) -> UniformQuantizer:
        return UniformQuantizer(bits=self.inner.bits,
                                group_size=self.inner.group_size,
                                symmetric=self.inner.symmetric)


def hessian_from_activations(x: jax.Array) -> jax.Array:
    """H = Xᵀ X / N from calibration activations ``x`` (N, m)."""
    x = x.astype(jnp.float32)
    return (x.T @ x) / x.shape[0]
