"""Quantizer substrate: MXINT, uniform-int, GPTQ-style."""
from repro.quant.base import QuantizerConfig, effective_bits, quant_error, tree_bytes
from repro.quant.gptq import BoundGPTQ, GPTQQuantizer, hessian_from_activations
from repro.quant.mxint import (
    MXIntPacked,
    MXIntQuantizer,
    pack_codes_4bit,
    unpack_codes_4bit,
)
from repro.quant.uniform import UniformPacked, UniformQuantizer

__all__ = [
    "QuantizerConfig",
    "effective_bits",
    "quant_error",
    "tree_bytes",
    "MXIntPacked",
    "MXIntQuantizer",
    "pack_codes_4bit",
    "unpack_codes_4bit",
    "UniformPacked",
    "UniformQuantizer",
    "GPTQQuantizer",
    "BoundGPTQ",
    "hessian_from_activations",
    "make_quantizer",
]


def make_quantizer(config: QuantizerConfig, hessian=None):
    """Factory from a serializable config (+ optional calibration Hessian)."""
    if config.kind == "mxint":
        return MXIntQuantizer(bits=config.bits, block_size=config.block_size)
    if config.kind == "uniform":
        return UniformQuantizer(bits=config.bits, group_size=config.block_size,
                                symmetric=config.symmetric)
    if config.kind == "gptq":
        if hessian is None:
            raise ValueError("gptq quantizer needs a calibration Hessian")
        return GPTQQuantizer(bits=config.bits, group_size=config.block_size,
                             symmetric=config.symmetric,
                             damping=config.damping).make_bound(hessian)
    raise ValueError(f"unknown quantizer kind {config.kind!r}")
