"""Quantizer protocol.

A quantizer maps a full-precision weight matrix ``W`` to a *simulated*
quantized matrix ``Q = dequant(quant(W))`` plus an opaque packed
representation for deployment. All SRR/QER math operates on the simulated
``Q`` (exactly what the paper does); the packed form feeds the serving path
and the Pallas kernels.

Quantizers are stateless pytree-of-config objects so they can be passed
through jit boundaries as static args.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Tuple

import jax
import jax.numpy as jnp


class Quantizer(Protocol):
    """Protocol implemented by all weight quantizers."""

    #: effective bits per weight including side info (e.g. 3.25 for MXINT3/b32)
    effective_bits: float

    def quantize(self, w: jax.Array) -> Any:
        """Return an opaque packed representation of ``w``."""
        ...

    def dequantize(self, packed: Any) -> jax.Array:
        """Inverse of :meth:`quantize` up to rounding."""
        ...

    def fake_quant(self, w: jax.Array) -> jax.Array:
        """``dequantize(quantize(w))`` — the simulated quantized weights."""
        ...


@dataclasses.dataclass(frozen=True)
class QuantizerConfig:
    """Serializable description of a quantizer choice."""

    kind: str = "mxint"  # mxint | uniform | gptq | none
    bits: int = 3
    block_size: int = 32  # MXINT block / uniform group size
    symmetric: bool = True
    # GPTQ-specific
    damping: float = 0.01

    def key(self) -> str:
        return f"{self.kind}{self.bits}b{self.block_size}"


def quant_error(quantizer: Quantizer, w: jax.Array) -> jax.Array:
    """E_Q(W) = W - Q(W): the quantization error operator from the paper."""
    return w - quantizer.fake_quant(w)


def effective_bits(config: QuantizerConfig) -> float:
    """Average bits/weight including shared side information.

    MXINT with block ``b`` shares one 8-bit exponent per block:
    ``bits + 8/b`` (e.g. 3 + 8/32 = 3.25, matching the paper's accounting).
    Uniform group quantization stores one fp16 scale (+ fp16 zero point if
    asymmetric) per group.
    """
    if config.kind == "none":
        return 16.0
    if config.kind == "mxint":
        return config.bits + 8.0 / config.block_size
    if config.kind in ("uniform", "gptq"):
        side = 16.0 if config.symmetric else 32.0
        return config.bits + side / config.block_size
    raise ValueError(f"unknown quantizer kind {config.kind!r}")


def tree_bytes(tree: Any) -> int:
    """Total bytes of all arrays in a pytree (for memory accounting)."""
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if isinstance(x, (jax.Array, jnp.ndarray))
    )
