"""Group-wise uniform integer quantizer (symmetric or asymmetric).

Used as (a) a second quantizer family for the paper's quantizer-agnostic
study (Table 5) and (b) the rounding primitive inside the GPTQ-style
quantizer. Groups run along the reduction axis like MXINT blocks, but the
scale is a full-precision float (not a power of two).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.quant.mxint import _pad_rows


class UniformPacked(NamedTuple):
    codes: jax.Array      # int8 (m, n)
    scales: jax.Array     # f32 (m//g, n)
    zeros: jax.Array      # f32 (m//g, n) — 0 when symmetric
    group_size: int
    bits: int
    orig_rows: int


@dataclasses.dataclass(frozen=True)
class UniformQuantizer:
    bits: int = 3
    group_size: int = 32
    symmetric: bool = True

    @property
    def effective_bits(self) -> float:
        side = 16.0 if self.symmetric else 32.0
        return self.bits + side / self.group_size

    def quantize(self, w: jax.Array) -> UniformPacked:
        m, n = w.shape
        g = self.group_size
        wp = _pad_rows(w.astype(jnp.float32), g)
        blocks = wp.reshape(-1, g, n)
        if self.symmetric:
            qmax = 2 ** (self.bits - 1) - 1
            amax = jnp.max(jnp.abs(blocks), axis=1)
            scale = jnp.where(amax > 0, amax / qmax, 1.0)
            zero = jnp.zeros_like(scale)
            codes = jnp.clip(jnp.round(blocks / scale[:, None, :]), -qmax - 1, qmax)
        else:
            levels = 2**self.bits - 1
            lo = jnp.min(blocks, axis=1)
            hi = jnp.max(blocks, axis=1)
            rng = hi - lo
            scale = jnp.where(rng > 0, rng / levels, 1.0)
            zero = lo
            codes = jnp.clip(jnp.round((blocks - zero[:, None, :]) / scale[:, None, :]), 0, levels)
            codes = codes - 2 ** (self.bits - 1)  # recenter into int8 range
            zero = zero + scale * 2 ** (self.bits - 1)
        return UniformPacked(
            codes=codes.reshape(wp.shape).astype(jnp.int8),
            scales=scale,
            zeros=zero,
            group_size=g,
            bits=self.bits,
            orig_rows=m,
        )

    def dequantize(self, p: UniformPacked) -> jax.Array:
        g = p.group_size
        codes = p.codes.astype(jnp.float32)
        nb = codes.shape[0] // g
        n = codes.shape[1]
        out = codes.reshape(nb, g, n) * p.scales[:, None, :] + p.zeros[:, None, :]
        return out.reshape(codes.shape)[: p.orig_rows]

    def fake_quant(self, w: jax.Array) -> jax.Array:
        return self.dequantize(self.quantize(w)).astype(w.dtype)

    def round_with_scales(self, w: jax.Array, scales: jax.Array, zeros: jax.Array) -> jax.Array:
        """Round ``w`` (g-block rows) with *fixed* scales — GPTQ inner step.

        ``w`` is (m, n); scales/zeros are (m//g, n) computed beforehand.
        Returns the fake-quantized values (same shape as ``w``).
        """
        g = self.group_size
        m, n = w.shape
        wp = _pad_rows(w.astype(jnp.float32), g)
        blocks = wp.reshape(-1, g, n)
        if self.symmetric:
            qmax = 2 ** (self.bits - 1) - 1
            codes = jnp.clip(jnp.round(blocks / scales[:, None, :]), -qmax - 1, qmax)
            out = codes * scales[:, None, :]
        else:
            levels = 2**self.bits - 1
            q = jnp.round((blocks - zeros[:, None, :]) / scales[:, None, :])
            half = 2 ** (self.bits - 1)
            codes = jnp.clip(q + half, 0, levels) - half
            out = codes * scales[:, None, :] + zeros[:, None, :]
        return out.reshape(wp.shape)[:m]
