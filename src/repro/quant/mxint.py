"""MXINT block quantizer (Darvish Rouhani et al., 2023).

A block of ``block_size`` consecutive weights along the *reduction* axis
(axis 0 of a ``(m, n)`` weight used as ``y = x @ W``) shares a single 8-bit
power-of-two exponent; each element stores a signed ``bits``-bit integer
mantissa. Effective bitwidth is ``bits + 8/block_size`` (3.25 for the
paper's 3-bit/b32 setting).

Two representations:
  * :class:`MXIntPacked` — codes in an int8 container (algorithm path).
  * :func:`pack_codes_4bit` / :func:`unpack_codes_4bit` — deployment
    container for ``bits <= 4``: two codes per uint8 byte. The Pallas
    serving kernel consumes this form.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MXIntPacked(NamedTuple):
    """Quantized weight: int8 codes + per-block int8 exponents.

    ``codes``     int8  (m, n)           mantissas in [-qmax-1, qmax]
    ``exponents`` int8  (m//block, n)    shared power-of-2 exponent per block
    """

    codes: jax.Array
    exponents: jax.Array
    block_size: int
    bits: int
    orig_rows: int  # m before padding


def _qmax(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def _pad_rows(w: jax.Array, block: int) -> jax.Array:
    m = w.shape[0]
    pad = (-m) % block
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
    return w


@dataclasses.dataclass(frozen=True)
class MXIntQuantizer:
    """Symmetric MXINT quantizer with shared power-of-2 block exponents."""

    bits: int = 3
    block_size: int = 32

    @property
    def effective_bits(self) -> float:
        return self.bits + 8.0 / self.block_size

    def quantize(self, w: jax.Array) -> MXIntPacked:
        if w.ndim != 2:
            raise ValueError(f"MXInt expects 2-D weights, got {w.shape}")
        m, n = w.shape
        b = self.block_size
        qmax = _qmax(self.bits)
        wp = _pad_rows(w.astype(jnp.float32), b)
        blocks = wp.reshape(-1, b, n)  # (nb, b, n)
        amax = jnp.max(jnp.abs(blocks), axis=1)  # (nb, n)
        # smallest power-of-2 scale such that amax/scale <= qmax
        safe = jnp.where(amax > 0, amax, 1.0)
        exp = jnp.ceil(jnp.log2(safe / qmax))
        exp = jnp.clip(exp, -127, 127)
        scale = jnp.exp2(exp)[:, None, :]  # (nb, 1, n)
        codes = jnp.clip(jnp.round(blocks / scale), -qmax - 1, qmax)
        codes = jnp.where(amax[:, None, :] > 0, codes, 0.0)
        return MXIntPacked(
            codes=codes.reshape(wp.shape).astype(jnp.int8),
            exponents=exp.astype(jnp.int8),
            block_size=b,
            bits=self.bits,
            orig_rows=m,
        )

    def dequantize(self, packed: MXIntPacked) -> jax.Array:
        b = packed.block_size
        codes = packed.codes.astype(jnp.float32)
        nb = codes.shape[0] // b
        n = codes.shape[1]
        scale = jnp.exp2(packed.exponents.astype(jnp.float32))
        out = (codes.reshape(nb, b, n) * scale[:, None, :]).reshape(codes.shape)
        return out[: packed.orig_rows]

    def fake_quant(self, w: jax.Array) -> jax.Array:
        return self.dequantize(self.quantize(w)).astype(w.dtype)


def pack_codes_4bit(codes: jax.Array) -> jax.Array:
    """Pack int8 codes in [-8, 7] two-per-byte (even rows = low nibble).

    Rows live on axis -2; leading stack dims (scan groups, MoE expert
    stacks, the (B, KV) dims of a head-major KV cache) pass through.
    Input (..., m, n) int8 with m even; output (..., m//2, n) uint8.
    """
    if codes.shape[-2] % 2:
        raise ValueError("row count must be even to pack 4-bit pairs")
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[..., 0::2, :], u[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_codes_4bit(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_codes_4bit` → int8 codes in [-8, 7].

    Rows live on axis -2; leading stack dims (scan groups, MoE expert
    stacks, KV-cache head dims) pass through. Sign extension is
    shift-based — ``(x << 4) >> 4`` as int8 for the low nibble, an
    arithmetic ``>> 4`` of the reinterpreted byte for the high one —
    two ops per nibble instead of a compare-and-select over the full
    array (this runs per decode step over the whole int4 KV cache on
    the XLA path). Interleave via stack+reshape — a scatter into
    ``out[0::2]`` would materialize an extra full-size zero array."""
    lo = (packed << 4).astype(jnp.int8) >> 4
    hi = packed.astype(jnp.int8) >> 4
    lead, (m2, n) = packed.shape[:-2], packed.shape[-2:]
    # (…, m2, 2, n) → rows interleave as [lo0, hi0, lo1, hi1, …]
    return jnp.stack([lo, hi], axis=-2).reshape(lead + (m2 * 2, n))
