"""Partition rules: param path + shape → PartitionSpec.

Mesh axes (see :mod:`repro.launch.mesh`):

  * ``pod``   — pure data parallelism across pods (DCN)
  * ``data``  — FSDP: params + optimizer state sharded, all-gathered per use
  * ``model`` — tensor parallelism (attention heads / FFN columns / MoE
                experts / vocab)

Rules are *name-based*: every projection in the model zoo routes through
``repro.models.linear`` with a stable dict schema, so the last string key
on a pytree path identifies the tensor's role. Column-parallel weights
(input dim replicated-per-use, output dim TP-sharded) are ``wq/wk/wv/up/
gate/...``; row-parallel weights (input TP-sharded so a preceding
column-parallel output feeds in without a gather) are ``wo/down/w_out``.

Two structural wrinkles:
  * **scan stacks** — params under ``groups`` carry a leading
    ``n_groups`` layer dim, never sharded; rules apply to trailing dims.
  * **MoE experts** — params under ``experts`` carry a leading expert dim
    sharded over ``model`` (expert parallelism); within-expert dims then
    avoid the ``model`` axis.

Every rule degrades safely: a dim is only sharded when divisible by the
mesh axis and at least ``min_shard`` wide, otherwise it is replicated
(GSPMD would pad non-divisible dims — legal, but wasteful).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# weights whose *output* (last) dim is TP-sharded
_COL_PARALLEL = {
    "wq", "wk", "wv", "up", "gate", "up_gate", "w_gate", "w_branch",
    "w_gates", "ffn_up", "w_if", "lm_head", "frontend_proj", "vision_proj",
    "kv_down", "k_up", "v_up", "q_up", "q_proj", "w_kpe",
}
# weights whose *input* (second-to-last) dim is TP-sharded
_ROW_PARALLEL = {"wo", "down", "w_out", "ffn_down"}
# small / replicated by name
_REPLICATED = {"g", "b", "conv_w", "router", "a_param", "conv_state",
               "w_a", "w_x"}


def _path_names(path: Tuple[Any, ...]) -> list[str]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return names


def _divisible(dim: int, axis_size: int, min_shard: int) -> bool:
    return axis_size > 1 and dim >= min_shard and dim % axis_size == 0


def spec_for_param(
    path: Tuple[Any, ...],
    shape: Sequence[int],
    mesh: Mesh,
    fsdp_axis: str = "data",
    tp_axis: str = "model",
    min_shard: int = 128,
) -> P:
    """PartitionSpec for one parameter array."""
    names = _path_names(path)
    axes = dict(mesh.shape)
    fsdp = fsdp_axis if fsdp_axis in axes else None
    tp = tp_axis if tp_axis in axes else None
    fsdp_n = axes.get(fsdp_axis, 1)
    tp_n = axes.get(tp_axis, 1)

    leaf = names[-1] if names else ""
    in_experts = "experts" in names
    ndim = len(shape)

    def shard(dim_size: int, axis: Optional[str], axis_n: int) -> Optional[str]:
        return axis if axis and _divisible(dim_size, axis_n, min_shard) else None

    # ---- 1-D / small tensors --------------------------------------------
    if ndim <= 1 or leaf in _REPLICATED:
        base: Tuple[Optional[str], ...] = (None,) * max(ndim, 0)
        out = list(base)
        # per-expert 1-D params still shard the expert dim
        if in_experts and ndim >= 1:
            out[0] = shard(shape[0], tp, tp_n)
        return P(*out)

    # ---- role of the trailing 2 dims -------------------------------------
    m, n = shape[-2], shape[-1]
    if leaf == "w" and "embed" in names:
        two = (shard(m, tp, tp_n), shard(n, fsdp, fsdp_n))       # (vocab, d)
    elif leaf in _ROW_PARALLEL or (leaf == "w" and names and
                                   names[-2] in _ROW_PARALLEL):
        two = (shard(m, tp, tp_n), shard(n, fsdp, fsdp_n))
    elif leaf in _COL_PARALLEL or (leaf == "w" and len(names) >= 2 and
                                   names[-2] in _COL_PARALLEL):
        two = (shard(m, fsdp, fsdp_n), shard(n, tp, tp_n))
    elif leaf in ("codes", "packed", "scale", "l"):
        # quantized-backbone containers: inherit the parent linear's role
        parent = names[-2] if len(names) >= 2 else ""
        row = parent in _ROW_PARALLEL
        if leaf == "l":       # (m, rank): rank never sharded
            two = (shard(m, tp if row else fsdp,
                         tp_n if row else fsdp_n), None)
        elif row:
            two = (shard(m, tp, tp_n), shard(n, fsdp, fsdp_n))
        else:
            two = (shard(m, fsdp, fsdp_n), shard(n, tp, tp_n))
    elif leaf == "r":          # (rank, n): follow the output dim's role
        parent = names[-2] if len(names) >= 2 else ""
        row = parent in _ROW_PARALLEL
        two = (None, shard(n, fsdp if row else tp,
                           fsdp_n if row else tp_n))
    else:
        # default 2-D: FSDP the larger dim, TP the other when divisible
        if m >= n:
            two = (shard(m, fsdp, fsdp_n), shard(n, tp, tp_n))
        else:
            two = (shard(m, tp, tp_n), shard(n, fsdp, fsdp_n))

    # ---- leading dims: expert dim → TP; scan/layer dims → replicated ----
    lead: list[Optional[str]] = [None] * (ndim - 2)
    if in_experts and ndim >= 3 and tp and _divisible(shape[ndim - 3], tp_n, 1):
        # Expert parallelism wins the model axis: each device owns E/tp
        # whole experts (full-width local GEMMs, dispatch/combine become
        # all-to-alls) rather than slicing every small expert tp-ways.
        lead[-1] = tp
        two = tuple(a if a != tp else None for a in two)
    return P(*lead, *two)


def tree_param_specs(params: Any, mesh: Mesh, **kw) -> Any:
    """Pytree of PartitionSpec matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for_param(path, x.shape, mesh, **kw), params)


def tree_shardings(params: Any, mesh: Mesh, **kw) -> Any:
    """Pytree of NamedSharding matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(
            mesh, spec_for_param(path, x.shape, mesh, **kw)), params)


# ==========================================================================
# Activation / batch / cache specs
# ==========================================================================
def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod', 'data') when multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """DP axes usable for this batch (drop axes the batch can't fill)."""
    axes: Tuple[str, ...] = ()
    cap = 1
    for a in dp_axes(mesh):
        if global_batch % (cap * mesh.shape[a]) == 0:
            axes = axes + (a,)
            cap *= mesh.shape[a]
    return axes


def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    """Spec for a (batch, ...) array: batch over usable DP axes."""
    axes = batch_axes(mesh, global_batch)
    lead = axes if axes else None
    return P(lead, *([None] * extra_dims))


def data_shardings(mesh: Mesh, batch: dict, global_batch: int) -> dict:
    """NamedShardings for a train/prefill batch dict of arrays/specs."""
    out = {}
    for k, v in batch.items():
        nd = len(v.shape)
        out[k] = NamedSharding(mesh, batch_spec(mesh, global_batch, nd - 1))
    return out


def spec_for_cache(
    path: Tuple[Any, ...],
    shape: Sequence[int],
    mesh: Mesh,
    global_batch: int,
    tp_axis: str = "model",
    min_shard: int = 16,
) -> P:
    """Decode-cache sharding.

    Batch (dim 0) over the usable DP axes. The TP axis goes to, in
    preference order: the KV-head dim, the head_dim, or a latent channel
    dim — *never* the sequence dim (decode appends via
    dynamic_update_slice at a runtime position; sharding S would force
    GSPMD to all-gather the cache every step).
    """
    names = _path_names(path)
    leaf = names[-1] if names else ""
    axes = dict(mesh.shape)
    tp = tp_axis if tp_axis in axes else None
    tp_n = axes.get(tp_axis, 1)
    ndim = len(shape)
    if ndim == 0 or leaf in ("pos", "slot_pos"):
        return P(*([None] * ndim))

    spec: list[Optional[Any]] = [None] * ndim
    # leading scan-stack dim: cache trees under "groups" carry n_groups
    b_dim = 1 if (names and any(n.startswith("p") and n[1:].isdigit()
                                for n in names) and ndim >= 2
                  and "groups" in names) else 0
    b_dim = 0
    baxes = batch_axes(mesh, global_batch)
    # caches stacked for scan have layer dim first; batch is then dim 1
    if "groups" in names and ndim >= 2:
        b_dim = 1
    if baxes and shape[b_dim] >= 1:
        spec[b_dim] = baxes

    if tp is None:
        return P(*spec)

    def try_dim(d: int) -> bool:
        if d < ndim and spec[d] is None and shape[d] % tp_n == 0 \
                and shape[d] >= min_shard:
            spec[d] = tp
            return True
        return False

    if leaf in ("k", "v", "k_scale", "v_scale") and ndim - b_dim >= 3:
        # head-major slot cache — k/v (B, KV, S, hd), scales (B, KV, S);
        # int4 k/v pages are packed uint8 (B, KV, S/2, hd), where axis
        # b_dim+2 counts byte rows (= slot pairs, so a sequence shard
        # never splits a byte): prefer KV heads (axis right after
        # batch); else shard the SEQUENCE dim (flash-decode: scores stay
        # local, only softmax stats and the (B,1,H,hd) partial outputs
        # all-reduce — sharding head_dim would all-reduce full score
        # rows instead)
        if not try_dim(b_dim + 1):
            try_dim(b_dim + 2)
    elif leaf in ("cross_k", "cross_v") and ndim - b_dim >= 3:
        # cross-attention memories stay sequence-major (B, S, KV, hd)
        if not try_dim(b_dim + 2):
            try_dim(b_dim + 1)
    elif leaf in ("ckv", "kpe") and ndim - b_dim == 3:
        try_dim(b_dim + 1)            # (B, S, r_kv): sequence dim
    elif leaf in ("c", "n", "h", "cell", "state", "conv") or ndim >= 2:
        # recurrent states: shard the widest non-batch dim
        cands = sorted(range(b_dim + 1, ndim), key=lambda d: -shape[d])
        for d in cands:
            if try_dim(d):
                break
    return P(*spec)


def tree_cache_shardings(cache: Any, mesh: Mesh, global_batch: int,
                         **kw) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(
            mesh, spec_for_cache(path, x.shape, mesh, global_batch, **kw)),
        cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
