"""Distribution: partition rules for the (pod, data, model) mesh."""
from repro.sharding.rules import (
    batch_axes,
    batch_spec,
    data_shardings,
    dp_axes,
    replicated,
    spec_for_cache,
    spec_for_param,
    tree_cache_shardings,
    tree_param_specs,
    tree_shardings,
)

__all__ = [
    "batch_axes", "batch_spec", "data_shardings", "dp_axes", "replicated",
    "spec_for_cache", "spec_for_param", "tree_cache_shardings",
    "tree_param_specs", "tree_shardings",
]
