"""Atomic, restart-safe checkpointing.

Layout (one directory per run):

    <dir>/step_00000400/
        arrays.npz        flat {keystr: ndarray} of the full state pytree
        manifest.json     step, timestamp, config hash, mesh note, keys
    <dir>/LATEST          text file naming the newest complete step dir

Write protocol (preemption-safe at every point):
  1. write into ``<dir>/.tmp.<step>.<pid>``,
  2. fsync + atomic ``os.replace`` onto ``step_XXXXXXXX``,
  3. rewrite ``LATEST`` via the same tmp+replace dance,
  4. prune to ``keep`` newest.
A crash mid-write leaves only a ``.tmp.*`` orphan, never a torn
checkpoint; restore reads LATEST, falling back to the newest complete
``step_*`` dir if LATEST itself was lost.

Resharding on restore: arrays land as host numpy and are ``device_put``
against whatever shardings the *new* mesh prescribes, so a job restarted
on a different device count re-lays-out automatically (elastic restart).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _unflatten(tree_like: Any, arrays: Dict[str, np.ndarray]) -> Any:
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in paths_leaves:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"expected {like.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def save(self, step: int, state: Any, meta: Optional[dict] = None) -> str:
        flat = _flatten(state)
        tmp = tempfile.mkdtemp(prefix=f".tmp.{step}.", dir=self.directory)
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            manifest = {
                "step": step,
                "time": time.time(),
                "keys": sorted(flat),
                **(meta or {}),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._write_latest(step)
        self._prune()
        return self._step_dir(step)

    def _write_latest(self, step: int) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.directory)
        with os.fdopen(fd, "w") as f:
            f.write(f"step_{step:08d}\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.directory, "LATEST"))

    def _complete_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                p = os.path.join(self.directory, name)
                if os.path.exists(os.path.join(p, "manifest.json")):
                    steps.append(int(name[5:]))
        return sorted(steps)

    def _prune(self) -> None:
        steps = self._complete_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.directory, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            p = os.path.join(self.directory, name)
            if os.path.exists(os.path.join(p, "manifest.json")):
                return int(name[5:])
        steps = self._complete_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Returns (state, manifest). ``state_like`` provides structure
        (arrays or ShapeDtypeStructs); ``shardings`` re-lays-out on load."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = self._step_dir(step)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        state = _unflatten(state_like, arrays)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        else:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, manifest
