"""Step builders: full training, QPEFT adapter training, microbatching.

Each builder returns a pure ``step(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with in/out shardings — the same function lowers
on a laptop mesh and on the 512-chip production mesh.

QPEFT (the paper's §4.4 training mode) keeps the quantized backbone in
``state.frozen`` with ``stop_gradient`` semantics (it is simply not
differentiated), trains only the adapter tree, and applies the per-rank
gradient scaling (Eq. 7/SGP, baked into ``gscale`` vectors) *before* the
optimizer — matching the paper's "attenuate updates along preserved
directions" rule under any optimizer.

Cross-pod int8 error-feedback gradient compression (beyond-paper, for the
DCN-bound regime) is exposed as ``compress_pods=True``: gradients are
averaged per pod by the normal SPMD all-reduce, then synced across pods
with an int8 psum inside shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import Ctx, lm_loss
from repro.optim import (
    AdamState,
    AdamW,
    apply_updates,
    clip_by_global_norm,
    ef_compressed_psum,
    scale_lr_grads_by_key,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    step: jax.Array        # scalar int32


class QPEFTState(NamedTuple):
    trainable: Any         # adapter tree ({"l","r"} dicts)
    frozen: Any            # quantized backbone + norms + gscale vectors
    opt: AdamState
    step: jax.Array


def init_train_state(params: Any, opt: AdamW) -> TrainState:
    return TrainState(params=params, opt=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def init_qpeft_state(trainable: Any, frozen: Any, opt: AdamW) -> QPEFTState:
    return QPEFTState(trainable=trainable, frozen=frozen,
                      opt=opt.init(trainable),
                      step=jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class StepConfig:
    remat: str = "none"            # none | full
    grad_clip: float = 1.0
    compute_dtype: Any = jnp.bfloat16
    microbatch: int = 0            # 0 = no microbatching
    compress_pods: bool = False    # int8 EF all-reduce on the 'pod' axis
    mesh: Any = None               # enables activation sharding hints


def _grads_of(loss_fn: Callable, params: Any, batch: Dict,
              micro: int) -> Tuple[jax.Array, Any]:
    """(loss, grads), microbatched by scanning over batch slices."""
    if micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)
    b = batch["tokens"].shape[0]
    assert b % micro == 0, f"batch {b} not divisible by microbatch {micro}"
    mb = b // micro

    def slice_batch(i):
        return {k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, axis=0)
                for k, v in batch.items()}

    def body(carry, i):
        loss_acc, g_acc = carry
        li, gi = jax.value_and_grad(loss_fn)(params, slice_batch(i))
        g_acc = jax.tree_util.tree_map(lambda a, b_: a + b_, g_acc, gi)
        return (loss_acc + li, g_acc), None

    g0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), g0), jnp.arange(micro))
    scale = 1.0 / micro
    return loss * scale, jax.tree_util.tree_map(lambda g: g * scale, grads)


def make_train_step(cfg: ModelConfig, opt: AdamW,
                    sc: StepConfig = StepConfig()) -> Callable:
    """Full-parameter LM training step."""
    # fused="off": training differentiates through every projection, and
    # the Pallas serving kernels define no VJP — keep the jnp lowering
    ctx = Ctx(compute_dtype=sc.compute_dtype, mesh=sc.mesh, fused="off")

    def loss_fn(params, batch):
        return lm_loss(ctx, params, batch, cfg, remat=sc.remat)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        loss, grads = _grads_of(loss_fn, state.params, batch, sc.microbatch)
        grads, gnorm = clip_by_global_norm(grads, sc.grad_clip)
        updates, opt_state = opt.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": state.step + 1}
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


def make_qpeft_step(cfg: ModelConfig, opt: AdamW,
                    sc: StepConfig = StepConfig()) -> Callable:
    """Adapter-only training on a frozen quantized backbone (§4.4)."""
    from repro.models.quantize import merge_qpeft, qpeft_grad_scales
    # fused="off": grads flow through the (l, r) adapters inside linear()
    ctx = Ctx(compute_dtype=sc.compute_dtype, mesh=sc.mesh, fused="off")

    def step(state: QPEFTState, batch: Dict) -> Tuple[QPEFTState, Dict]:
        frozen = state.frozen

        def loss_fn(trainable, b):
            params = merge_qpeft(trainable, frozen)
            return lm_loss(ctx, params, b, cfg, remat=sc.remat)

        loss, grads = _grads_of(loss_fn, state.trainable, batch,
                                sc.microbatch)
        # paper Eq. 7 / SGP: attenuate preserved-direction gradients
        scales = qpeft_grad_scales(state.trainable, frozen)
        grads = scale_lr_grads_by_key(grads, scales)
        grads, gnorm = clip_by_global_norm(grads, sc.grad_clip)
        updates, opt_state = opt.update(grads, state.opt, state.trainable)
        trainable = apply_updates(state.trainable, updates)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": state.step + 1}
        return QPEFTState(trainable, frozen, opt_state, state.step + 1), \
            metrics

    return step


# ==========================================================================
# Cross-pod compressed gradient sync (opt-in, shard_map over 'pod')
# ==========================================================================
def make_compressed_sync(mesh, specs: Any) -> Callable:
    """Returns sync(grads, ef) -> (synced, ef'): int8 EF psum over 'pod'.

    ``specs`` is a pytree of PartitionSpec matching the gradient tree,
    *without* the 'pod' axis (per-pod gradients are replicated across
    pods' corresponding shards before the sync). Used when per-pod
    gradients are produced independently and the cross-pod reduction
    should ride DCN compressed.
    """
    from jax.experimental.shard_map import shard_map

    def sync(grads, ef):
        def inner(g, e):
            return ef_compressed_psum(g, e, axis="pod")
        return shard_map(
            inner, mesh=mesh,
            in_specs=(specs, specs),
            out_specs=(specs, specs),
            check_rep=False,
        )(grads, ef)

    return sync
