"""Training loop: jit'd step + checkpoint/restart + metrics.

Fault-tolerance posture (1000+ node design, exercised at laptop scale):

  * **checkpoint/restart** — CheckpointManager writes atomic, complete
    snapshots every ``ckpt_every`` steps; ``Trainer.run`` always tries to
    resume from the newest one, so a preempted/killed job relaunches with
    the same command line and continues. Verified by tests that kill and
    restart mid-run.
  * **deterministic data** — batches are pure functions of (seed, step),
    so a restarted or *replaced* host recomputes identical inputs; no
    data-loader state to replicate, no divergence between survivors and
    replacements.
  * **elastic restart** — the state is saved device-agnostic (host numpy)
    and re-laid-out against the restart mesh's shardings; a job restarted
    on a different device count reshards automatically.
  * **straggler mitigation** — steps are synchronous (SPMD), so the
    mitigation is replacement + deterministic recompute, plus step-time
    telemetry (``metrics["step_time"]``) to detect slow hosts; async
    variants (backup workers) are out of scope and documented in
    DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.train.checkpoint import CheckpointManager, config_hash


@dataclasses.dataclass
class Trainer:
    step_fn: Callable                    # (state, batch) -> (state, metrics)
    data_iter_fn: Callable[[int], Iterator[Dict[str, jax.Array]]]
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 100
    log_every: int = 10
    meta: Optional[dict] = None
    log_fn: Callable[[str], None] = print

    def run(self, state: Any, total_steps: int,
            state_shardings: Any = None) -> tuple[Any, List[Dict]]:
        """Run to ``total_steps``, resuming from the newest checkpoint."""
        start = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                state, manifest = self.ckpt.restore(
                    state, step=latest, shardings=state_shardings)
                start = int(manifest["step"])
                self.log_fn(f"[trainer] resumed from step {start}")
        if start >= total_steps:
            return state, []

        step_fn = self.step_fn
        history: List[Dict] = []
        data = self.data_iter_fn(start)
        t_last = time.perf_counter()
        for step in range(start, total_steps):
            batch = next(data)
            state, metrics = step_fn(state, batch)
            if (step + 1) % self.log_every == 0 or step + 1 == total_steps:
                metrics = {k: float(v) for k, v in metrics.items()}
                now = time.perf_counter()
                metrics["step_time"] = (now - t_last) / self.log_every
                t_last = now
                history.append(metrics)
                self.log_fn(
                    f"[trainer] step {step + 1}/{total_steps} "
                    f"loss={metrics.get('loss', float('nan')):.4f} "
                    f"({metrics['step_time'] * 1e3:.0f} ms/step)")
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, state, meta=self.meta)
        if self.ckpt is not None:
            self.ckpt.save(total_steps, state, meta=self.meta)
        return state, history
