"""Training substrate: steps, trainer loop, checkpointing."""
from repro.train.checkpoint import CheckpointManager, config_hash
from repro.train.steps import (
    QPEFTState,
    StepConfig,
    TrainState,
    init_qpeft_state,
    init_train_state,
    make_compressed_sync,
    make_qpeft_step,
    make_train_step,
)
from repro.train.trainer import Trainer

__all__ = [
    "CheckpointManager", "config_hash", "QPEFTState", "StepConfig",
    "TrainState", "init_qpeft_state", "init_train_state",
    "make_compressed_sync", "make_qpeft_step", "make_train_step", "Trainer",
]
