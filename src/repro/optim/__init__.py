"""Optimizer substrate: AdamW, schedules, grad transforms, compression."""
from repro.optim.adamw import (
    AdamState,
    AdamW,
    apply_updates,
    constant_schedule,
    cosine_schedule,
)
from repro.optim.compress import (
    dequantize_int8,
    ef_compressed_psum,
    init_error_feedback,
    quantize_int8,
)
from repro.optim.transforms import (
    clip_by_global_norm,
    global_norm,
    scale_lr_grads_by_key,
    srr_grad_transform,
)

__all__ = [
    "AdamState", "AdamW", "apply_updates", "constant_schedule",
    "cosine_schedule", "clip_by_global_norm", "global_norm",
    "scale_lr_grads_by_key", "srr_grad_transform", "dequantize_int8",
    "ef_compressed_psum", "init_error_feedback", "quantize_int8",
]
