"""Error-feedback int8 gradient compression for cross-pod data parallelism.

At 1000+ node scale the ``pod`` mesh axis rides the DCN, whose bandwidth
is an order of magnitude below ICI; the cross-pod gradient all-reduce is
then the dominant collective. Compressing that all-reduce from f32 to int8
cuts its bytes 4× at the cost of quantization noise, which *error
feedback* (Karimireddy et al., 2019; QSGD, Alistarh et al., 2017 — the
same additive-noise model the paper's Assumption 4.1 leans on) makes
asymptotically harmless: the residual of each step's quantization is added
back before the next step's compression, so noise averages out instead of
accumulating.

Usage inside a shard_map'd gradient sync (see repro.train.steps):

    g_local = ... per-pod mean gradient ...
    g_sync, new_ef = ef_compressed_psum(g_local, ef_state, axis="pod")

All ops are elementwise + one psum per leaf — jit/SPMD friendly.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(grads_like: Any) -> Any:
    """Zero residual buffers matching the gradient tree (f32)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (codes int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def ef_compressed_psum(
    grads: Any,
    ef: Any,
    axis: str,
) -> Tuple[Any, Any]:
    """Compressed cross-``axis`` mean with error feedback.

    Per leaf: c = Q8(g + ef);  synced = psum(c)/n;  ef' = (g + ef) − deq(c).
    The psum runs on int32 accumulations of int8 codes (codes fit: ≤127·n
    for n ≤ 2^24 pods), plus one scalar psum for the max scale.
    """
    n = jax.lax.psum(1.0, axis)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        # shared scale across the axis so codes are summable
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        codes = jnp.clip(jnp.round(g / scale), -127, 127)
        summed = jax.lax.psum(codes.astype(jnp.int32), axis)
        synced = summed.astype(jnp.float32) * scale / n
        new_e = g - codes * scale
        return synced, new_e

    is_pair = lambda t: type(t) is tuple
    pairs = jax.tree_util.tree_map(one, grads, ef)
    synced = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_ef = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_pair)
    return synced, new_ef
